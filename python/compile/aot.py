"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards. Interchange is HLO **text**, not serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version the published ``xla`` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per dataset preset this emits::

    artifacts/<preset>/init.hlo.txt          ()                      -> params
    artifacts/<preset>/client_fwd.hlo.txt    (cp..., x)              -> (act, act_dct)
    artifacts/<preset>/server_step.hlo.txt   (sp..., sm..., act, y, lr)
                                             -> (sp'..., sm'..., loss, correct, gact, gact_dct)
    artifacts/<preset>/client_step.hlo.txt   (cp..., cm..., x, gact, lr) -> (cp'..., cm'...)
    artifacts/<preset>/idct.hlo.txt          (coeffs)                -> spatial
    artifacts/<preset>/eval_step.hlo.txt     (cp..., sp..., x, y)    -> (loss, correct)

plus ``artifacts/manifest.json`` (signatures, shapes, flat parameter specs)
and ``artifacts/golden/golden.json`` (cross-language test vectors consumed
by ``rust/tests/golden_vectors.rs``).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import dct_kernel, ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default ELIDES large
    # constants as literal "{...}" placeholders, which the XLA text parser
    # happily reads back as zeros — silently zeroing the DCT basis matrices
    # and every initialized parameter.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO text still contains elided constants"
    return text


def _spec_json(specs):
    return [{"name": s.name, "shape": list(s.shape)} for s in specs]


def _shape_dtype(tree):
    """Flatten a pytree of arrays into [(shape, dtype_str), ...]."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [
        {"shape": list(l.shape), "dtype": str(l.dtype)} for l in leaves
    ]


def lower_preset(cfg: model.ModelConfig, out_dir: str) -> dict:
    """Lower all entry points for one preset; returns its manifest section."""
    os.makedirs(out_dir, exist_ok=True)
    b = cfg.batch_size
    f32 = jnp.float32
    x_spec = jax.ShapeDtypeStruct((b, cfg.in_channels, cfg.image_hw, cfg.image_hw), f32)
    y_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), f32)
    act_shape = cfg.activation_shape()
    act_spec = jax.ShapeDtypeStruct(act_shape, f32)

    cspecs = model.client_specs(cfg)
    sspecs = model.server_specs(cfg)
    cp_spec = [jax.ShapeDtypeStruct(s.shape, f32) for s in cspecs]
    sp_spec = [jax.ShapeDtypeStruct(s.shape, f32) for s in sspecs]

    artifacts = {}

    def emit(name, fn, *arg_specs):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = _shape_dtype(
            jax.eval_shape(fn, *arg_specs)
        )
        in_shapes = _shape_dtype(arg_specs)
        artifacts[name] = {
            "file": fname,
            "inputs": in_shapes,
            "outputs": out_shapes,
            "hlo_lines": len(text.splitlines()),
        }
        print(f"  {name:<12} {len(text.splitlines()):>6} HLO lines "
              f"{len(in_shapes):>3} in {len(out_shapes):>3} out")

    emit("init", functools.partial(model.entry_init, cfg))
    emit(
        "client_fwd",
        lambda cp, x: model.entry_client_fwd(cfg, cp, x),
        cp_spec,
        x_spec,
    )
    emit(
        "server_step",
        lambda sp, sm, a, y, lr: model.entry_server_step(cfg, sp, sm, a, y, lr),
        sp_spec,
        sp_spec,
        act_spec,
        y_spec,
        lr_spec,
    )
    emit(
        "client_step",
        lambda cp, cm, x, g, lr: model.entry_client_step(cfg, cp, cm, x, g, lr),
        cp_spec,
        cp_spec,
        x_spec,
        act_spec,
        lr_spec,
    )
    emit("idct", model.entry_idct, act_spec)
    emit(
        "eval_step",
        lambda cp, sp, x, y: model.entry_eval(cfg, cp, sp, x, y),
        cp_spec,
        sp_spec,
        x_spec,
        y_spec,
    )

    return {
        "batch_size": b,
        "in_channels": cfg.in_channels,
        "image_hw": cfg.image_hw,
        "num_classes": cfg.num_classes,
        "activation_shape": list(act_shape),
        "client_params": _spec_json(cspecs),
        "server_params": _spec_json(sspecs),
        "artifacts": artifacts,
        "vmem_bytes_per_tile": dct_kernel.vmem_bytes_estimate(
            act_shape[2], act_shape[3]
        ),
    }


def write_golden(out_dir: str, seed: int = 2026):
    """Cross-language test vectors for the Rust side."""
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    cases = []
    for shape in [(1, 2, 4, 4), (2, 3, 8, 8), (1, 1, 14, 14), (1, 2, 6, 10)]:
        x = rng.standard_normal(shape).astype(np.float32)
        y = np.asarray(dct_kernel.dct2_pallas(jnp.asarray(x)))
        back = np.asarray(dct_kernel.idct2_pallas(jnp.asarray(y)))
        cases.append(
            {
                "shape": list(shape),
                "input": [float(v) for v in x.ravel()],
                "dct": [float(v) for v in y.ravel()],
                "idct_roundtrip_max_err": float(np.abs(back - x).max()),
            }
        )
    zz = {
        f"{m}x{n}": [int(i) for i in ref.zigzag_indices(m, n)]
        for (m, n) in [(4, 4), (8, 8), (14, 14), (3, 5), (16, 16)]
    }
    afd = []
    for _ in range(6):
        m, n = int(rng.integers(2, 10)), int(rng.integers(2, 10))
        plane = rng.standard_normal((m, n)).astype(np.float32)
        plane *= np.exp(-0.3 * np.arange(m * n).reshape(m, n) / (m * n) * 10)
        order = ref.zigzag_indices(m, n)
        seq = plane.ravel()[order]
        theta = float(rng.choice([0.5, 0.7, 0.9, 0.95]))
        afd.append(
            {
                "m": m,
                "n": n,
                "plane": [float(v) for v in plane.ravel()],
                "theta": theta,
                "k_star": ref.afd_split_point(seq, theta),
            }
        )
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump({"dct_cases": cases, "zigzag": zz, "afd_cases": afd}, f)
    print(f"  golden vectors -> {out_dir}/golden.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="mnist,ham")
    args = ap.parse_args()

    manifest = {"version": 1, "presets": {}}
    for name in args.presets.split(","):
        cfg = model.PRESETS[name.strip()]
        print(f"lowering preset '{name}' "
              f"(batch {cfg.batch_size}, act {cfg.activation_shape()})")
        manifest["presets"][name] = lower_preset(
            cfg, os.path.join(args.out_dir, name)
        )
    write_golden(os.path.join(args.out_dir, "golden"))
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
