"""L2: the split ResNet in pure JAX (no flax), plus loss/optimizer.

The paper splits ResNet-18 after the "first three layers" (stem + first
residual stage) -- client side -- leaving the rest on the server. We follow
the same cut with a width-reduced ResNet sized for CPU-PJRT execution
(DESIGN.md section 3): the cut-layer tensor per sample keeps the (C, M, N)
layout the codec operates on, which is what matters for reproduction.

Normalization: GroupNorm instead of BatchNorm. The AOT artifacts must be
pure functions (no running statistics flowing between rust-held state and
the graph), and GroupNorm is the standard stats-free substitute in split /
federated settings where client batches are small and non-IID.

Parameters are **flat lists of arrays** with an explicit spec (name, shape)
so the lowering order is deterministic and the Rust manifest can describe
every HLO parameter. The optimizer is SGD with momentum, also expressed as
pure functions over flat lists.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dct_kernel


# --------------------------------------------------------------------------
# primitive layers
# --------------------------------------------------------------------------

def conv(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """3x3 (or 1x1) SAME convolution, NCHW activations, HWIO weights."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
    )


def group_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, groups: int = 4) -> jnp.ndarray:
    """GroupNorm over channel groups of an NCHW tensor."""
    b, c, h, w = x.shape
    g = min(groups, c)
    xg = x.reshape(b, g, c // g, h, w)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + 1e-5)
    x = xg.reshape(b, c, h, w)
    return x * gamma.reshape(1, c, 1, 1) + beta.reshape(1, c, 1, 1)


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

class ParamSpec(NamedTuple):
    """One parameter tensor: stable name + shape."""

    name: str
    shape: tuple


def _conv_spec(name, kh, kw, cin, cout):
    return ParamSpec(name, (kh, kw, cin, cout))


def _gn_spec(name, c):
    return [ParamSpec(f"{name}.gamma", (c,)), ParamSpec(f"{name}.beta", (c,))]


def _block_specs(name: str, cin: int, cout: int, stride: int):
    """Residual block: conv-gn-relu-conv-gn + (projection if shape changes)."""
    specs = [
        _conv_spec(f"{name}.conv1", 3, 3, cin, cout),
        *_gn_spec(f"{name}.gn1", cout),
        _conv_spec(f"{name}.conv2", 3, 3, cout, cout),
        *_gn_spec(f"{name}.gn2", cout),
    ]
    if stride != 1 or cin != cout:
        specs.append(_conv_spec(f"{name}.proj", 1, 1, cin, cout))
    return specs


class ModelConfig(NamedTuple):
    """Architecture + workload description for one dataset preset."""

    name: str
    in_channels: int
    image_hw: int
    num_classes: int
    base_width: int
    batch_size: int

    @property
    def cut_hw(self) -> int:
        """Spatial size of the cut-layer activations (stem stride 2)."""
        return self.image_hw // 2

    @property
    def cut_channels(self) -> int:
        return self.base_width

    def activation_shape(self):
        """Shape of the smashed data: (B, C, M, N)."""
        return (self.batch_size, self.cut_channels, self.cut_hw, self.cut_hw)


MNIST = ModelConfig("mnist", 1, 28, 10, 16, 32)
HAM = ModelConfig("ham", 3, 32, 7, 16, 32)
PRESETS = {"mnist": MNIST, "ham": HAM}


def client_specs(cfg: ModelConfig):
    """Client sub-model: stem conv (stride 2) + first residual block."""
    w = cfg.base_width
    return [
        _conv_spec("stem.conv", 3, 3, cfg.in_channels, w),
        *_gn_spec("stem.gn", w),
        *_block_specs("cblock", w, w, 1),
    ]


def server_specs(cfg: ModelConfig):
    """Server sub-model: two down-sampling stages + classifier head."""
    w = cfg.base_width
    return [
        *_block_specs("sblock1", w, 2 * w, 2),
        *_block_specs("sblock2", 2 * w, 4 * w, 2),
        ParamSpec("fc.w", (4 * w, cfg.num_classes)),
        ParamSpec("fc.b", (cfg.num_classes,)),
    ]


def init_params(specs, key):
    """He-normal conv init, unit gamma / zero beta, zero fc bias."""
    params = []
    for spec in specs:
        key, sub = jax.random.split(key)
        if spec.name.endswith(".gamma"):
            params.append(jnp.ones(spec.shape, jnp.float32))
        elif spec.name.endswith((".beta", ".b")):
            params.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.name.endswith(".w"):  # fc
            fan_in = spec.shape[0]
            params.append(
                jax.random.normal(sub, spec.shape, jnp.float32)
                * np.sqrt(2.0 / fan_in)
            )
        else:  # conv HWIO
            fan_in = spec.shape[0] * spec.shape[1] * spec.shape[2]
            params.append(
                jax.random.normal(sub, spec.shape, jnp.float32)
                * np.sqrt(2.0 / fan_in)
            )
    return params


# --------------------------------------------------------------------------
# forward passes (params consumed positionally from flat lists)
# --------------------------------------------------------------------------

class _P:
    """Sequential reader over a flat parameter list."""

    def __init__(self, params):
        self.params = list(params)
        self.i = 0

    def take(self, n=1):
        out = self.params[self.i : self.i + n]
        self.i += n
        return out[0] if n == 1 else out

    def done(self):
        assert self.i == len(self.params), f"consumed {self.i}/{len(self.params)}"


def _block_fwd(p: _P, x, cin, cout, stride):
    w1 = p.take()
    g1, b1 = p.take(2)
    w2 = p.take()
    g2, b2 = p.take(2)
    h = jax.nn.relu(group_norm(conv(x, w1, stride), g1, b1))
    h = group_norm(conv(h, w2, 1), g2, b2)
    if stride != 1 or cin != cout:
        x = conv(x, p.take(), stride)
    return jax.nn.relu(x + h)


def client_forward(cfg: ModelConfig, client_params, x):
    """Client sub-model: image batch -> cut-layer activations (B,C,M,N)."""
    p = _P(client_params)
    w = cfg.base_width
    h = jax.nn.relu(group_norm(conv(x, p.take(), 2), *p.take(2)))
    h = _block_fwd(p, h, w, w, 1)
    p.done()
    return h


def server_forward(cfg: ModelConfig, server_params, act):
    """Server sub-model: activations -> logits."""
    p = _P(server_params)
    w = cfg.base_width
    h = _block_fwd(p, act, w, 2 * w, 2)
    h = _block_fwd(p, h, 2 * w, 4 * w, 2)
    h = h.mean(axis=(2, 3))  # global average pool
    fw, fb = p.take(2)
    p.done()
    return h @ fw + fb


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def correct_count(logits, labels):
    """Number of correct top-1 predictions (int32)."""
    return (jnp.argmax(logits, axis=-1) == labels).sum().astype(jnp.int32)


# --------------------------------------------------------------------------
# optimizer (SGD + momentum over flat lists)
# --------------------------------------------------------------------------

def sgd_momentum(params, moms, grads, lr, mu=0.9):
    """m' = mu m + g ; p' = p - lr m'. Returns (new_params, new_moms)."""
    new_moms = [mu * m + g for m, g in zip(moms, grads)]
    new_params = [p - lr * m for p, m in zip(params, new_moms)]
    return new_params, new_moms


# --------------------------------------------------------------------------
# AOT entry points (each lowered to one HLO artifact by aot.py)
# --------------------------------------------------------------------------

def entry_client_fwd(cfg: ModelConfig, client_params, x):
    """-> (activations, dct_coeffs). The DCT runs in-graph via the Pallas
    kernel so the wire path never recomputes it host-side."""
    act = client_forward(cfg, client_params, x)
    return act, dct_kernel.dct2_pallas(act)


def entry_server_step(cfg: ModelConfig, server_params, server_moms, act, labels, lr):
    """Server training step on (decompressed) activations.

    -> (new_server_params..., new_moms..., loss, correct, grad_act,
        grad_act_dct). The gradient w.r.t. the activations is returned in
    both domains: spatial (for spatial-domain baseline codecs) and DCT (for
    SL-FAC's FQC on the downlink), computed by the same Pallas kernel.
    """

    def loss_fn(sp, a):
        logits = server_forward(cfg, sp, a)
        return cross_entropy(logits, labels), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
        server_params, act
    )
    gsp, gact = grads
    new_sp, new_sm = sgd_momentum(server_params, server_moms, gsp, lr)
    return (
        new_sp,
        new_sm,
        loss,
        correct_count(logits, labels),
        gact,
        dct_kernel.dct2_pallas(gact),
    )


def entry_client_step(cfg: ModelConfig, client_params, client_moms, x, grad_act, lr):
    """Client backward + update given the (decompressed) activation gradient.

    Recomputes the client forward (standard SL: the client kept no
    intermediate state between the two phases of a step) and pulls the
    cotangent through with vjp. -> (new_client_params..., new_moms...).
    """

    def fwd(cp):
        return client_forward(cfg, cp, x)

    _, vjp = jax.vjp(fwd, client_params)
    (gcp,) = vjp(grad_act)
    new_cp, new_cm = sgd_momentum(client_params, client_moms, gcp, lr)
    return new_cp, new_cm


def entry_idct(coeffs):
    """Decompression tail: coefficient planes -> spatial tensor."""
    return dct_kernel.idct2_pallas(coeffs)


def entry_eval(cfg: ModelConfig, client_params, server_params, x, labels):
    """Full-model evaluation on one batch -> (mean loss, correct count)."""
    act = client_forward(cfg, client_params, x)
    logits = server_forward(cfg, server_params, act)
    return cross_entropy(logits, labels), correct_count(logits, labels)


def entry_init(cfg: ModelConfig, seed: int = 0):
    """-> (client_params..., server_params...). Momenta start at zero and
    are materialized Rust-side (manifest carries the shapes)."""
    key = jax.random.PRNGKey(seed)
    kc, ks = jax.random.split(key)
    return init_params(client_specs(cfg), kc), init_params(server_specs(cfg), ks)
