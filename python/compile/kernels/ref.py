"""Pure-jnp reference implementations (the correctness oracles).

Everything the Pallas kernel (dct_kernel.py) and the Rust frequency stack
must agree with is defined here once, in the most transparent form:

* ``dct_matrix(n)``   -- orthonormal DCT-II basis matrix (paper Eq. 1-2).
* ``dct2`` / ``idct2`` -- per-channel 2-D DCT-II / DCT-III over (B, C, M, N).
* ``zigzag_indices``  -- JPEG-style anti-diagonal scan order for MxN planes.
* ``spectral_energy`` / ``cumulative_energy_ratio`` -- Eq. 3 / Eq. 4.
* ``afd_split_point`` -- the smallest k* with ratio >= theta (Algorithm 1).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def dct_matrix(n: int) -> jnp.ndarray:
    """Orthonormal DCT-II basis: D[u, m] = a(u) cos(pi/n (m+1/2) u).

    Matches paper Eq. 1-2 (written there 1-based; this is the standard
    0-based form). D is orthogonal: D @ D.T = I.
    """
    m = np.arange(n)
    u = np.arange(n)[:, None]
    mat = np.cos(np.pi / n * (m + 0.5) * u)
    mat[0] *= np.sqrt(1.0 / n)
    mat[1:] *= np.sqrt(2.0 / n)
    return jnp.asarray(mat, dtype=jnp.float32)


def dct2(x: jnp.ndarray) -> jnp.ndarray:
    """2-D DCT-II of each channel of a (..., M, N) array: D_M @ X @ D_N^T."""
    m, n = x.shape[-2], x.shape[-1]
    dm = dct_matrix(m)
    dn = dct_matrix(n)
    return jnp.einsum("um,...mn,vn->...uv", dm, x, dn)


def idct2(y: jnp.ndarray) -> jnp.ndarray:
    """Inverse (DCT-III) of each channel: D_M^T @ Y @ D_N."""
    m, n = y.shape[-2], y.shape[-1]
    dm = dct_matrix(m)
    dn = dct_matrix(n)
    return jnp.einsum("mu,...uv,nv->...mn", dm.T, y, dn.T)


def zigzag_indices(m: int, n: int) -> np.ndarray:
    """Row-major indices of an MxN plane in zig-zag (low->high freq) order.

    Even anti-diagonals are walked bottom-left->top-right, odd ones the
    other way (JPEG convention, generalized to rectangles). Must match
    ``slfac::freq::ZigZag`` exactly -- cross-checked by the golden vectors.
    """
    out = []
    for d in range(m + n - 1):
        r_lo = max(0, d - n + 1)
        r_hi = min(d, m - 1)
        rows = range(r_hi, r_lo - 1, -1) if d % 2 == 0 else range(r_lo, r_hi + 1)
        for r in rows:
            out.append(r * n + (d - r))
    return np.asarray(out, dtype=np.int64)


def spectral_energy(coeffs: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3: E = X^2 elementwise."""
    return coeffs * coeffs


def cumulative_energy_ratio(coeffs_zigzag: np.ndarray) -> np.ndarray:
    """Eq. 4 over an already-zig-zag-ordered 1-D coefficient sequence."""
    e = np.asarray(coeffs_zigzag, dtype=np.float64) ** 2
    total = e.sum()
    if total <= 0:
        return np.ones_like(e)
    return np.cumsum(e) / total


def afd_split_point(coeffs_zigzag: np.ndarray, theta: float) -> int:
    """Smallest k* (1-based count) with cumulative ratio >= theta.

    All-zero planes default to k* = 1 (the DC term), matching the Rust
    implementation (``slfac::freq::afd_channel``).
    """
    e = np.asarray(coeffs_zigzag, dtype=np.float64) ** 2
    if e.sum() <= 0:
        return 1
    r = cumulative_energy_ratio(coeffs_zigzag)
    idx = np.nonzero(r >= theta - 1e-15)[0]
    return int(idx[0]) + 1 if idx.size else len(coeffs_zigzag)
