"""L1: the batched per-channel 2-D DCT as a Pallas kernel.

The paper's compute hot-spot on the wire path is the frequency transform of
the smashed data (AFD step 1, Eq. 1). On GPU the authors run it as CUDA
tensor ops; re-thought for TPU (DESIGN.md section "Hardware-Adaptation"):

* the 2-D DCT factorizes into two dense matmuls per channel,
  ``D_M @ X @ D_N^T`` -- an MXU (systolic array) workload;
* the grid iterates over the flattened (batch x channel) planes; BlockSpec
  keeps one ``M x N`` plane plus both basis matrices resident in VMEM per
  grid step (< 3 KiB for 14x14 f32 -- far under the ~16 MiB VMEM budget,
  see ``vmem_bytes_estimate``), so there are no HBM round-trips between the
  two matmuls;
* both matmuls accumulate in f32 via ``preferred_element_type`` so the
  kernel is bfloat16-input ready on real MXU hardware.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which runs on any backend
and is bit-compatible with the ref oracle. On a real TPU the same
``pallas_call`` compiles with ``interpret=False`` unchanged.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _dct2_kernel_grid(x_ref, dm_ref, dnt_ref, o_ref):
    """One grid step: transform one (M, N) plane. All refs live in VMEM."""
    x = x_ref[0]  # (M, N)
    # tmp = D_M @ X  -- MXU matmul 1
    tmp = jnp.dot(dm_ref[...], x, preferred_element_type=jnp.float32)
    # out = tmp @ D_N^T -- MXU matmul 2
    o_ref[0] = jnp.dot(tmp, dnt_ref[...], preferred_element_type=jnp.float32)


def _dct2_kernel_block(x_ref, dm_ref, dnt_ref, o_ref):
    """Single-block form: transform all (B*C) planes with batched matmuls.

    Used for the AOT/CPU path. The grid form (`_dct2_kernel_grid`) lowers
    interpret-mode to an HLO while-loop with dynamic-update-slice, which
    xla_extension 0.5.1 (the version the rust `xla` crate binds) parses but
    executes incorrectly (all-zero output buffers). The single-block form
    lowers to plain dot_generals — identical math, and on a real TPU the
    grid form is what you would compile (see DESIGN.md
    §Hardware-Adaptation).
    """
    x = x_ref[...]  # (BC, M, N)
    tmp = jnp.einsum(
        "um,bmn->bun", dm_ref[...], x, preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.einsum(
        "bun,vn->buv", tmp, dnt_ref[...].T, preferred_element_type=jnp.float32
    )


#: Set SLFAC_PALLAS_GRID=1 to lower the per-plane grid variant (real-TPU
#: shape; not executable by the CPU xla_extension 0.5.1 runtime — see
#: `_dct2_kernel_block`).
USE_GRID = os.environ.get("SLFAC_PALLAS_GRID", "0") == "1"


def _transform(x: jnp.ndarray, dm: jnp.ndarray, dnt: jnp.ndarray) -> jnp.ndarray:
    """Apply the kernel over (B, C, M, N)."""
    b, c, m, n = x.shape
    flat = x.reshape(b * c, m, n)
    if USE_GRID:
        out = pl.pallas_call(
            _dct2_kernel_grid,
            grid=(b * c,),
            in_specs=[
                pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),  # one plane/step
                pl.BlockSpec((m, m), lambda i: (0, 0)),        # D_M resident
                pl.BlockSpec((n, n), lambda i: (0, 0)),        # D_N^T resident
            ],
            out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b * c, m, n), jnp.float32),
            interpret=True,
        )(flat, dm, dnt)
    else:
        out = pl.pallas_call(
            _dct2_kernel_block,
            out_shape=jax.ShapeDtypeStruct((b * c, m, n), jnp.float32),
            interpret=True,  # CPU-PJRT compatible; see module docstring
        )(flat, dm, dnt)
    return out.reshape(b, c, m, n)


@functools.partial(jax.jit, static_argnames=())
def dct2_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Forward 2-D DCT-II of every channel of a (B, C, M, N) tensor."""
    m, n = x.shape[-2], x.shape[-1]
    dm = ref.dct_matrix(m)
    dn = ref.dct_matrix(n)
    return _transform(x, dm, dn.T)


@functools.partial(jax.jit, static_argnames=())
def idct2_pallas(y: jnp.ndarray) -> jnp.ndarray:
    """Inverse (DCT-III): D_M^T @ Y @ D_N."""
    m, n = y.shape[-2], y.shape[-1]
    dm = ref.dct_matrix(m)
    dn = ref.dct_matrix(n)
    return _transform(y, dm.T, dn)


def vmem_bytes_estimate(m: int, n: int) -> int:
    """Per-grid-step VMEM footprint (bytes): one plane in, one out, both
    basis matrices, plus the (M, N) matmul temporary. Used by DESIGN.md's
    real-TPU estimate and checked in the perf tests."""
    plane = m * n * 4
    return 2 * plane + (m * m + n * n) * 4 + plane


def mxu_utilization_estimate(m: int, n: int) -> float:
    """Fraction of a 128x128 MXU pass the two matmuls fill (upper bound on
    achievable MXU efficiency for one plane; batching planes into the grid
    amortizes the systolic pipeline fill)."""
    return min(1.0, m / 128.0) * min(1.0, n / 128.0)
