"""Oracle self-tests: the pure-jnp reference must satisfy the mathematical
properties the paper relies on before it can judge the Pallas kernel."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref


@pytest.mark.parametrize("n", [1, 2, 4, 7, 14, 16, 28])
def test_dct_matrix_orthonormal(n):
    d = np.asarray(ref.dct_matrix(n), dtype=np.float64)
    np.testing.assert_allclose(d @ d.T, np.eye(n), atol=1e-5)


def test_dct2_constant_concentrates_at_dc():
    x = jnp.full((1, 1, 8, 8), 3.0)
    y = np.asarray(ref.dct2(x))[0, 0]
    assert abs(y[0, 0] - 3.0 * 8.0) < 1e-4  # c * sqrt(M*N)
    assert np.abs(y).sum() - abs(y[0, 0]) < 1e-4


@pytest.mark.parametrize("shape", [(1, 1, 4, 4), (2, 3, 8, 8), (1, 2, 14, 10)])
def test_dct2_idct2_roundtrip(shape):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)
    back = ref.idct2(ref.dct2(x))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


def test_dct2_preserves_energy():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 4, 8, 8)), dtype=jnp.float32)
    ex = float((x * x).sum())
    y = ref.dct2(x)
    ey = float((y * y).sum())
    assert abs(ex - ey) / ex < 1e-5


@pytest.mark.parametrize("m,n", [(1, 1), (4, 4), (8, 8), (3, 5), (5, 3), (14, 14)])
def test_zigzag_is_permutation(m, n):
    idx = ref.zigzag_indices(m, n)
    assert sorted(idx.tolist()) == list(range(m * n))


def test_zigzag_8x8_matches_jpeg_prefix():
    idx = ref.zigzag_indices(8, 8)
    assert idx[:10].tolist() == [0, 1, 8, 16, 9, 2, 3, 10, 17, 24]


def test_cumulative_ratio_monotone_and_bounded():
    rng = np.random.default_rng(3)
    seq = rng.standard_normal(32)
    r = ref.cumulative_energy_ratio(seq)
    assert np.all(np.diff(r) >= -1e-12)
    assert abs(r[-1] - 1.0) < 1e-9


def test_afd_split_point_threshold_semantics():
    seq = np.array([10.0, 1.0, 0.5, 0.1, 0.01])
    k = ref.afd_split_point(seq, 0.9)
    r = ref.cumulative_energy_ratio(seq)
    assert r[k - 1] >= 0.9
    if k > 1:
        assert r[k - 2] < 0.9


def test_afd_zero_plane_defaults_to_one():
    assert ref.afd_split_point(np.zeros(16), 0.9) == 1


def test_afd_theta_one_takes_all():
    seq = np.ones(9)
    assert ref.afd_split_point(seq, 1.0) == 9
