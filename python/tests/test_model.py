"""L2 tests: split-model shapes, gradient flow, optimizer semantics, and a
short end-to-end training sanity check through the *exact* entry points the
AOT artifacts freeze."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(scope="module", params=["mnist", "ham"])
def cfg(request):
    return model.PRESETS[request.param]


def _data(cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.standard_normal(
            (cfg.batch_size, cfg.in_channels, cfg.image_hw, cfg.image_hw)
        ),
        dtype=jnp.float32,
    )
    y = jnp.asarray(rng.integers(0, cfg.num_classes, cfg.batch_size), dtype=jnp.int32)
    return x, y


def _init(cfg, seed=0):
    return model.entry_init(cfg, seed)


def test_param_specs_match_init_shapes(cfg):
    cp, sp = _init(cfg)
    cspecs, sspecs = model.client_specs(cfg), model.server_specs(cfg)
    assert len(cp) == len(cspecs)
    assert len(sp) == len(sspecs)
    for p, s in zip(cp + sp, cspecs + sspecs):
        assert p.shape == s.shape, s.name


def test_client_forward_shape(cfg):
    cp, _ = _init(cfg)
    x, _ = _data(cfg)
    act = model.client_forward(cfg, cp, x)
    assert act.shape == cfg.activation_shape()
    assert bool(jnp.all(jnp.isfinite(act)))


def test_client_fwd_entry_returns_act_and_dct(cfg):
    from compile.kernels import ref

    cp, _ = _init(cfg)
    x, _ = _data(cfg)
    act, act_dct = model.entry_client_fwd(cfg, cp, x)
    np.testing.assert_allclose(
        np.asarray(act_dct), np.asarray(ref.dct2(act)), atol=1e-3
    )


def test_server_forward_logits(cfg):
    cp, sp = _init(cfg)
    x, _ = _data(cfg)
    act = model.client_forward(cfg, cp, x)
    logits = model.server_forward(cfg, sp, act)
    assert logits.shape == (cfg.batch_size, cfg.num_classes)


def test_server_step_updates_and_grad_shapes(cfg):
    cp, sp = _init(cfg)
    sm = [jnp.zeros_like(p) for p in sp]
    x, y = _data(cfg)
    act = model.client_forward(cfg, cp, x)
    new_sp, new_sm, loss, correct, gact, gact_dct = model.entry_server_step(
        cfg, sp, sm, act, y, jnp.float32(0.05)
    )
    assert gact.shape == act.shape
    assert gact_dct.shape == act.shape
    assert float(loss) > 0
    assert 0 <= int(correct) <= cfg.batch_size
    # parameters actually moved
    deltas = [float(jnp.abs(a - b).max()) for a, b in zip(sp, new_sp)]
    assert max(deltas) > 0
    # momentum buffers now hold the gradients
    assert all(m.shape == p.shape for m, p in zip(new_sm, new_sp))


def test_client_step_moves_params(cfg):
    cp, sp = _init(cfg)
    cm = [jnp.zeros_like(p) for p in cp]
    sm = [jnp.zeros_like(p) for p in sp]
    x, y = _data(cfg)
    act = model.client_forward(cfg, cp, x)
    _, _, _, _, gact, _ = model.entry_server_step(cfg, sp, sm, act, y, jnp.float32(0.05))
    new_cp, new_cm = model.entry_client_step(cfg, cp, cm, x, gact, jnp.float32(0.05))
    deltas = [float(jnp.abs(a - b).max()) for a, b in zip(cp, new_cp)]
    assert max(deltas) > 0
    assert len(new_cm) == len(cp)


def test_eval_entry_consistent_with_manual(cfg):
    cp, sp = _init(cfg)
    x, y = _data(cfg)
    loss, correct = model.entry_eval(cfg, cp, sp, x, y)
    act = model.client_forward(cfg, cp, x)
    logits = model.server_forward(cfg, sp, act)
    np.testing.assert_allclose(
        float(loss), float(model.cross_entropy(logits, y)), atol=1e-6
    )
    assert int(correct) == int(model.correct_count(logits, y))


def test_sgd_momentum_semantics():
    p = [jnp.asarray([1.0, 2.0])]
    m = [jnp.asarray([0.5, 0.0])]
    g = [jnp.asarray([1.0, -1.0])]
    new_p, new_m = model.sgd_momentum(p, m, g, lr=0.1, mu=0.9)
    np.testing.assert_allclose(np.asarray(new_m[0]), [1.45, -1.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_p[0]), [1.0 - 0.145, 2.0 + 0.1], atol=1e-6)


def test_short_training_reduces_loss():
    """A few full split steps on a tiny learnable problem must reduce loss —
    this is the L2 gradient-flow smoke test that guards the artifacts."""
    cfg = model.MNIST
    cp, sp = _init(cfg, seed=1)
    cm = [jnp.zeros_like(p) for p in cp]
    sm = [jnp.zeros_like(p) for p in sp]
    # one fixed batch, overfit it
    rng = np.random.default_rng(3)
    x = jnp.asarray(
        rng.standard_normal((cfg.batch_size, 1, 28, 28)), dtype=jnp.float32
    )
    y = jnp.asarray(rng.integers(0, 10, cfg.batch_size), dtype=jnp.int32)
    lr = jnp.float32(0.05)

    losses = []
    for _ in range(8):
        act = model.client_forward(cfg, cp, x)
        sp, sm, loss, _, gact, _ = model.entry_server_step(cfg, sp, sm, act, y, lr)
        cp, cm = model.entry_client_step(cfg, cp, cm, x, gact, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_group_norm_normalizes():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 8, 6, 6)) * 10 + 5, dtype=jnp.float32)
    gamma = jnp.ones(8)
    beta = jnp.zeros(8)
    out = model.group_norm(x, gamma, beta, groups=4)
    # per-(sample, group) stats ~ (0, 1)
    g = np.asarray(out).reshape(2, 4, 2, 6, 6)
    np.testing.assert_allclose(g.mean(axis=(2, 3, 4)), 0.0, atol=1e-4)
    np.testing.assert_allclose(g.std(axis=(2, 3, 4)), 1.0, atol=1e-2)
