"""L1 correctness: the Pallas DCT kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed tests cover the algebraic properties
(linearity, orthonormality/Parseval, adjointness of forward/inverse).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dct_kernel, ref


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@pytest.mark.parametrize(
    "shape", [(1, 1, 4, 4), (2, 3, 8, 8), (4, 16, 14, 14), (1, 2, 6, 10), (3, 1, 16, 16)]
)
def test_kernel_matches_ref_forward(shape):
    x = _rand(shape, 1)
    got = np.asarray(dct_kernel.dct2_pallas(x))
    want = np.asarray(ref.dct2(x))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 4, 4), (2, 3, 8, 8), (1, 2, 14, 14)])
def test_kernel_matches_ref_inverse(shape):
    y = _rand(shape, 2)
    got = np.asarray(dct_kernel.idct2_pallas(y))
    want = np.asarray(ref.idct2(y))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    c=st.integers(1, 6),
    m=st.integers(2, 16),
    n=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_roundtrip_any_shape(b, c, m, n, seed):
    x = _rand((b, c, m, n), seed)
    back = dct_kernel.idct2_pallas(dct_kernel.dct2_pallas(x))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 14), n=st.integers(2, 14), seed=st.integers(0, 10_000))
def test_kernel_agrees_with_ref_property(m, n, seed):
    x = _rand((1, 2, m, n), seed)
    np.testing.assert_allclose(
        np.asarray(dct_kernel.dct2_pallas(x)),
        np.asarray(ref.dct2(x)),
        atol=1e-4,
    )


def test_kernel_is_linear():
    x = _rand((1, 2, 8, 8), 3)
    y = _rand((1, 2, 8, 8), 4)
    lhs = dct_kernel.dct2_pallas(2.0 * x + 3.0 * y)
    rhs = 2.0 * dct_kernel.dct2_pallas(x) + 3.0 * dct_kernel.dct2_pallas(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


def test_kernel_preserves_energy():
    x = _rand((2, 4, 14, 14), 5)
    y = dct_kernel.dct2_pallas(x)
    ex = float((x * x).sum())
    ey = float(jnp.sum(y * y))
    assert abs(ex - ey) / ex < 1e-5


def test_kernel_handles_batch_channel_flattening_order():
    # Each (b, c) plane must be transformed independently: check one plane
    # against a single-plane call.
    x = _rand((2, 3, 8, 8), 6)
    full = np.asarray(dct_kernel.dct2_pallas(x))
    single = np.asarray(dct_kernel.dct2_pallas(x[1:2, 2:3]))
    np.testing.assert_allclose(full[1, 2], single[0, 0], atol=1e-5)


def test_vmem_estimate_under_budget():
    # DESIGN.md section 8: the per-tile footprint must sit far below a real
    # TPU's ~16 MiB VMEM for every shape this project uses.
    for m, n in [(14, 14), (16, 16)]:
        assert dct_kernel.vmem_bytes_estimate(m, n) < 64 * 1024


def test_float32_dtype_out():
    x = _rand((1, 1, 4, 4), 7)
    assert dct_kernel.dct2_pallas(x).dtype == jnp.float32
