"""AOT path tests: the HLO-text lowering contract the Rust runtime relies on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_has_full_constants():
    """print_large_constants must be in effect: elided '{...}' placeholders
    parse back as ZEROS in xla_extension 0.5.1 and silently zero the DCT
    bases (the root cause of a real bug during bring-up)."""
    lowered = jax.jit(model.entry_idct).lower(
        jax.ShapeDtypeStruct((2, 2, 8, 8), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "{...}" not in text
    # the 8x8 orthonormal DCT basis contains 1/sqrt(8) = 0.35355...
    assert "0.35" in text


def test_lowered_entry_is_tuple_rooted():
    lowered = jax.jit(model.entry_idct).lower(
        jax.ShapeDtypeStruct((1, 1, 4, 4), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    # return_tuple=True ⇒ the entry root is a tuple
    assert "ROOT tuple" in text


def test_lower_preset_writes_all_artifacts(tmp_path):
    cfg = model.ModelConfig("tiny", 1, 8, 3, 4, 4)  # small & fast
    section = aot.lower_preset(cfg, str(tmp_path))
    expected = {"init", "client_fwd", "server_step", "client_step", "idct", "eval_step"}
    assert set(section["artifacts"].keys()) == expected
    for name, sig in section["artifacts"].items():
        path = tmp_path / sig["file"]
        assert path.exists(), name
        text = path.read_text()
        assert "{...}" not in text, f"{name} has elided constants"
    # manifest shape info consistent with the config
    assert section["activation_shape"] == [4, 4, 4, 4]
    assert len(section["client_params"]) == len(model.client_specs(cfg))
    assert len(section["server_params"]) == len(model.server_specs(cfg))
    # io signatures: client_fwd takes params + x, returns act + dct
    cf = section["artifacts"]["client_fwd"]
    assert len(cf["inputs"]) == len(model.client_specs(cfg)) + 1
    assert len(cf["outputs"]) == 2
    assert cf["outputs"][0]["shape"] == section["activation_shape"]


def test_write_golden_is_deterministic(tmp_path):
    d1 = tmp_path / "g1"
    d2 = tmp_path / "g2"
    aot.write_golden(str(d1), seed=7)
    aot.write_golden(str(d2), seed=7)
    assert (d1 / "golden.json").read_text() == (d2 / "golden.json").read_text()
    g = json.loads((d1 / "golden.json").read_text())
    assert g["dct_cases"] and g["zigzag"] and g["afd_cases"]
    for case in g["dct_cases"]:
        assert case["idct_roundtrip_max_err"] < 1e-3


def test_server_step_signature_order(tmp_path):
    """The Rust trainer slices server_step outputs positionally:
    [sp' x n][sm' x n][loss][correct][gact][gact_dct]."""
    cfg = model.ModelConfig("tiny2", 1, 8, 3, 4, 4)
    section = aot.lower_preset(cfg, str(tmp_path))
    ss = section["artifacts"]["server_step"]
    n = len(model.server_specs(cfg))
    outs = ss["outputs"]
    assert len(outs) == 2 * n + 4
    assert outs[2 * n]["shape"] == []          # loss scalar
    assert outs[2 * n + 1]["dtype"] == "int32"  # correct count
    assert outs[2 * n + 2]["shape"] == section["activation_shape"]  # gact
    assert outs[2 * n + 3]["shape"] == section["activation_shape"]  # gact_dct
