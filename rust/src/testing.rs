//! Mini property-testing framework (no `proptest` offline).
//!
//! Runs a property over many seeded-random cases and reports the first
//! failing seed so the case reproduces exactly. Used by codec/coordinator
//! invariant tests:
//!
//! ```
//! use slfac::testing::{prop, Gen};
//! prop("abs is non-negative", 200, |g: &mut Gen| {
//!     let x = g.f32_in(-100.0, 100.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::rng::Pcg32;

/// Random case generator handed to properties.
pub struct Gen {
    rng: Pcg32,
    /// Case index (0-based) — handy for size scaling.
    pub case: usize,
}

impl Gen {
    /// Underlying RNG access.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    /// Standard normal f32.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Vec of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Vec of normals with occasional large outliers — stresses quantizers.
    pub fn spiky_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let base = self.rng.normal();
                if self.rng.uniform() < 0.02 {
                    base * 100.0
                } else {
                    base
                }
            })
            .collect()
    }

    /// A random small (B, C, M, N) activation-like shape.
    pub fn bchw_shape(&mut self) -> [usize; 4] {
        [
            self.usize_in(1, 4),
            self.usize_in(1, 8),
            self.usize_in(1, 16),
            self.usize_in(1, 16),
        ]
    }

    /// Random tensor of the given shape, N(0, std).
    pub fn tensor(&mut self, shape: &[usize], std: f32) -> crate::tensor::Tensor {
        crate::tensor::Tensor::randn(shape, std, &mut self.rng)
    }

    /// Pick an element uniformly from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f32) -> bool {
        self.rng.uniform() < p
    }
}

/// Base seed: override with `SLFAC_PROP_SEED` to replay a failure campaign.
fn base_seed() -> u64 {
    std::env::var("SLFAC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Run `cases` random cases of a property. On panic, re-raises with the
/// failing case seed in the message.
pub fn prop<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Pcg32::seeded(seed),
                case,
            };
            f(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 set SLFAC_PROP_SEED={base} to replay): {msg}"
            );
        }
    }
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol,
            "index {i}: {x} vs {y} (atol {atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        prop("counter", 25, |_g| {
            COUNT.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(COUNT.load(Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn prop_reports_failure_with_seed() {
        prop("always-fails", 10, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges() {
        prop("gen ranges", 50, |g| {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let s = g.bchw_shape();
            assert!(s.iter().all(|&d| d >= 1));
        });
    }

    #[test]
    fn assert_close_passes_and_lengths_checked() {
        assert_close(&[1.0, 2.0], &[1.0001, 1.9999], 1e-3);
    }
}
