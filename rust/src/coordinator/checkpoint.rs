//! Crash-durable training checkpoints with bit-identical resume.
//!
//! A checkpoint snapshots everything the trainer needs to continue from a
//! round boundary exactly as if the process had never stopped: the
//! aggregated client weights/momenta, server weights/momenta, every
//! device's loader/link/codec RNG state, the completed-round counter, the
//! full [`RoundMetrics`] history (cum-bytes rebuilt on import through
//! [`crate::coordinator::TrainingHistory::push`]), and the [`CommStats`]
//! snapshot. Per-round draws (client sampling, fault plans) are pure
//! functions of `(seed, round)` and need no state at all — only the
//! *stateful* streams (loader shuffles, link jitter, codec sampling) are
//! serialized, which is what makes resume bit-identical.
//!
//! Durability discipline:
//! - **Atomic writes** — [`write_atomic`] writes to `<path>.tmp`, fsyncs,
//!   then renames into place, so a crash mid-write never leaves a torn
//!   file under the final name.
//! - **Fail closed on load** — the same discipline as
//!   `Payload::from_bytes`: a length-prefixed binary layout with a magic,
//!   a version byte, the config fingerprint, the body length, and an
//!   FNV-1a/[`crate::rng::mix64`] checksum over the body. Torn, corrupt,
//!   or foreign-fingerprint files are rejected with named errors; nothing
//!   is ever partially applied.
//! - **Keep-last-k retention** — [`save`] prunes all but the newest
//!   [`KEEP_LAST`] `ckpt_round_*.bin` files (zero-padded round numbers, so
//!   lexical order is numeric order and [`latest`] is a directory scan).

use crate::config::ExperimentConfig;
use crate::data::LoaderState;
use crate::json::{fnv1a64, Json};
use crate::rng::mix64;
use crate::runtime::HostTensor;
use crate::transport::{CommStats, LinkState};
use anyhow::{bail, Context, Result};
use std::io::Write as _;

use super::metrics::RoundMetrics;

/// File magic: "SLCK" (SL-FAC checkpoint).
const MAGIC: [u8; 4] = *b"SLCK";
/// Binary layout version. Bumped on any layout change; old files are
/// rejected with a named error rather than misparsed.
const VERSION: u8 = 1;
/// Header bytes: magic + version + config fingerprint + body length +
/// body checksum.
const HEADER_LEN: usize = 4 + 1 + 8 + 8 + 8;
/// Retention policy: [`save`] keeps this many newest checkpoints.
pub const KEEP_LAST: usize = 3;

/// Write `bytes` to `path` atomically: create parent dirs, write
/// `<path>.tmp`, fsync, rename into place. The rename is atomic on POSIX
/// filesystems, so readers see either the old file or the complete new
/// one — never a torn write. Shared by checkpoints and
/// [`crate::coordinator::TrainingHistory::write_csv`].
pub fn write_atomic(path: &str, bytes: &[u8]) -> std::io::Result<()> {
    let p = std::path::Path::new(path);
    if let Some(parent) = p.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// One device's checkpointed state: everything mutable a [`DeviceCtx`]
/// carries across rounds (scratch buffers are fully overwritten before
/// every read and are not state).
///
/// [`DeviceCtx`]: crate::coordinator::Trainer
#[derive(Debug, Clone)]
pub struct DeviceState {
    /// Batch loader (shuffled order, cursor, epoch count, reshuffle RNG).
    pub loader: LoaderState,
    /// Link counters + jitter RNG.
    pub link: LinkState,
    /// Codec sampling stream `(state, inc)`.
    pub codec_rng: (u64, u64),
}

/// Parameter + momentum tensors for one side of the split model.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// Parameter tensors.
    pub params: Vec<HostTensor>,
    /// Momentum tensors (same shapes as `params`).
    pub momentum: Vec<HostTensor>,
}

/// Full training state at a round boundary.
#[derive(Debug, Clone)]
pub struct CheckpointState {
    /// The run's serialized `ExperimentConfig` (for the named-key diff in
    /// mismatch errors — the binary header carries only the fingerprint).
    pub config_json: String,
    /// `ExperimentConfig::fingerprint()` of the run that wrote this file.
    pub config_fp: u64,
    /// Rounds completed when the snapshot was taken; resume continues at
    /// `completed_rounds + 1`.
    pub completed_rounds: u64,
    /// Accumulated per-round communication makespan at the boundary.
    pub makespan_total_s: f64,
    /// Per-device state, in ascending device-id order.
    pub devices: Vec<DeviceState>,
    /// Aggregated client weights/momenta.
    pub client: ModelState,
    /// Server weights/momenta.
    pub server: ModelState,
    /// Per-round metrics for every completed round, in order.
    pub history: Vec<RoundMetrics>,
    /// Communication stats at the boundary (informational — the trainer
    /// rebuilds run-level stats from the restored links; kept so external
    /// tools can read progress without replaying).
    pub comm: CommStats,
}

// ---------------------------------------------------------------------
// little-endian body writer/reader (fail-closed on truncation)
// ---------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
    fn tensor(&mut self, t: &HostTensor) -> Result<()> {
        let data = t.as_f32().context("checkpoint tensors must be f32")?;
        self.u64(t.dims().len() as u64);
        for &d in t.dims() {
            self.u64(d as u64);
        }
        for &v in data {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        Ok(())
    }
    fn tensors(&mut self, ts: &[HostTensor]) -> Result<()> {
        self.u64(ts.len() as u64);
        for t in ts {
            self.tensor(t)?;
        }
        Ok(())
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .context("checkpoint body truncated")?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// Length-prefixed count, sanity-bounded so a corrupted length can't
    /// drive a giant allocation before the truncation check fires.
    fn count(&mut self, what: &str) -> Result<usize> {
        let n = self.u64()?;
        anyhow::ensure!(
            (n as usize) <= self.buf.len(),
            "checkpoint body: implausible {what} count {n}"
        );
        Ok(n as usize)
    }
    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.count("byte-run")?;
        self.take(n)
    }
    fn tensor(&mut self) -> Result<HostTensor> {
        let rank = self.count("tensor rank")?;
        anyhow::ensure!(rank <= 8, "checkpoint body: implausible tensor rank {rank}");
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.count("tensor dim")?);
        }
        let numel: usize = dims.iter().product();
        anyhow::ensure!(
            numel.checked_mul(4).is_some_and(|b| self.pos + b <= self.buf.len()),
            "checkpoint body truncated inside a tensor"
        );
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(f32::from_bits(u32::from_le_bytes(
                self.take(4)?.try_into().unwrap(),
            )));
        }
        Ok(HostTensor::f32(&dims, data))
    }
    fn tensors(&mut self) -> Result<Vec<HostTensor>> {
        let n = self.count("tensor")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.tensor()?);
        }
        Ok(out)
    }
}

/// Body checksum: FNV-1a 64 finalized through the SplitMix64 mixer (a
/// single flipped bit avalanches across the whole word).
fn checksum(body: &[u8]) -> u64 {
    mix64(fnv1a64(body))
}

impl CheckpointState {
    /// Serialize to the length-prefixed, checksummed binary layout.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut w = Writer::new();
        w.bytes(self.config_json.as_bytes());
        w.u64(self.completed_rounds);
        w.f64(self.makespan_total_s);
        w.u64(self.devices.len() as u64);
        for d in &self.devices {
            w.u64(d.loader.indices.len() as u64);
            for &i in &d.loader.indices {
                w.u64(i as u64);
            }
            w.u64(d.loader.cursor as u64);
            w.u64(d.loader.epochs as u64);
            w.u64(d.loader.batch_size as u64);
            w.u64(d.loader.rng.0);
            w.u64(d.loader.rng.1);
            w.u64(d.link.rng.0);
            w.u64(d.link.rng.1);
            w.u64(d.link.uplink_bytes);
            w.u64(d.link.downlink_bytes);
            w.f64(d.link.busy_s);
            w.u64(d.link.transfers);
            w.u64(d.codec_rng.0);
            w.u64(d.codec_rng.1);
        }
        w.tensors(&self.client.params)?;
        w.tensors(&self.client.momentum)?;
        w.tensors(&self.server.params)?;
        w.tensors(&self.server.momentum)?;
        w.u64(self.history.len() as u64);
        for m in &self.history {
            w.u64(m.round as u64);
            w.f64(m.train_loss);
            w.f64(m.train_acc);
            w.f64(m.test_acc);
            w.f64(m.test_loss);
            w.u64(m.uplink_bytes);
            w.u64(m.downlink_bytes);
            w.f64(m.comm_time_s);
            w.f64(m.sim_time_s);
            w.f64(m.queue_wait_s);
            w.u64(m.dropped_devices);
            w.u64(m.sampled_devices);
            w.u64(m.retransmits);
            w.u64(m.lost_bytes);
            w.u64(m.corrupt_payloads);
            w.f64(m.recovery_wait_s);
            w.u8(m.skipped as u8);
            w.f64(m.wall_time_s);
        }
        w.u64(self.comm.uplink_bytes);
        w.u64(self.comm.downlink_bytes);
        w.f64(self.comm.makespan_s);
        w.f64(self.comm.total_busy_s);

        let body = w.buf;
        let mut out = Vec::with_capacity(HEADER_LEN + body.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&self.config_fp.to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&checksum(&body).to_le_bytes());
        out.extend_from_slice(&body);
        Ok(out)
    }

    /// Parse a checkpoint, failing closed on anything short of a complete,
    /// checksummed, current-version file: short headers, wrong magic,
    /// unknown versions, truncated (torn) bodies, and checksum mismatches
    /// all produce named errors and no partial state.
    pub fn from_bytes(bytes: &[u8]) -> Result<CheckpointState> {
        if bytes.len() < HEADER_LEN {
            bail!(
                "checkpoint header truncated: {} bytes < {HEADER_LEN}",
                bytes.len()
            );
        }
        if bytes[..4] != MAGIC {
            bail!("not a checkpoint file (bad magic)");
        }
        let version = bytes[4];
        if version != VERSION {
            bail!("unsupported checkpoint version {version} (expected {VERSION})");
        }
        let config_fp = u64::from_le_bytes(bytes[5..13].try_into().unwrap());
        let body_len = u64::from_le_bytes(bytes[13..21].try_into().unwrap()) as usize;
        let stored_sum = u64::from_le_bytes(bytes[21..29].try_into().unwrap());
        let body = &bytes[HEADER_LEN..];
        if body.len() != body_len {
            bail!(
                "checkpoint body torn: header says {body_len} bytes, file has {}",
                body.len()
            );
        }
        let got_sum = checksum(body);
        if got_sum != stored_sum {
            bail!(
                "checkpoint checksum mismatch: stored {stored_sum:#018x}, \
                 computed {got_sum:#018x} — file is corrupt"
            );
        }

        let mut r = Reader::new(body);
        let config_json = String::from_utf8(r.bytes()?.to_vec())
            .context("checkpoint config JSON is not UTF-8")?;
        let completed_rounds = r.u64()?;
        let makespan_total_s = r.f64()?;
        let n_devices = r.count("device")?;
        let mut devices = Vec::with_capacity(n_devices);
        for _ in 0..n_devices {
            let n_idx = r.count("shard index")?;
            let mut indices = Vec::with_capacity(n_idx);
            for _ in 0..n_idx {
                indices.push(r.u64()? as usize);
            }
            let loader = LoaderState {
                indices,
                cursor: r.u64()? as usize,
                epochs: r.u64()? as usize,
                batch_size: r.u64()? as usize,
                rng: (r.u64()?, r.u64()?),
            };
            let link = LinkState {
                rng: (r.u64()?, r.u64()?),
                uplink_bytes: r.u64()?,
                downlink_bytes: r.u64()?,
                busy_s: r.f64()?,
                transfers: r.u64()?,
            };
            let codec_rng = (r.u64()?, r.u64()?);
            devices.push(DeviceState {
                loader,
                link,
                codec_rng,
            });
        }
        let client = ModelState {
            params: r.tensors()?,
            momentum: r.tensors()?,
        };
        let server = ModelState {
            params: r.tensors()?,
            momentum: r.tensors()?,
        };
        let n_rounds = r.count("history round")?;
        let mut history = Vec::with_capacity(n_rounds);
        for _ in 0..n_rounds {
            history.push(RoundMetrics {
                round: r.u64()? as usize,
                train_loss: r.f64()?,
                train_acc: r.f64()?,
                test_acc: r.f64()?,
                test_loss: r.f64()?,
                uplink_bytes: r.u64()?,
                downlink_bytes: r.u64()?,
                comm_time_s: r.f64()?,
                sim_time_s: r.f64()?,
                queue_wait_s: r.f64()?,
                dropped_devices: r.u64()?,
                sampled_devices: r.u64()?,
                retransmits: r.u64()?,
                lost_bytes: r.u64()?,
                corrupt_payloads: r.u64()?,
                recovery_wait_s: r.f64()?,
                skipped: r.u8()? != 0,
                wall_time_s: r.f64()?,
            });
        }
        let comm = CommStats {
            uplink_bytes: r.u64()?,
            downlink_bytes: r.u64()?,
            makespan_s: r.f64()?,
            total_busy_s: r.f64()?,
        };
        if r.pos != body.len() {
            bail!(
                "checkpoint body has {} trailing bytes after the last section",
                body.len() - r.pos
            );
        }
        Ok(CheckpointState {
            config_json,
            config_fp,
            completed_rounds,
            makespan_total_s,
            devices,
            client,
            server,
            history,
            comm,
        })
    }
}

/// Checkpoint filename for a round boundary. Zero-padded so lexical order
/// equals numeric order (what [`latest`] relies on).
fn file_name(round: u64) -> String {
    format!("ckpt_round_{round:08}.bin")
}

/// Atomically write `state` into `dir` and prune to the newest
/// `keep_last` checkpoints. Returns the written path.
pub fn save(dir: &str, state: &CheckpointState, keep_last: usize) -> Result<String> {
    let path = format!("{dir}/{}", file_name(state.completed_rounds));
    let bytes = state.to_bytes()?;
    write_atomic(&path, &bytes)
        .with_context(|| format!("writing checkpoint {path}"))?;
    // retention: drop the oldest files beyond keep_last (the just-written
    // file is always newest — resume takes the highest round number)
    let mut names = list_checkpoints(dir)?;
    if names.len() > keep_last.max(1) {
        let n_drop = names.len() - keep_last.max(1);
        names.truncate(n_drop);
        for old in names {
            let _ = std::fs::remove_file(format!("{dir}/{old}"));
        }
    }
    Ok(path)
}

/// Checkpoint file names in `dir`, ascending (oldest first). Missing dir
/// reads as empty.
fn list_checkpoints(dir: &str) -> Result<Vec<String>> {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading checkpoint dir {dir}")),
    };
    let mut names: Vec<String> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("ckpt_round_") && n.ends_with(".bin"))
        .collect();
    names.sort();
    Ok(names)
}

/// Path of the newest checkpoint in `dir`, or `None` when the directory
/// is empty or missing (a fresh start, not an error — first runs resume
/// from nothing).
pub fn latest(dir: &str) -> Result<Option<String>> {
    Ok(list_checkpoints(dir)?.pop().map(|n| format!("{dir}/{n}")))
}

/// Load and parse one checkpoint file (fail-closed; see
/// [`CheckpointState::from_bytes`]).
pub fn load(path: &str) -> Result<CheckpointState> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading checkpoint {path}"))?;
    CheckpointState::from_bytes(&bytes)
        .with_context(|| format!("parsing checkpoint {path}"))
}

/// Build the named-key diff error for a resume against a different
/// config: every serialized key whose value differs between the
/// checkpoint's stored config and the current one is listed with both
/// values, so the operator sees exactly which hyperparameter changed.
pub fn config_mismatch_error(stored_json: &str, current: &ExperimentConfig) -> anyhow::Error {
    let cur = current.to_json();
    let Ok(stored) = Json::parse(stored_json) else {
        return anyhow::anyhow!(
            "checkpoint was written by a different config (fingerprint mismatch), \
             and its stored config JSON does not parse"
        );
    };
    let empty = std::collections::BTreeMap::new();
    let so = stored.as_obj().unwrap_or(&empty);
    let co = cur.as_obj().unwrap_or(&empty);
    let mut diffs = Vec::new();
    for key in so.keys().chain(co.keys()) {
        if diffs.iter().any(|d: &String| d.starts_with(&format!("{key}:"))) {
            continue;
        }
        let sv = so.get(key).map(|v| v.to_string()).unwrap_or_else(|| "<absent>".into());
        let cv = co.get(key).map(|v| v.to_string()).unwrap_or_else(|| "<absent>".into());
        if sv != cv {
            diffs.push(format!("{key}: checkpoint {sv} vs current {cv}"));
        }
    }
    anyhow::anyhow!(
        "cannot resume: checkpoint was written by a different config — {}",
        if diffs.is_empty() {
            "fingerprint differs but no serialized key does (stale fingerprint?)".to_string()
        } else {
            diffs.join("; ")
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> CheckpointState {
        let metric = RoundMetrics {
            round: 1,
            train_loss: 1.25,
            train_acc: 0.5,
            test_acc: 0.5,
            test_loss: 1.5,
            uplink_bytes: 100,
            downlink_bytes: 50,
            comm_time_s: 0.1,
            sim_time_s: 0.2,
            queue_wait_s: 0.0,
            dropped_devices: 0,
            sampled_devices: 2,
            retransmits: 1,
            lost_bytes: 64,
            corrupt_payloads: 0,
            recovery_wait_s: 0.0,
            skipped: false,
            wall_time_s: 0.01,
        };
        CheckpointState {
            config_json: "{\"seed\": 7}".into(),
            config_fp: 0xDEAD_BEEF_1234_5678,
            completed_rounds: 1,
            makespan_total_s: 0.375,
            devices: vec![DeviceState {
                loader: LoaderState {
                    indices: vec![3, 1, 4, 1, 5],
                    cursor: 2,
                    epochs: 1,
                    batch_size: 2,
                    rng: (0x1111, 0x2223),
                },
                link: LinkState {
                    rng: (0x3333, 0x4445),
                    uplink_bytes: 1000,
                    downlink_bytes: 500,
                    busy_s: 1.5,
                    transfers: 4,
                },
                codec_rng: (0x5555, 0x6667),
            }],
            client: ModelState {
                params: vec![HostTensor::f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -0.25])],
                momentum: vec![HostTensor::f32(&[2, 3], vec![0.0; 6])],
            },
            server: ModelState {
                params: vec![HostTensor::f32(&[3, 2], vec![0.5; 6])],
                momentum: vec![HostTensor::f32(&[3, 2], vec![0.125; 6])],
            },
            history: vec![metric],
            comm: CommStats {
                uplink_bytes: 1000,
                downlink_bytes: 500,
                makespan_s: 0.375,
                total_busy_s: 1.5,
            },
        }
    }

    fn tensors_bit_eq(a: &[HostTensor], b: &[HostTensor]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.dims() == y.dims()
                    && x.as_f32()
                        .unwrap()
                        .iter()
                        .zip(y.as_f32().unwrap())
                        .all(|(p, q)| p.to_bits() == q.to_bits())
            })
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let s = state();
        let bytes = s.to_bytes().unwrap();
        let t = CheckpointState::from_bytes(&bytes).unwrap();
        assert_eq!(t.config_json, s.config_json);
        assert_eq!(t.config_fp, s.config_fp);
        assert_eq!(t.completed_rounds, s.completed_rounds);
        assert_eq!(t.makespan_total_s.to_bits(), s.makespan_total_s.to_bits());
        assert_eq!(t.devices.len(), 1);
        assert_eq!(t.devices[0].loader.indices, s.devices[0].loader.indices);
        assert_eq!(t.devices[0].loader.cursor, 2);
        assert_eq!(t.devices[0].loader.rng, (0x1111, 0x2223));
        assert_eq!(t.devices[0].link.rng, (0x3333, 0x4445));
        assert_eq!(t.devices[0].link.busy_s.to_bits(), 1.5f64.to_bits());
        assert_eq!(t.devices[0].codec_rng, (0x5555, 0x6667));
        assert!(tensors_bit_eq(&t.client.params, &s.client.params));
        assert!(tensors_bit_eq(&t.client.momentum, &s.client.momentum));
        assert!(tensors_bit_eq(&t.server.params, &s.server.params));
        assert!(tensors_bit_eq(&t.server.momentum, &s.server.momentum));
        assert_eq!(t.history.len(), 1);
        assert!(t.history[0].bit_eq(&s.history[0]));
        assert_eq!(t.history[0].wall_time_s.to_bits(), s.history[0].wall_time_s.to_bits());
        assert!(t.comm.bit_eq(&s.comm));
    }

    #[test]
    fn torn_and_corrupt_files_fail_closed_with_named_errors() {
        let bytes = state().to_bytes().unwrap();
        // header truncation
        let err = CheckpointState::from_bytes(&bytes[..10]).unwrap_err();
        assert!(err.to_string().contains("header truncated"), "{err}");
        // torn body (crash mid-write without the atomic writer)
        let err = CheckpointState::from_bytes(&bytes[..bytes.len() - 7]).unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
        // single flipped body bit → checksum mismatch
        let mut bad = bytes.clone();
        let mid = HEADER_LEN + (bad.len() - HEADER_LEN) / 2;
        bad[mid] ^= 0x10;
        let err = CheckpointState::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // wrong magic
        let mut foreign = bytes.clone();
        foreign[0] = b'X';
        let err = CheckpointState::from_bytes(&foreign).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        // future version
        let mut vnext = bytes;
        vnext[4] = VERSION + 1;
        let err = CheckpointState::from_bytes(&vnext).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn save_prunes_to_keep_last_and_latest_finds_newest() {
        let dir = format!(
            "{}/slfac_ckpt_unit_{}",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest(&dir).unwrap().is_none(), "missing dir reads empty");
        let mut s = state();
        for round in 1..=6u64 {
            s.completed_rounds = round;
            save(&dir, &s, KEEP_LAST).unwrap();
        }
        let names = list_checkpoints(&dir).unwrap();
        assert_eq!(names.len(), KEEP_LAST, "retention prunes to keep-last");
        assert_eq!(names.last().unwrap(), &file_name(6));
        let newest = latest(&dir).unwrap().unwrap();
        assert!(newest.ends_with(&file_name(6)), "{newest}");
        let loaded = load(&newest).unwrap();
        assert_eq!(loaded.completed_rounds, 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_atomic_leaves_no_tmp_behind() {
        let dir = format!(
            "{}/slfac_atomic_unit_{}",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let _ = std::fs::remove_dir_all(&dir);
        let path = format!("{dir}/nested/out.csv");
        write_atomic(&path, b"a,b\n1,2\n").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"a,b\n1,2\n");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        // overwrite is atomic too
        write_atomic(&path, b"new").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"new");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_mismatch_error_names_differing_keys() {
        let a = ExperimentConfig {
            seed: 7,
            ..Default::default()
        };
        let stored = a.to_json().to_string();
        let b = ExperimentConfig {
            seed: 8,
            lr: a.lr * 2.0,
            ..Default::default()
        };
        let err = config_mismatch_error(&stored, &b).to_string();
        assert!(err.contains("seed"), "{err}");
        assert!(err.contains("lr"), "{err}");
        assert!(err.contains("cannot resume"), "{err}");
        // unparseable stored JSON still produces a clear error
        let err = config_mismatch_error("not json", &b).to_string();
        assert!(err.contains("does not parse"), "{err}");
    }
}
