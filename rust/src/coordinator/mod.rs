//! L3 coordinator: the split-learning system.
//!
//! * [`engine`] — the sharded, thread-parallel round engine: a scoped
//!   worker pool that splits device state into contiguous shards and runs
//!   the embarrassingly-parallel phases concurrently, sized by the
//!   `workers` config knob (`0` = one worker per CPU).
//! * [`trainer`] — the training orchestrator: device workers, lockstep
//!   round phases, SplitFed client-weight aggregation, sequential-SL mode,
//!   evaluation, and the wire path (codec ↔ network simulator ↔ runtime).
//! * [`aggregate`] — FedAvg over flat parameter lists (parameter-sharded,
//!   order-stable).
//! * [`metrics`] — per-round metrics, history, CSV output, and bit-exact
//!   comparison helpers for the differential determinism tests.
//!
//! One communication round (parallel mode) runs in three deterministic
//! phases per local batch:
//!
//! 1. **fan-out (device-parallel)** — every device runs `client_fwd`
//!    through the executor, compresses the smashed data (L3 codec, worker
//!    thread), and "uplinks" it through its simulated link;
//! 2. **server (barrier; serialized in device-id order)** — decompress
//!    (+ `idct` for frequency codecs), `server_step` (updates server
//!    params, returns the activation gradient in both domains), compress
//!    the gradient, "downlink" it;
//! 3. **fan-in (device-parallel)** — every device decompresses its
//!    gradient and runs `client_step`.
//!
//! # Determinism
//!
//! A run is a function of its seed alone — never of the worker count or
//! thread scheduling. Three mechanisms enforce this (and the
//! `parallel_determinism` integration test checks it bit-for-bit):
//!
//! * every device owns **derived RNG streams** (`rng::derive_seed`) for
//!   its loader, link jitter, and codec sampling;
//! * phases 1/3 share no mutable state across devices; phase 2 and
//!   round-end aggregation are barriers executed in device-id order;
//! * all floating-point reductions (loss sums, comm stats, FedAvg) fold
//!   in device-id order after the barrier — order-stable, hence
//!   bit-stable.

pub mod aggregate;
pub mod engine;
pub mod metrics;
pub mod trainer;

pub use aggregate::{fedavg, fedavg_sharded};
pub use engine::{effective_workers, run_sharded};
pub use metrics::{RoundMetrics, TrainingHistory};
pub use trainer::{TrainOutcome, Trainer};
