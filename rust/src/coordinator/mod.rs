//! L3 coordinator: the split-learning system.
//!
//! * [`trainer`] — the training orchestrator: device workers, lockstep
//!   round phases, SplitFed client-weight aggregation, sequential-SL mode,
//!   evaluation, and the wire path (codec ↔ network simulator ↔ runtime).
//! * [`aggregate`] — FedAvg over flat parameter lists.
//! * [`metrics`] — per-round metrics, history, CSV output.
//!
//! One communication round (parallel mode) runs in three deterministic
//! phases per local batch:
//!
//! 1. **fan-out (parallel)** — every device runs `client_fwd` through the
//!    executor, compresses the smashed data (L3 codec, device thread), and
//!    "uplinks" it through its simulated link;
//! 2. **server (serialized, device order)** — decompress (+ `idct` for
//!    frequency codecs), `server_step` (updates server params, returns the
//!    activation gradient in both domains), compress the gradient,
//!    "downlink" it;
//! 3. **fan-in (parallel)** — every device decompresses its gradient and
//!    runs `client_step`.
//!
//! Phase 2's fixed ordering makes runs bit-reproducible while codec work
//! still parallelizes across device threads.

pub mod aggregate;
pub mod metrics;
pub mod trainer;

pub use aggregate::fedavg;
pub use metrics::{RoundMetrics, TrainingHistory};
pub use trainer::{TrainOutcome, Trainer};
