//! L3 coordinator: the split-learning system.
//!
//! * [`engine`] — the sharded, thread-parallel round engine: a scoped
//!   worker pool that splits device state into contiguous shards and runs
//!   the embarrassingly-parallel phases concurrently, sized by the
//!   `workers` config knob (`0` = one worker per CPU).
//! * [`trainer`] — the training orchestrator: device workers, the wire
//!   path (codec ↔ transport ↔ runtime), SplitFed client-weight
//!   aggregation (straggler-aware), sequential-SL mode, evaluation. Round
//!   *control flow* is delegated to the [`crate::transport`] schedulers
//!   through the trainer's `RoundOps` implementation.
//! * [`aggregate`] — FedAvg over flat parameter lists (parameter-sharded,
//!   order-stable; dropped stragglers carry zero weight).
//! * [`metrics`] — per-round metrics, history, CSV output, and bit-exact
//!   comparison helpers for the differential determinism tests.
//! * [`checkpoint`] — crash-durable round-boundary snapshots (atomic
//!   write + checksummed binary layout + keep-last-k retention) behind
//!   the trainer's `checkpoint_every`/`resume_latest` surface; resume is
//!   bit-identical to never having crashed.
//!
//! One communication round under the **sync scheduler** (the default)
//! runs in three deterministic phases per local batch:
//!
//! 1. **fan-out (device-parallel)** — every device runs `client_fwd`
//!    through the executor, compresses the smashed data (L3 codec, worker
//!    thread), and "uplinks" it through its simulated link;
//! 2. **server (barrier; serialized in device-id order)** — decompress
//!    (+ `idct` for frequency codecs), `server_step` (updates server
//!    params, returns the activation gradient in both domains), compress
//!    the gradient, "downlink" it;
//! 3. **fan-in (device-parallel)** — every device decompresses its
//!    gradient and runs `client_step`.
//!
//! Under the **async scheduler** (`scheduler = "async"`) the barrier
//! disappears: devices pipeline their local steps independently on the
//! simulated clock, the server consumes uplinks in arrival order, and a
//! straggler policy (`wait-all` / `deadline-drop` / `quorum`) decides
//! when the round closes and which devices are dropped from that round's
//! aggregation. See [`crate::transport`] and `ARCHITECTURE.md`.
//!
//! Both schedulers run under the **contention model**: the server is a
//! serial busy resource (`server_service_s` per batch — uplinks queue,
//! surfaced as `RoundMetrics::queue_wait_s`), and with
//! `uplink = "shared"` concurrent uplinks split one pipe's capacity
//! fairly. **Client sampling** (`sample_fraction` / `sample_k`) picks a
//! per-round participant subset from a seed-derived stream; unsampled
//! devices transfer nothing, carry zero FedAvg weight, and rejoin from
//! the aggregate next round.
//!
//! # Determinism
//!
//! A run is a function of its seed alone — never of the worker count or
//! thread scheduling. Four mechanisms enforce this (and the
//! `parallel_determinism` integration test checks it bit-for-bit, for
//! both schedulers):
//!
//! * every device owns **derived RNG streams** (`rng::derive_seed`) for
//!   its loader, link jitter, and codec sampling;
//! * device-parallel phases share no mutable state across devices; server
//!   steps serialize (device-id order under sync, simulated-arrival order
//!   under async);
//! * all floating-point reductions (loss sums, comm stats, FedAvg) fold
//!   in a fixed order — device-id order for barriers, event order for
//!   async — order-stable, hence bit-stable;
//! * everything the async scheduler decides (server order, batches,
//!   drops) derives from the `(sim_time, seq)` event order, a pure
//!   function of the configuration ([`crate::transport::event`]).

pub mod aggregate;
pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod trainer;

pub use aggregate::{fedavg, fedavg_sharded};
pub use checkpoint::{CheckpointState, DeviceState, ModelState};
pub use engine::{effective_workers, run_sharded, run_sharded_indexed};
pub use metrics::{RoundMetrics, StreamFold, TrainingHistory};
pub use trainer::{TrainOutcome, Trainer};
