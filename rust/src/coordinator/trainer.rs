//! The training orchestrator: devices, rounds, the wire path, aggregation,
//! evaluation. See the module docs in [`super`] for the phase structure and
//! [`super::engine`] for the worker pool + determinism contract.

use crate::codec::{self, ActivationCodec, Payload};
use crate::config::{DatasetKind, ExperimentConfig, Partition, SyncMode};
use crate::data::{
    partition_dirichlet, partition_iid, synthetic, BatchLoader, Dataset,
};
use crate::net::{CommStats, Direction, Link};
use crate::rng::{derive_seed, stream, Pcg32};
use crate::runtime::{ExecutorHandle, ExecutorStats, HostTensor};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::engine;
use super::metrics::{RoundMetrics, TrainingHistory};

/// Per-device state owned by the trainer across rounds. Everything a
/// worker thread needs for phases 1 and 3 lives here (own loader + link +
/// codec RNG stream), which is what makes the sharded engine's
/// no-shared-mutable-state determinism argument hold — see
/// [`super::engine`].
struct DeviceCtx {
    id: usize,
    loader: BatchLoader,
    link: Link,
    /// Per-device codec sampling stream (randomized codecs draw from this
    /// through [`ActivationCodec::compress_with_rng`], so payloads do not
    /// depend on cross-device scheduling).
    codec_rng: Pcg32,
    /// Device's client-side parameters (SplitFed: reset to the aggregate at
    /// round start; sequential: handed off device-to-device).
    cp: Vec<HostTensor>,
    /// Device's client-side momenta.
    cm: Vec<HostTensor>,
    shard_len: usize,
    /// Set by phase 1, consumed by phases 2–3.
    pending: Option<StepCtx>,
    /// Link busy time at round start (for per-round makespan).
    busy_at_round_start: f64,
}

/// One in-flight batch between phases.
struct StepCtx {
    x: HostTensor,
    y: HostTensor,
    uplink: Payload,
    /// Filled by phase 2.
    grad: Option<GradMsg>,
}

/// Gradient travelling server→device.
enum GradMsg {
    /// Compressed (codec wire path).
    Compressed(Payload),
    /// Raw tensor (when `compress_gradients = false`).
    Raw(HostTensor),
}

/// Final result of a training run.
pub struct TrainOutcome {
    /// Per-round metrics.
    pub history: TrainingHistory,
    /// Aggregate communication statistics.
    pub comm: CommStats,
    /// Executor-side statistics (per-artifact exec counts/times).
    pub exec_stats: ExecutorStats,
}

/// The split-learning trainer (one experiment run).
pub struct Trainer {
    cfg: ExperimentConfig,
    exec: ExecutorHandle,
    codec: Arc<dyn ActivationCodec>,
    preset: String,
    train: Dataset,
    test: Dataset,
    devices: Vec<DeviceCtx>,
    /// Server-side parameters + momenta (updated in phase 2 only; the Mutex
    /// documents the sharing discipline for future parallel-server modes).
    server: Mutex<(Vec<HostTensor>, Vec<HostTensor>)>,
    /// Aggregated client params/momenta between rounds.
    client: (Vec<HostTensor>, Vec<HostTensor>),
    n_client_params: usize,
}

impl Trainer {
    /// Build a trainer: datasets, partition, executor, initial parameters.
    pub fn new(cfg: ExperimentConfig, exec: ExecutorHandle) -> Result<Self> {
        cfg.validate()?;
        let preset = cfg.dataset.name().to_string();
        let manifest = crate::runtime::ArtifactManifest::load(&cfg.artifacts_dir)?;
        let pm = manifest.preset(&preset)?.clone();
        anyhow::ensure!(
            pm.batch_size == cfg.batch_size,
            "config batch_size {} != artifact batch_size {} — re-run `make artifacts`",
            cfg.batch_size,
            pm.batch_size
        );

        let spec = synthetic::DatasetSpec {
            train_samples: cfg.train_samples,
            test_samples: cfg.test_samples,
            noise: cfg.noise,
            seed: cfg.seed,
        };
        let (train, test) = match cfg.dataset {
            DatasetKind::Mnist => synthetic::mnist_like(&spec),
            DatasetKind::Ham => synthetic::ham_like(&spec),
        };

        let parts = match cfg.partition {
            Partition::Iid => partition_iid(&train, cfg.devices, cfg.seed),
            Partition::Dirichlet(beta) => {
                partition_dirichlet(&train, cfg.devices, beta, cfg.seed)
            }
        };
        crate::info!(
            "partition: {} devices, skew {:.3}",
            cfg.devices,
            crate::data::partition::label_skew(&train, &parts)
        );

        // initial parameters from the init artifact
        let init_out = exec.execute(&preset, "init", vec![])?;
        let n_client = pm.client_params.len();
        let n_server = pm.server_params.len();
        anyhow::ensure!(
            init_out.len() == n_client + n_server,
            "init artifact returned {} tensors, manifest says {}",
            init_out.len(),
            n_client + n_server
        );
        let mut it = init_out.into_iter();
        let cp: Vec<HostTensor> = (&mut it).take(n_client).collect();
        let sp: Vec<HostTensor> = it.collect();
        let zeros =
            |ps: &[HostTensor]| -> Vec<HostTensor> {
                ps.iter()
                    .map(|p| HostTensor::f32(p.dims(), vec![0.0; p.numel()]))
                    .collect()
            };
        let cm = zeros(&cp);
        let sm = zeros(&sp);

        let codec: Arc<dyn ActivationCodec> =
            Arc::from(codec::by_name(&cfg.codec, &cfg.codec_params)?);

        // Per-device randomness: every stream derives from (root seed,
        // purpose, device id), so no device's draws depend on any other
        // device's progress — a prerequisite for schedule-independent
        // parallel rounds.
        let devices = parts
            .into_iter()
            .enumerate()
            .map(|(id, shard)| DeviceCtx {
                id,
                shard_len: shard.len(),
                loader: BatchLoader::new(
                    shard,
                    cfg.batch_size,
                    derive_seed(cfg.seed, stream::LOADER, id as u64),
                ),
                link: Link::new(cfg.link, derive_seed(cfg.seed, stream::LINK, id as u64)),
                codec_rng: Pcg32::derived(cfg.seed, stream::CODEC, id as u64),
                cp: cp.clone(),
                cm: cm.clone(),
                pending: None,
                busy_at_round_start: 0.0,
            })
            .collect();

        Ok(Trainer {
            cfg,
            exec,
            codec,
            preset,
            train,
            test,
            devices,
            server: Mutex::new((sp, sm)),
            client: (cp, cm),
            n_client_params: n_client,
        })
    }

    /// The experiment configuration.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Run all configured rounds; returns the full outcome.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let mut history = TrainingHistory {
            name: self.cfg.name.clone(),
            codec: self.cfg.codec.clone(),
            rounds: Vec::new(),
        };
        for round in 1..=self.cfg.rounds {
            let m = self.run_round(round)?;
            crate::info!(
                "round {:>3}: loss {:.4} train {:.1}% test {:.1}%  {:.2} MB  comm {:.3}s",
                round,
                m.train_loss,
                m.train_acc * 100.0,
                m.test_acc * 100.0,
                m.total_bytes() as f64 / 1e6,
                m.comm_time_s
            );
            history.rounds.push(m);
        }
        // Order-stable reduction: fold in device-id order so f64 sums are
        // bit-identical no matter how many workers ran the phases.
        let mut comm = CommStats::default();
        for d in &self.devices {
            comm.accumulate(&d.link);
        }
        Ok(TrainOutcome {
            history,
            comm,
            exec_stats: self.exec.stats()?,
        })
    }

    /// One communication round.
    fn run_round(&mut self, round: usize) -> Result<RoundMetrics> {
        let t0 = Instant::now();
        match self.cfg.sync {
            SyncMode::ParallelFedAvg => self.round_parallel(round, t0),
            SyncMode::Sequential => self.round_sequential(round, t0),
        }
    }

    fn round_parallel(&mut self, round: usize, t0: Instant) -> Result<RoundMetrics> {
        // reset device copies to the aggregate
        for d in self.devices.iter_mut() {
            d.cp = self.client.0.clone();
            d.cm = self.client.1.clone();
            d.busy_at_round_start = d.link.busy_s;
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut samples = 0u64;
        let (mut up0, mut down0) = (0u64, 0u64);
        for d in &self.devices {
            up0 += d.link.uplink_bytes;
            down0 += d.link.downlink_bytes;
        }

        for _step in 0..self.cfg.batches_per_round {
            self.phase_fanout()?;
            let (l, c, n) = self.phase_server()?;
            loss_sum += l;
            correct += c;
            samples += n;
            self.phase_fanin()?;
        }

        // SplitFed aggregation, weighted by shard sizes. Sharded across
        // workers by *parameter index* — each parameter still folds its
        // devices in id order, so the result is bit-identical to the
        // sequential fold (see `aggregate::fedavg_sharded`).
        let workers = self.workers();
        let weights: Vec<f64> = self.devices.iter().map(|d| d.shard_len as f64).collect();
        let cps: Vec<Vec<HostTensor>> =
            self.devices.iter().map(|d| d.cp.clone()).collect();
        let cms: Vec<Vec<HostTensor>> =
            self.devices.iter().map(|d| d.cm.clone()).collect();
        self.client = (
            super::aggregate::fedavg_sharded(&cps, &weights, workers)?,
            super::aggregate::fedavg_sharded(&cms, &weights, workers)?,
        );

        self.finish_round(round, t0, loss_sum, correct, samples, up0, down0)
    }

    fn round_sequential(&mut self, round: usize, t0: Instant) -> Result<RoundMetrics> {
        // vanilla SL: client weights hand off device→device within the round
        for d in self.devices.iter_mut() {
            d.busy_at_round_start = d.link.busy_s;
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut samples = 0u64;
        let (mut up0, mut down0) = (0u64, 0u64);
        for d in &self.devices {
            up0 += d.link.uplink_bytes;
            down0 += d.link.downlink_bytes;
        }

        let (mut cp, mut cm) = (self.client.0.clone(), self.client.1.clone());
        for di in 0..self.devices.len() {
            self.devices[di].cp = cp.clone();
            self.devices[di].cm = cm.clone();
            for _ in 0..self.cfg.batches_per_round {
                self.device_fanout(di)?;
                let (l, c, n) = self.server_step_for(di)?;
                loss_sum += l;
                correct += c;
                samples += n;
                self.device_fanin(di)?;
            }
            cp = self.devices[di].cp.clone();
            cm = self.devices[di].cm.clone();
        }
        self.client = (cp, cm);
        self.finish_round(round, t0, loss_sum, correct, samples, up0, down0)
    }

    /// Effective worker-pool width for the parallel phases.
    fn workers(&self) -> usize {
        engine::effective_workers(self.cfg.workers, self.cfg.devices)
    }

    /// Phase 1 over all devices: client forward + codec encode + uplink,
    /// sharded across the worker pool.
    fn phase_fanout(&mut self) -> Result<()> {
        let exec = &self.exec;
        let codec = &self.codec;
        let cfg = &self.cfg;
        let preset = &self.preset;
        let train = &self.train;
        let workers = self.workers();
        engine::run_sharded(&mut self.devices, workers, |_, dev| {
            device_fanout_impl(dev, exec, codec.as_ref(), cfg, preset, train)
        })
    }

    fn device_fanout(&mut self, di: usize) -> Result<()> {
        device_fanout_impl(
            &mut self.devices[di],
            &self.exec,
            self.codec.as_ref(),
            &self.cfg,
            &self.preset,
            &self.train,
        )
    }

    /// Phase 2: serialized server updates in device order.
    fn phase_server(&mut self) -> Result<(f64, u64, u64)> {
        let mut loss = 0.0;
        let mut correct = 0u64;
        let mut n = 0u64;
        for di in 0..self.devices.len() {
            let (l, c, b) = self.server_step_for(di)?;
            loss += l;
            correct += c;
            n += b;
        }
        Ok((loss, correct, n))
    }

    fn server_step_for(&mut self, di: usize) -> Result<(f64, u64, u64)> {
        let cfg = &self.cfg;
        let freq = self.codec.frequency_domain();
        let dev = &mut self.devices[di];
        let step = dev.pending.as_mut().context("phase order violation")?;

        // decompress uplink → activations
        let decoded = self.codec.decompress(&step.uplink)?;
        let act = if freq {
            let out = self.exec.execute(
                &self.preset,
                "idct",
                vec![HostTensor::from_tensor(&decoded)],
            )?;
            out.into_iter().next().context("idct output")?
        } else {
            HostTensor::from_tensor(&decoded)
        };

        // server training step
        let mut server = self.server.lock().unwrap();
        let (sp, sm) = &mut *server;
        let n_s = sp.len();
        let mut inputs = Vec::with_capacity(2 * n_s + 3);
        inputs.extend(sp.iter().cloned());
        inputs.extend(sm.iter().cloned());
        inputs.push(act);
        inputs.push(step.y.clone());
        inputs.push(HostTensor::scalar_f32(cfg.lr));
        let mut out = self
            .exec
            .execute(&self.preset, "server_step", inputs)?
            .into_iter();
        let new_sp: Vec<HostTensor> = (&mut out).take(n_s).collect();
        let new_sm: Vec<HostTensor> = (&mut out).take(n_s).collect();
        let loss = out.next().context("loss output")?.first();
        let correct = out.next().context("correct output")?.first() as u64;
        let gact = out.next().context("gact output")?;
        let gact_dct = out.next().context("gact_dct output")?;
        *sp = new_sp;
        *sm = new_sm;
        drop(server);

        // downlink gradient
        let batch = step.y.numel() as u64;
        if cfg.compress_gradients {
            let g = if freq { gact_dct } else { gact };
            let payload = self
                .codec
                .compress_with_rng(&g.into_tensor(), &mut dev.codec_rng)?;
            dev.link
                .transfer(Direction::Downlink, payload.wire_bytes());
            step.grad = Some(GradMsg::Compressed(payload));
        } else {
            dev.link.transfer(Direction::Downlink, gact.raw_bytes());
            step.grad = Some(GradMsg::Raw(gact));
        }
        Ok((loss, correct, batch))
    }

    /// Phase 3 over all devices: gradient decode + client backward,
    /// sharded across the worker pool.
    fn phase_fanin(&mut self) -> Result<()> {
        let exec = &self.exec;
        let codec = &self.codec;
        let cfg = &self.cfg;
        let preset = &self.preset;
        let workers = self.workers();
        engine::run_sharded(&mut self.devices, workers, |_, dev| {
            device_fanin_impl(dev, exec, codec.as_ref(), cfg, preset)
        })
    }

    fn device_fanin(&mut self, di: usize) -> Result<()> {
        device_fanin_impl(
            &mut self.devices[di],
            &self.exec,
            self.codec.as_ref(),
            &self.cfg,
            &self.preset,
        )
    }

    fn finish_round(
        &mut self,
        round: usize,
        t0: Instant,
        loss_sum: f64,
        correct: u64,
        samples: u64,
        up0: u64,
        down0: u64,
    ) -> Result<RoundMetrics> {
        let (test_loss, test_acc) = self.evaluate()?;
        let batches = (self.cfg.batches_per_round * self.cfg.devices) as f64;
        let (mut up1, mut down1) = (0u64, 0u64);
        let mut makespan = 0.0f64;
        for d in &self.devices {
            up1 += d.link.uplink_bytes;
            down1 += d.link.downlink_bytes;
            makespan = makespan.max(d.link.busy_s - d.busy_at_round_start);
        }
        Ok(RoundMetrics {
            round,
            train_loss: loss_sum / batches,
            train_acc: correct as f64 / samples.max(1) as f64,
            test_acc,
            test_loss,
            uplink_bytes: up1 - up0,
            downlink_bytes: down1 - down0,
            comm_time_s: makespan,
            wall_time_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Evaluate the aggregated model on the test split (full batches only).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let b = self.cfg.batch_size;
        let n_batches = self.test.len() / b;
        anyhow::ensure!(n_batches > 0, "test set smaller than one batch");
        let server = self.server.lock().unwrap();
        let (sp, _) = &*server;
        let mut loss = 0.0;
        let mut correct = 0u64;
        for i in 0..n_batches {
            let mut images = Vec::with_capacity(b * self.test.sample_size());
            let mut labels = Vec::with_capacity(b);
            for j in i * b..(i + 1) * b {
                images.extend_from_slice(self.test.image(j));
                labels.push(self.test.labels[j] as i32);
            }
            let x = HostTensor::f32(
                &[
                    b,
                    self.test.channels,
                    self.test.height,
                    self.test.width,
                ],
                images,
            );
            let y = HostTensor::i32(&[b], labels);
            let mut inputs = Vec::with_capacity(self.n_client_params + sp.len() + 2);
            inputs.extend(self.client.0.iter().cloned());
            inputs.extend(sp.iter().cloned());
            inputs.push(x);
            inputs.push(y);
            let out = self.exec.execute(&self.preset, "eval_step", inputs)?;
            loss += out[0].first();
            correct += out[1].first() as u64;
        }
        Ok((
            loss / n_batches as f64,
            correct as f64 / (n_batches * b) as f64,
        ))
    }

    /// Immutable view of per-device link stats (for reports).
    pub fn link_stats(&self) -> Vec<(usize, u64, u64, f64)> {
        self.devices
            .iter()
            .map(|d| (d.id, d.link.uplink_bytes, d.link.downlink_bytes, d.link.busy_s))
            .collect()
    }

    /// Snapshot of the aggregated client-side parameters (for the
    /// differential determinism tests: parallel and sequential runs must
    /// end bit-identical here).
    pub fn client_params(&self) -> Vec<HostTensor> {
        self.client.0.clone()
    }

    /// Snapshot of the server-side parameters.
    pub fn server_params(&self) -> Vec<HostTensor> {
        self.server.lock().unwrap().0.clone()
    }
}

/// Phase-1 body (shared by parallel and sequential modes).
fn device_fanout_impl(
    dev: &mut DeviceCtx,
    exec: &ExecutorHandle,
    codec: &dyn ActivationCodec,
    cfg: &ExperimentConfig,
    preset: &str,
    train: &Dataset,
) -> Result<()> {
    let (images, labels) = dev.loader.next_batch(train);
    let x = HostTensor::f32(
        &[cfg.batch_size, train.channels, train.height, train.width],
        images,
    );
    let y = HostTensor::i32(
        &[cfg.batch_size],
        labels.into_iter().map(|l| l as i32).collect(),
    );
    let mut inputs: Vec<HostTensor> = dev.cp.iter().cloned().collect();
    inputs.push(x.clone());
    let mut out = exec.execute(preset, "client_fwd", inputs)?.into_iter();
    let act = out.next().context("act output")?;
    let act_dct = out.next().context("act_dct output")?;

    let wire_input: Tensor = if codec.frequency_domain() {
        act_dct.into_tensor()
    } else {
        act.into_tensor()
    };
    let payload = codec.compress_with_rng(&wire_input, &mut dev.codec_rng)?;
    dev.link.transfer(Direction::Uplink, payload.wire_bytes());
    dev.pending = Some(StepCtx {
        x,
        y,
        uplink: payload,
        grad: None,
    });
    Ok(())
}

/// Phase-3 body (shared by parallel and sequential modes).
fn device_fanin_impl(
    dev: &mut DeviceCtx,
    exec: &ExecutorHandle,
    codec: &dyn ActivationCodec,
    cfg: &ExperimentConfig,
    preset: &str,
) -> Result<()> {
    let step = dev.pending.take().context("phase order violation")?;
    let grad = step.grad.context("phase 2 did not run")?;
    let gact = match grad {
        GradMsg::Raw(g) => g,
        GradMsg::Compressed(p) => {
            let decoded = codec.decompress(&p)?;
            if codec.frequency_domain() {
                exec.execute(preset, "idct", vec![HostTensor::from_tensor(&decoded)])?
                    .into_iter()
                    .next()
                    .context("idct output")?
            } else {
                HostTensor::from_tensor(&decoded)
            }
        }
    };
    let n_c = dev.cp.len();
    let mut inputs = Vec::with_capacity(2 * n_c + 3);
    inputs.extend(dev.cp.iter().cloned());
    inputs.extend(dev.cm.iter().cloned());
    inputs.push(step.x);
    inputs.push(gact);
    inputs.push(HostTensor::scalar_f32(cfg.lr));
    let mut out = exec.execute(preset, "client_step", inputs)?.into_iter();
    dev.cp = (&mut out).take(n_c).collect();
    dev.cm = out.collect();
    anyhow::ensure!(dev.cm.len() == n_c, "client_step output arity");
    Ok(())
}
