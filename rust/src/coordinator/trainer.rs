//! The training orchestrator: devices, rounds, the wire path, aggregation,
//! evaluation. See the module docs in [`super`] for the phase structure,
//! [`super::engine`] for the worker pool + determinism contract, and
//! [`crate::transport`] for the round schedulers this trainer delegates
//! round control flow to.

use crate::codec::{self, ActivationCodec, CodecScratch, Payload};
use crate::config::{DatasetKind, ExperimentConfig, Partition, SyncMode};
use crate::data::{
    partition_dirichlet, partition_iid, synthetic, BatchLoader, Dataset,
};
use crate::rng::{derive_seed, stream, Pcg32};
use crate::runtime::{ExecutorHandle, ExecutorStats, HostTensor, ResidentSession};
use crate::tensor::Tensor;
use crate::transport::{
    assign_profiles, build_scheduler, fault::CORRUPT_FLIPS, CommStats, DeviceId, DeviceProfile,
    Direction, DownlinkMode, FaultPlan, Link, RoundOps, RoundReport, RoundScheduler, ServerOut,
    ServerStep, UplinkMode, UplinkMsg,
};
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::checkpoint::{self, CheckpointState, DeviceState, ModelState};
use super::engine;
use super::metrics::{RoundMetrics, StreamFold, TrainingHistory};

/// Per-device state owned by the trainer across rounds. Everything a
/// worker thread needs for the fan-out/fan-in phases lives here (own
/// loader + link + codec RNG stream), which is what makes the sharded
/// engine's no-shared-mutable-state determinism argument hold — see
/// [`super::engine`].
struct DeviceCtx {
    id: usize,
    /// Link class / compute-speed profile (heterogeneous fleets).
    profile: DeviceProfile,
    loader: BatchLoader,
    link: Link,
    /// Per-device codec sampling stream (randomized codecs draw from this
    /// through [`ActivationCodec::compress_with_rng`], so payloads do not
    /// depend on cross-device scheduling).
    codec_rng: Pcg32,
    /// Per-device codec scratch arena (work buffers + recycled payload
    /// bodies). Exactly one worker owns this device per phase, so the
    /// arena is race-free by construction, and arena contents never
    /// influence results — the steady-state wire path allocates nothing
    /// (see `codec::plan`).
    scratch: CodecScratch,
    /// Reusable decode target for uplink/gradient payloads (reset in
    /// place each step; its data is copied into a `HostTensor` for the
    /// executor).
    decode: Tensor,
    /// Fast path: reusable batch image buffer (`[B·C·H·W]` flat).
    x_buf: Vec<f32>,
    /// Fast path: reusable batch label buffer.
    y_buf: Vec<i32>,
    /// Fast path: reusable wire-domain staging tensor — the activation
    /// coefficients/activations on fan-out, the gradient on the downlink.
    wire: Tensor,
    /// Fast path: reusable spatial tensor for decoded + inverse-DCT'd
    /// payloads.
    spatial: Tensor,
    /// Device's client-side parameters (SplitFed: reset to the aggregate at
    /// round start; sequential: handed off device-to-device). Reference
    /// path only — the fast path keeps weights device-resident in the
    /// executor's [`ResidentSession`] slots.
    cp: Vec<HostTensor>,
    /// Device's client-side momenta (reference path only).
    cm: Vec<HostTensor>,
    shard_len: usize,
    /// Set by fan-out, consumed by the server step and fan-in.
    pending: Option<StepCtx>,
    /// Fault injection: clean copy of the pending uplink body while seeded
    /// bit flips are applied (the retransmission resends the original
    /// payload). Empty — no allocation — unless the fault layer is active.
    clean_body: Vec<u8>,
}

/// One in-flight batch between phases.
struct StepCtx {
    /// Batch tensors (reference path; the fast path keeps the batch in
    /// the device's reusable `x_buf`/`y_buf` instead — `None` here).
    x: Option<HostTensor>,
    y: Option<HostTensor>,
    uplink: Payload,
    /// Filled by the server step.
    grad: Option<GradMsg>,
}

/// Gradient travelling server→device.
enum GradMsg {
    /// Compressed (codec wire path).
    Compressed(Payload),
    /// Raw tensor (reference path, `compress_gradients = false`).
    Raw(HostTensor),
    /// Fast path, `compress_gradients = false`: the spatial gradient sits
    /// in the device's reusable `wire` tensor (no `HostTensor` built).
    Stashed,
}

/// State restored by [`Trainer::resume_latest`], consumed by the next
/// [`Trainer::run`]: the run starts at `completed + 1` with the restored
/// history pre-pushed (cumulative byte totals rebuilt through the normal
/// `push` path) and the makespan accumulator re-seeded.
struct RestoredRun {
    completed: usize,
    rounds: Vec<RoundMetrics>,
    makespan_total_s: f64,
}

/// Final result of a training run.
pub struct TrainOutcome {
    /// Per-round metrics.
    pub history: TrainingHistory,
    /// Aggregate communication statistics (`makespan_s` is the sum of
    /// per-round makespans — see [`CommStats`]).
    pub comm: CommStats,
    /// Executor-side statistics (per-artifact exec counts/times).
    pub exec_stats: ExecutorStats,
}

/// The split-learning trainer (one experiment run).
pub struct Trainer {
    cfg: ExperimentConfig,
    exec: ExecutorHandle,
    codec: Arc<dyn ActivationCodec>,
    /// Round scheduler for the parallel (SplitFed) mode — sync lockstep or
    /// event-driven async with a straggler policy.
    scheduler: Box<dyn RoundScheduler>,
    preset: String,
    train: Dataset,
    test: Dataset,
    devices: Vec<DeviceCtx>,
    /// Server-side parameters + momenta (updated in the server step only;
    /// the Mutex documents the sharing discipline for future
    /// parallel-server modes).
    server: Mutex<(Vec<HostTensor>, Vec<HostTensor>)>,
    /// Aggregated client params/momenta between rounds (reference path;
    /// the fast path's aggregate lives in the resident session's slot).
    client: (Vec<HostTensor>, Vec<HostTensor>),
    n_client_params: usize,
    /// Device-resident compute session (`compute_fast_path` + a backend
    /// that supports it). `None` routes everything through the artifact
    /// `execute` path — bit-identical, just slower.
    resident: Option<ResidentSession>,
    /// Reusable per-round participant buffer (client sampling).
    participants: Vec<usize>,
    /// Reusable per-round completion mask, global device ids. Participants
    /// start a round `true`; the scheduler retracts stragglers through
    /// [`RoundOps::cancel`], so the mask is exact when `run_round` returns
    /// ([`RoundReport`] itself carries only counts — no per-device vector
    /// is materialized at fleet scale).
    completed_mask: Vec<bool>,
    /// Reusable per-round FedAvg weight buffer.
    fedavg_weights: Vec<f64>,
    /// Reusable participant-local → global index buffer for the sharded
    /// batch dispatch (`engine::run_sharded_indexed`).
    scratch_idx: Vec<usize>,
    /// Sum of per-round communication makespans (the satellite fix: the
    /// run-level makespan is per-round accounting, not a lifetime max).
    makespan_total_s: f64,
    /// Runtime-only interruption hook (not a config knob, so it never
    /// perturbs the config fingerprint): `run()` leaves the round loop
    /// after checkpointing this round. The crash-resume tests and the CI
    /// smoke use it to interrupt a run at a round boundary while keeping
    /// the *configured* `rounds` — and hence the checkpoint fingerprint —
    /// identical to the uninterrupted run.
    stop_after_round: Option<usize>,
    /// Set by `round_parallel` when every participant was dropped and the
    /// aggregate was carried forward; recorded as `RoundMetrics::skipped`.
    round_skipped: bool,
    /// Restored state from `resume_latest`, consumed by the next `run()`.
    resume: Option<RestoredRun>,
}

impl Trainer {
    /// Build a trainer: datasets, partition, executor, profiles, initial
    /// parameters.
    pub fn new(cfg: ExperimentConfig, exec: ExecutorHandle) -> Result<Self> {
        cfg.validate()?;
        let preset = cfg.dataset.name().to_string();
        let manifest = crate::runtime::ArtifactManifest::load(&cfg.artifacts_dir)?;
        let pm = manifest.preset(&preset)?.clone();
        anyhow::ensure!(
            pm.batch_size == cfg.batch_size,
            "config batch_size {} != artifact batch_size {} — re-run `make artifacts`",
            cfg.batch_size,
            pm.batch_size
        );

        let spec = synthetic::DatasetSpec {
            train_samples: cfg.train_samples,
            test_samples: cfg.test_samples,
            noise: cfg.noise,
            seed: cfg.seed,
        };
        let (train, test) = match cfg.dataset {
            DatasetKind::Mnist => synthetic::mnist_like(&spec),
            DatasetKind::Ham => synthetic::ham_like(&spec),
        };

        let parts = match cfg.partition {
            Partition::Iid => partition_iid(&train, cfg.devices, cfg.seed),
            Partition::Dirichlet(beta) => {
                partition_dirichlet(&train, cfg.devices, beta, cfg.seed)
            }
        };
        crate::info!(
            "partition: {} devices, skew {:.3}",
            cfg.devices,
            crate::data::partition::label_skew(&train, &parts)
        );

        // initial parameters from the init artifact
        let init_out = exec.execute(&preset, "init", vec![])?;
        let n_client = pm.client_params.len();
        let n_server = pm.server_params.len();
        anyhow::ensure!(
            init_out.len() == n_client + n_server,
            "init artifact returned {} tensors, manifest says {}",
            init_out.len(),
            n_client + n_server
        );
        let mut it = init_out.into_iter();
        let cp: Vec<HostTensor> = (&mut it).take(n_client).collect();
        let sp: Vec<HostTensor> = it.collect();
        let zeros =
            |ps: &[HostTensor]| -> Vec<HostTensor> {
                ps.iter()
                    .map(|p| HostTensor::f32(p.dims(), vec![0.0; p.numel()]))
                    .collect()
            };
        let cm = zeros(&cp);
        let sm = zeros(&sp);

        let codec: Arc<dyn ActivationCodec> =
            Arc::from(codec::by_name(&cfg.codec, &cfg.codec_params)?);

        // Device-resident compute (the zero-allocation fast path): weights
        // and momenta live in executor-side per-device slots updated in
        // place, instead of round-tripping through fresh HostTensors every
        // step. Bit-identical to the artifact path by construction (see
        // runtime::compute); backends without support fall back silently.
        let resident = if cfg.compute_fast_path {
            let r = exec.open_resident(&preset, cfg.devices)?;
            if r.is_none() {
                crate::info!(
                    "compute_fast_path: backend has no device-resident support — \
                     using the artifact execute path"
                );
            }
            r
        } else {
            None
        };
        let use_resident = resident.is_some();

        // Per-device heterogeneity (link class + compute multiplier) from
        // the profile spec; "config" keeps the pre-transport homogeneous
        // behavior.
        let profiles = assign_profiles(&cfg.profile, cfg.devices, cfg.link)?;

        // Per-device randomness: every stream derives from (root seed,
        // purpose, device id), so no device's draws depend on any other
        // device's progress — a prerequisite for schedule-independent
        // parallel rounds.
        let devices = parts
            .into_iter()
            .zip(profiles)
            .enumerate()
            .map(|(id, (shard, profile))| DeviceCtx {
                id,
                shard_len: shard.len(),
                loader: BatchLoader::new(
                    shard,
                    cfg.batch_size,
                    derive_seed(cfg.seed, stream::LOADER, id as u64),
                ),
                link: Link::new(profile.link, derive_seed(cfg.seed, stream::LINK, id as u64)),
                profile,
                codec_rng: Pcg32::derived(cfg.seed, stream::CODEC, id as u64),
                scratch: CodecScratch::new(),
                decode: Tensor::zeros(&[1]),
                x_buf: Vec::new(),
                y_buf: Vec::new(),
                wire: Tensor::zeros(&[1]),
                spatial: Tensor::zeros(&[1]),
                // the fast path keeps weights in the resident slots — no
                // per-device HostTensor copies to maintain
                cp: if use_resident { Vec::new() } else { cp.clone() },
                cm: if use_resident { Vec::new() } else { cm.clone() },
                pending: None,
                clean_body: Vec::new(),
            })
            .collect();

        let scheduler = build_scheduler(cfg.scheduler, cfg.straggler);
        Ok(Trainer {
            cfg,
            exec,
            codec,
            scheduler,
            preset,
            train,
            test,
            devices,
            server: Mutex::new((sp, sm)),
            client: (cp, cm),
            n_client_params: n_client,
            resident,
            participants: Vec::new(),
            completed_mask: Vec::new(),
            fedavg_weights: Vec::new(),
            scratch_idx: Vec::new(),
            makespan_total_s: 0.0,
            stop_after_round: None,
            round_skipped: false,
            resume: None,
        })
    }

    /// The experiment configuration.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Interrupt the next `run()` after checkpointing `round` (runtime-only
    /// knob; `None` runs to completion). See the `stop_after_round` field.
    pub fn set_stop_after(&mut self, round: Option<usize>) {
        self.stop_after_round = round;
    }

    /// Run all configured rounds; returns the full outcome.
    ///
    /// When `resume_latest` restored a checkpoint, the loop starts at the
    /// round after the checkpointed one with the restored per-round history
    /// replayed through the normal `push` path (so the cumulative byte
    /// totals are rebuilt identically); everything downstream — metrics,
    /// CSV, final parameters — is bit-identical to a run that never
    /// stopped.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let mut history =
            TrainingHistory::with_capacity(&self.cfg.name, &self.cfg.codec, self.cfg.rounds);
        self.makespan_total_s = 0.0;
        let first_round = match self.resume.take() {
            Some(res) => {
                for m in res.rounds {
                    history.push(m);
                }
                self.makespan_total_s = res.makespan_total_s;
                res.completed + 1
            }
            None => 1,
        };
        for round in first_round..=self.cfg.rounds {
            let m = self.run_round(round)?;
            let mut extras = String::new();
            if m.queue_wait_s > 0.0 {
                extras.push_str(&format!("  wait {:.3}s", m.queue_wait_s));
            }
            if m.dropped_devices > 0 {
                extras.push_str(&format!("  dropped {}", m.dropped_devices));
            }
            if m.retransmits > 0 || m.corrupt_payloads > 0 {
                extras.push_str(&format!(
                    "  retx {} corrupt {}",
                    m.retransmits, m.corrupt_payloads
                ));
            }
            if (m.sampled_devices as usize) < self.cfg.devices {
                extras.push_str(&format!(
                    "  sampled {}/{}",
                    m.sampled_devices, self.cfg.devices
                ));
            }
            crate::info!(
                "round {:>3}: loss {:.4} train {:.1}% test {:.1}%  {:.2} MB  comm {:.3}s  sim {:.3}s{}",
                round,
                m.train_loss,
                m.train_acc * 100.0,
                m.test_acc * 100.0,
                m.total_bytes() as f64 / 1e6,
                m.comm_time_s,
                m.sim_time_s,
                extras
            );
            history.push(m);
            if self.cfg.checkpoint_every > 0 && round % self.cfg.checkpoint_every == 0 {
                self.save_checkpoint(round, &history)?;
            }
            if self.stop_after_round == Some(round) {
                crate::info!("stop_after_round: leaving the round loop after round {round}");
                break;
            }
        }
        // Order-stable reduction: fold in device-id order so f64 sums are
        // bit-identical no matter how many workers ran the phases. The
        // run-level makespan is the accumulated per-round makespan — not
        // any link's lifetime busy maximum.
        let mut comm = CommStats::default();
        for d in &self.devices {
            comm.accumulate(&d.link);
        }
        comm.makespan_s = self.makespan_total_s;
        Ok(TrainOutcome {
            history,
            comm,
            exec_stats: self.exec.stats()?,
        })
    }

    /// One communication round.
    fn run_round(&mut self, round: usize) -> Result<RoundMetrics> {
        let t0 = Instant::now();
        self.round_skipped = false;
        match self.cfg.sync {
            SyncMode::ParallelFedAvg => self.round_parallel(round, t0),
            SyncMode::Sequential => self.round_sequential(round, t0),
        }
    }

    fn round_parallel(&mut self, round: usize, t0: Instant) -> Result<RoundMetrics> {
        // reset device copies to the aggregate + fresh round accounting
        if let Some(res) = &self.resident {
            // in-place copy into the resident slots — same values the
            // reference path clones, no allocation
            for d in 0..self.devices.len() {
                res.load_client_from_agg(d)?;
            }
            for d in self.devices.iter_mut() {
                d.link.begin_round();
            }
        } else {
            for d in self.devices.iter_mut() {
                d.cp = self.client.0.clone();
                d.cm = self.client.1.clone();
                d.link.begin_round();
            }
        }
        let (mut up0, mut down0) = (0u64, 0u64);
        for d in &self.devices {
            up0 += d.link.uplink_bytes;
            down0 += d.link.downlink_bytes;
        }

        // Per-round client sampling: the participant subset is a pure
        // function of (seed, round), drawn before any scheduling. Devices
        // left out transfer nothing this round and rejoin from the
        // aggregate next round (the straggler rejoin path, minus the
        // wasted bytes). Drawn into a reusable buffer.
        self.cfg
            .sampling
            .draw_into(self.cfg.seed, round, self.cfg.devices, &mut self.participants);

        // Participants start the round marked complete; the scheduler
        // retracts stragglers through `RoundOps::cancel`, so the mask is
        // exact when `run_round` returns. Unsampled devices stay `false`
        // and carry zero FedAvg weight.
        self.completed_mask.clear();
        self.completed_mask.resize(self.devices.len(), false);
        for &g in &self.participants {
            self.completed_mask[g] = true;
        }

        // The scheduler drives the round through the RoundOps interface;
        // disjoint-field borrows let it run against the device table while
        // the scheduler itself stays borrowed from self.
        let workers = self.workers();
        let participants = &self.participants;
        // One fault plan per round, a pure function of (seed, round) — the
        // same plan at workers = 1 and N, sync and async. Inactive fault
        // configs hand the schedulers `None` and take the legacy paths
        // bit-identically.
        let fault = self
            .cfg
            .fault
            .is_active()
            .then(|| FaultPlan::new(self.cfg.fault, self.cfg.seed, round as u64));
        let report = {
            let mut ops = TrainerRoundOps {
                devices: &mut self.devices[..],
                participants,
                completed: &mut self.completed_mask[..],
                idx: &mut self.scratch_idx,
                exec: &self.exec,
                codec: self.codec.as_ref(),
                cfg: &self.cfg,
                preset: &self.preset,
                train: &self.train,
                server: &self.server,
                resident: self.resident.as_ref(),
                workers,
                fault,
            };
            self.scheduler.run_round(&mut ops)?
        };

        // SplitFed aggregation, weighted by shard sizes, over devices that
        // completed the round (stragglers dropped by the policy — and
        // devices not sampled into the round — sit this aggregation out
        // and rejoin from the aggregate next round). Sharded across
        // workers by *parameter index* — each parameter still folds its
        // devices in id order, so the result is bit-identical to the
        // sequential fold (see `aggregate::fedavg_sharded`). The fast path
        // folds the resident slots in place with the identical arithmetic
        // (see `ResidentSession::fedavg`).
        let mask = &self.completed_mask;
        let devices = &self.devices;
        self.fedavg_weights.clear();
        self.fedavg_weights.extend(
            devices
                .iter()
                .enumerate()
                .map(|(i, d)| if mask[i] { d.shard_len as f64 } else { 0.0 }),
        );
        if self.fedavg_weights.iter().sum::<f64>() > 0.0 {
            if let Some(res) = &self.resident {
                res.fedavg(&self.fedavg_weights)?;
            } else {
                let cps: Vec<Vec<HostTensor>> =
                    self.devices.iter().map(|d| d.cp.clone()).collect();
                let cms: Vec<Vec<HostTensor>> =
                    self.devices.iter().map(|d| d.cm.clone()).collect();
                self.client = (
                    super::aggregate::fedavg_sharded(&cps, &self.fedavg_weights, workers)?,
                    super::aggregate::fedavg_sharded(&cms, &self.fedavg_weights, workers)?,
                );
            }
        } else {
            // the all-dropped round: zero total FedAvg weight would divide
            // to NaN, so the aggregate (and momenta) carry forward
            // unchanged and the round is recorded as skipped
            self.round_skipped = true;
            crate::warn!(
                "round {round}: every participant was dropped (policy {}) — \
                 keeping previous aggregate, recording the round as skipped",
                self.cfg.straggler.name()
            );
        }

        let sampled = self.participants.len() as u64;
        self.finish_round(round, t0, &report, up0, down0, sampled)
    }

    fn round_sequential(&mut self, round: usize, t0: Instant) -> Result<RoundMetrics> {
        // vanilla SL: client weights hand off device→device within the
        // round — inherently serial, so the round schedulers don't apply.
        // Client sampling still does: only sampled devices take part in
        // the relay (ascending id order), everyone else sits out.
        for d in self.devices.iter_mut() {
            d.link.begin_round();
        }
        self.cfg
            .sampling
            .draw_into(self.cfg.seed, round, self.cfg.devices, &mut self.participants);
        let mut loss_sum = 0.0f64;
        let mut correct = 0u64;
        let mut samples = 0u64;
        let mut server_steps = 0u64;
        let (mut up0, mut down0) = (0u64, 0u64);
        for d in &self.devices {
            up0 += d.link.uplink_bytes;
            down0 += d.link.downlink_bytes;
        }

        // Weight shuttle: the fast path hands the resident slots off
        // device→device in place; the reference path clones HostTensors
        // along the same chain (identical values either way).
        let (mut cp, mut cm) = if self.resident.is_some() {
            (Vec::new(), Vec::new())
        } else {
            (self.client.0.clone(), self.client.1.clone())
        };
        let mut prev: Option<usize> = None;
        for idx in 0..self.participants.len() {
            let di = self.participants[idx];
            if let Some(res) = &self.resident {
                match prev {
                    None => res.load_client_from_agg(di)?,
                    Some(p) => res.copy_client(p, di)?,
                }
            } else {
                self.devices[di].cp = cp.clone();
                self.devices[di].cm = cm.clone();
            }
            for _ in 0..self.cfg.batches_per_round {
                device_fanout_impl(
                    &mut self.devices[di],
                    self.resident.as_ref(),
                    &self.exec,
                    self.codec.as_ref(),
                    &self.cfg,
                    &self.preset,
                    &self.train,
                )?;
                let out = server_step_impl(
                    &mut self.devices[di],
                    self.resident.as_ref(),
                    &self.exec,
                    self.codec.as_ref(),
                    &self.cfg,
                    &self.preset,
                    &self.server,
                )?
                .context("corrupt uplink payload in sequential round")?;
                loss_sum += out.loss;
                correct += out.correct;
                samples += out.samples;
                server_steps += 1;
                device_fanin_impl(
                    &mut self.devices[di],
                    self.resident.as_ref(),
                    &self.exec,
                    self.codec.as_ref(),
                    &self.cfg,
                    &self.preset,
                )?;
            }
            if self.resident.is_none() {
                cp = self.devices[di].cp.clone();
                cm = self.devices[di].cm.clone();
            }
            prev = Some(di);
        }
        if let Some(res) = &self.resident {
            if let Some(last) = prev {
                res.store_client_to_agg(last)?;
            }
        } else {
            self.client = (cp, cm);
        }

        // serial handoff: the round's simulated duration is the sum over
        // participants of their transfer busy time, two compute phases per
        // local step, and the server's per-batch service time (the server
        // never queues here — one device talks to it at a time)
        let mut sim_round_s = 0.0f64;
        for &di in &self.participants {
            let d = &self.devices[di];
            sim_round_s += d.link.round_busy_s
                + 2.0
                    * self.cfg.base_compute_s
                    * d.profile.compute_mult
                    * self.cfg.batches_per_round as f64
                + self.cfg.server_service_s * self.cfg.batches_per_round as f64;
        }
        // participant-local, like the scheduler reports: sequential never
        // drops anyone, and sampled-out devices are not "dropped"
        let report = RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s,
            queue_wait_s: 0.0,
            n_devices: self.participants.len(),
            completed: self.participants.len(),
            ..RoundReport::zeroed()
        };
        let sampled = self.participants.len() as u64;
        self.finish_round(round, t0, &report, up0, down0, sampled)
    }

    /// Effective worker-pool width for the parallel phases.
    fn workers(&self) -> usize {
        engine::effective_workers(self.cfg.workers, self.cfg.devices)
    }

    fn finish_round(
        &mut self,
        round: usize,
        t0: Instant,
        report: &RoundReport,
        up0: u64,
        down0: u64,
        sampled_devices: u64,
    ) -> Result<RoundMetrics> {
        let (test_loss, test_acc) = self.evaluate()?;
        let (mut up1, mut down1) = (0u64, 0u64);
        // per-round makespan from the round-busy snapshot counters (the
        // CommStats::makespan_s fix: never derived from lifetime busy_s),
        // folded in device-id order as a streaming reduction — no
        // per-device vector is ever built (fleet-scale discipline; busy
        // times are non-negative, so the fold's max is bit-identical to
        // the historical 0.0-seeded running max)
        let mut busy = StreamFold::new();
        for d in &self.devices {
            up1 += d.link.uplink_bytes;
            down1 += d.link.downlink_bytes;
            busy.observe(d.link.round_busy_s);
        }
        let makespan = busy.max_or(0.0);
        self.makespan_total_s += makespan;
        Ok(RoundMetrics {
            round,
            train_loss: report.loss_sum / report.server_steps.max(1) as f64,
            train_acc: report.correct as f64 / report.samples.max(1) as f64,
            test_acc,
            test_loss,
            uplink_bytes: up1 - up0,
            downlink_bytes: down1 - down0,
            comm_time_s: makespan,
            sim_time_s: report.sim_round_s,
            queue_wait_s: report.queue_wait_s,
            dropped_devices: report.dropped() as u64,
            sampled_devices,
            retransmits: report.retransmits,
            lost_bytes: report.lost_bytes,
            corrupt_payloads: report.corrupt_payloads,
            recovery_wait_s: report.recovery_wait_s,
            skipped: self.round_skipped,
            wall_time_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Evaluate the aggregated model on the test split (full batches only).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let b = self.cfg.batch_size;
        let n_batches = self.test.len() / b;
        anyhow::ensure!(n_batches > 0, "test set smaller than one batch");
        if let Some(res) = &self.resident {
            // resident slots + reusable batch staging — allocation-free,
            // same per-batch loss/correct values as the artifact path
            let mut loss = 0.0;
            let mut correct = 0u64;
            for i in 0..n_batches {
                let (l, c) = res.eval_batch(&self.test, i * b, b)?;
                loss += l;
                correct += c;
            }
            return Ok((
                loss / n_batches as f64,
                correct as f64 / (n_batches * b) as f64,
            ));
        }
        let server = self.server.lock().unwrap();
        let (sp, _) = &*server;
        let mut loss = 0.0;
        let mut correct = 0u64;
        for i in 0..n_batches {
            let mut images = Vec::with_capacity(b * self.test.sample_size());
            let mut labels = Vec::with_capacity(b);
            for j in i * b..(i + 1) * b {
                images.extend_from_slice(self.test.image(j));
                labels.push(self.test.labels[j] as i32);
            }
            let x = HostTensor::f32(
                &[
                    b,
                    self.test.channels,
                    self.test.height,
                    self.test.width,
                ],
                images,
            );
            let y = HostTensor::i32(&[b], labels);
            let mut inputs = Vec::with_capacity(self.n_client_params + sp.len() + 2);
            inputs.extend(self.client.0.iter().cloned());
            inputs.extend(sp.iter().cloned());
            inputs.push(x);
            inputs.push(y);
            let out = self.exec.execute(&self.preset, "eval_step", inputs)?;
            loss += out[0].first();
            correct += out[1].first() as u64;
        }
        Ok((
            loss / n_batches as f64,
            correct as f64 / (n_batches * b) as f64,
        ))
    }

    /// Immutable view of per-device link stats (for reports).
    pub fn link_stats(&self) -> Vec<(usize, u64, u64, f64)> {
        self.devices
            .iter()
            .map(|d| (d.id, d.link.uplink_bytes, d.link.downlink_bytes, d.link.busy_s))
            .collect()
    }

    /// Snapshot of the aggregated client-side parameters (for the
    /// differential determinism tests: parallel and sequential runs must
    /// end bit-identical here).
    pub fn client_params(&self) -> Vec<HostTensor> {
        match &self.resident {
            Some(res) => res.client_params(),
            None => self.client.0.clone(),
        }
    }

    /// Snapshot of the server-side parameters.
    pub fn server_params(&self) -> Vec<HostTensor> {
        match &self.resident {
            Some(res) => res.server_params(),
            None => self.server.lock().unwrap().0.clone(),
        }
    }

    /// Full training state at the boundary after `completed` rounds.
    ///
    /// Round-boundary state is *sufficient* for bit-identical resume
    /// because every per-round draw (client sampling, fault plans) is a
    /// pure function of `(seed, round)` — only the stateful streams need
    /// to survive: each device's loader (shuffle position), link jitter
    /// RNG + lifetime byte/busy counters, and codec sampling RNG. Scratch
    /// buffers and pending steps are never live at a round boundary.
    fn checkpoint_state(
        &self,
        completed: usize,
        history: &TrainingHistory,
    ) -> Result<CheckpointState> {
        let devices = self
            .devices
            .iter()
            .map(|d| DeviceState {
                loader: d.loader.snapshot(),
                link: d.link.snapshot(),
                codec_rng: d.codec_rng.state_parts(),
            })
            .collect();
        let (client, server) = if let Some(res) = &self.resident {
            // fast path: weights live in the resident aggregate/server
            // slots — export as single flat tensors with the plan's shapes
            let plan = res.plan();
            let (cw, cm) = res.export_client_agg();
            let (sw, sm) = res.export_server();
            (
                ModelState {
                    params: vec![HostTensor::f32(&[plan.in_dim, plan.act_feat], cw)],
                    momentum: vec![HostTensor::f32(&[plan.in_dim, plan.act_feat], cm)],
                },
                ModelState {
                    params: vec![HostTensor::f32(&[plan.act_feat, plan.classes], sw)],
                    momentum: vec![HostTensor::f32(&[plan.act_feat, plan.classes], sm)],
                },
            )
        } else {
            let s = self.server.lock().unwrap();
            (
                ModelState {
                    params: self.client.0.clone(),
                    momentum: self.client.1.clone(),
                },
                ModelState {
                    params: s.0.clone(),
                    momentum: s.1.clone(),
                },
            )
        };
        // informational snapshot — resume rebuilds CommStats from the
        // restored links, this is for offline checkpoint inspection
        let mut comm = CommStats::default();
        for d in &self.devices {
            comm.accumulate(&d.link);
        }
        comm.makespan_s = self.makespan_total_s;
        Ok(CheckpointState {
            config_json: self.cfg.to_json().to_string(),
            config_fp: self.cfg.fingerprint(),
            completed_rounds: completed as u64,
            makespan_total_s: self.makespan_total_s,
            devices,
            client,
            server,
            history: history.rounds.clone(),
            comm,
        })
    }

    /// Write an atomic, checksummed checkpoint into `cfg.checkpoint_dir`
    /// and prune to the retention window.
    fn save_checkpoint(&self, round: usize, history: &TrainingHistory) -> Result<()> {
        let state = self.checkpoint_state(round, history)?;
        let path = checkpoint::save(&self.cfg.checkpoint_dir, &state, checkpoint::KEEP_LAST)?;
        crate::info!("checkpoint: round {round} -> {path}");
        Ok(())
    }

    /// Restore the newest checkpoint in `cfg.checkpoint_dir`, if any.
    ///
    /// Returns the number of completed rounds restored — `0` means a fresh
    /// start (missing or empty directory). Fails closed on torn/corrupt
    /// files (named errors from the checkpoint reader) and on a config
    /// fingerprint mismatch (named-key diff: resuming under a different
    /// config would silently change the experiment mid-run).
    pub fn resume_latest(&mut self) -> Result<usize> {
        anyhow::ensure!(
            !self.cfg.checkpoint_dir.is_empty(),
            "resume requires checkpoint_dir to be set"
        );
        let Some(path) = checkpoint::latest(&self.cfg.checkpoint_dir)? else {
            crate::info!(
                "resume: no checkpoint under {} — starting fresh",
                self.cfg.checkpoint_dir
            );
            return Ok(0);
        };
        let state = checkpoint::load(&path)?;
        if state.config_fp != self.cfg.fingerprint() {
            return Err(checkpoint::config_mismatch_error(&state.config_json, &self.cfg));
        }
        anyhow::ensure!(
            state.devices.len() == self.devices.len(),
            "checkpoint has {} devices, this run has {}",
            state.devices.len(),
            self.devices.len()
        );
        let completed = state.completed_rounds as usize;
        anyhow::ensure!(
            completed <= self.cfg.rounds,
            "checkpoint completed {} rounds but the config runs only {}",
            completed,
            self.cfg.rounds
        );
        anyhow::ensure!(
            state.history.len() == completed,
            "checkpoint history has {} rounds, its round counter says {}",
            state.history.len(),
            completed
        );

        // model state first (shape checks fail before anything mutates)
        if let Some(res) = &self.resident {
            anyhow::ensure!(
                state.client.params.len() == 1
                    && state.client.momentum.len() == 1
                    && state.server.params.len() == 1
                    && state.server.momentum.len() == 1,
                "checkpoint tensor arity does not match the resident session layout"
            );
            res.import_client_agg(
                state.client.params[0].as_f32()?,
                state.client.momentum[0].as_f32()?,
            )?;
            res.import_server(
                state.server.params[0].as_f32()?,
                state.server.momentum[0].as_f32()?,
            )?;
        } else {
            let check = |run: &[HostTensor], ckpt: &[HostTensor], what: &str| -> Result<()> {
                anyhow::ensure!(
                    run.len() == ckpt.len(),
                    "{what}: checkpoint has {} tensors, this run has {}",
                    ckpt.len(),
                    run.len()
                );
                for (r, c) in run.iter().zip(ckpt) {
                    anyhow::ensure!(
                        r.dims() == c.dims(),
                        "{what}: checkpoint tensor dims {:?} != this run's {:?}",
                        c.dims(),
                        r.dims()
                    );
                }
                Ok(())
            };
            check(&self.client.0, &state.client.params, "client params")?;
            check(&self.client.1, &state.client.momentum, "client momentum")?;
            {
                let mut guard = self.server.lock().unwrap();
                check(&guard.0, &state.server.params, "server params")?;
                check(&guard.1, &state.server.momentum, "server momentum")?;
                guard.0 = state.server.params.clone();
                guard.1 = state.server.momentum.clone();
            }
            self.client = (state.client.params.clone(), state.client.momentum.clone());
        }

        // per-device stateful streams (loader shuffle, link jitter +
        // lifetime counters, codec sampling)
        for (d, ds) in self.devices.iter_mut().zip(&state.devices) {
            anyhow::ensure!(
                ds.loader.indices.len() == d.shard_len,
                "device {}: checkpoint shard has {} samples, this run's has {}",
                d.id,
                ds.loader.indices.len(),
                d.shard_len
            );
            d.loader = BatchLoader::from_state(ds.loader.clone())?;
            d.link.restore(&ds.link);
            d.codec_rng = Pcg32::from_state_parts(ds.codec_rng.0, ds.codec_rng.1);
        }

        self.resume = Some(RestoredRun {
            completed,
            rounds: state.history,
            makespan_total_s: state.makespan_total_s,
        });
        crate::info!("resume: restored {completed} completed rounds from {path}");
        Ok(completed)
    }
}

/// The trainer's implementation of the scheduler-facing [`RoundOps`]
/// interface: device-local phases dispatch through the sharded worker
/// pool, the server step serializes on the shared server state.
///
/// Scheduler-side device ids are **participant-local** (`0..k` over this
/// round's sampled subset, in ascending global-id order); the mapping to
/// the trainer's device table goes through `participants`. With sampling
/// off, `participants` is the identity and the mapping disappears.
struct TrainerRoundOps<'a> {
    devices: &'a mut [DeviceCtx],
    /// Global device ids participating this round, ascending.
    participants: &'a [usize],
    /// Per-round completion mask over **global** device ids (owned by the
    /// trainer, round-persistent). Participants enter `true`;
    /// [`RoundOps::cancel`] retracts.
    completed: &'a mut [bool],
    /// Round-persistent participant-local → global index staging for the
    /// sharded batch dispatch (`engine::run_sharded_indexed`).
    idx: &'a mut Vec<usize>,
    exec: &'a ExecutorHandle,
    codec: &'a dyn ActivationCodec,
    cfg: &'a ExperimentConfig,
    preset: &'a str,
    train: &'a Dataset,
    server: &'a Mutex<(Vec<HostTensor>, Vec<HostTensor>)>,
    /// Device-resident fast path (None routes through `exec`).
    resident: Option<&'a ResidentSession>,
    workers: usize,
    /// This round's fault plan (`None` = fault layer off → schedulers take
    /// the legacy bit-identical paths). Draws are keyed by
    /// **participant-local** device ids, like every other scheduler-side
    /// id; with sampling off, local and global ids coincide.
    fault: Option<FaultPlan>,
}

impl TrainerRoundOps<'_> {
    /// Stage the global ids behind a participant-local batch into the
    /// round-persistent index buffer (duplicates are a scheduler bug —
    /// debug-asserted inside `run_sharded_indexed`).
    fn stage_idx(&mut self, devs: &[DeviceId]) {
        let participants = self.participants;
        self.idx.clear();
        self.idx.extend(devs.iter().map(|&d| participants[d]));
    }

    /// The device behind a participant-local id.
    fn dev(&self, local: DeviceId) -> &DeviceCtx {
        &self.devices[self.participants[local]]
    }
}

impl RoundOps for TrainerRoundOps<'_> {
    fn n_devices(&self) -> usize {
        self.participants.len()
    }

    fn steps(&self) -> usize {
        self.cfg.batches_per_round
    }

    fn compute_s(&self, dev: DeviceId) -> f64 {
        self.cfg.base_compute_s * self.dev(dev).profile.compute_mult
    }

    fn server_service_s(&self) -> f64 {
        self.cfg.server_service_s
    }

    fn shared_uplink_bps(&self) -> Option<f64> {
        match self.cfg.uplink {
            UplinkMode::Private => None,
            UplinkMode::Shared => Some(self.cfg.shared_capacity_bps()),
        }
    }

    fn uplink_latency_s(&self, dev: DeviceId) -> f64 {
        self.dev(dev).profile.link.latency_s
    }

    fn charge_uplink(&mut self, dev: DeviceId, busy_s: f64) {
        self.devices[self.participants[dev]]
            .link
            .charge(Direction::Uplink, 0, busy_s);
    }

    fn shared_downlink_bps(&self) -> Option<f64> {
        match self.cfg.downlink {
            DownlinkMode::Private => None,
            DownlinkMode::Shared => Some(self.cfg.shared_downlink_capacity_bps()),
        }
    }

    fn downlink_latency_s(&self, dev: DeviceId) -> f64 {
        self.dev(dev).profile.link.latency_s
    }

    fn charge_downlink(&mut self, dev: DeviceId, busy_s: f64) {
        self.devices[self.participants[dev]]
            .link
            .charge(Direction::Downlink, 0, busy_s);
    }

    fn cohorts(&self) -> usize {
        self.cfg.cohorts
    }

    fn fanout(&mut self, devs: &[DeviceId], out: &mut Vec<UplinkMsg>) -> Result<()> {
        let exec = self.exec;
        let codec = self.codec;
        let cfg = self.cfg;
        let preset = self.preset;
        let train = self.train;
        let resident = self.resident;
        let workers = self.workers;
        self.stage_idx(devs);
        out.clear();
        out.resize(
            devs.len(),
            UplinkMsg {
                wire_bytes: 0,
                cost_s: 0.0,
            },
        );
        engine::run_sharded_indexed(
            &mut *self.devices,
            &self.idx[..],
            &mut out[..],
            workers,
            |_, dev| device_fanout_impl(dev, resident, exec, codec, cfg, preset, train),
        )
    }

    fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut> {
        // legacy contract: a decode failure aborts the round (fault-free
        // configs never hit this — corrupted payloads only exist under an
        // active plan, which routes through `server_step_checked` instead)
        server_step_impl(
            &mut self.devices[self.participants[dev]],
            self.resident,
            self.exec,
            self.codec,
            self.cfg,
            self.preset,
            self.server,
        )?
        .ok_or_else(|| anyhow::anyhow!("corrupt uplink payload on device {dev}"))
    }

    fn server_step_checked(&mut self, dev: DeviceId) -> Result<ServerStep> {
        // fail-closed: a decode failure fails only this device (the
        // scheduler counts it and drops the device); every other device's
        // round is untouched
        Ok(
            match server_step_impl(
                &mut self.devices[self.participants[dev]],
                self.resident,
                self.exec,
                self.codec,
                self.cfg,
                self.preset,
                self.server,
            )? {
                Some(out) => ServerStep::Served(out),
                None => ServerStep::Corrupt,
            },
        )
    }

    fn fanin(&mut self, devs: &[DeviceId]) -> Result<()> {
        let exec = self.exec;
        let codec = self.codec;
        let cfg = self.cfg;
        let preset = self.preset;
        let resident = self.resident;
        let workers = self.workers;
        self.stage_idx(devs);
        // zero-sized results: `vec![(); n]` never touches the heap
        let mut units = vec![(); devs.len()];
        engine::run_sharded_indexed(
            &mut *self.devices,
            &self.idx[..],
            &mut units[..],
            workers,
            |_, dev| device_fanin_impl(dev, resident, exec, codec, cfg, preset),
        )
    }

    fn cancel(&mut self, dev: DeviceId) {
        let global = self.participants[dev];
        self.devices[global].pending = None;
        self.completed[global] = false;
    }

    fn fault_plan(&self) -> Option<FaultPlan> {
        self.fault
    }

    fn corrupt_uplink(&mut self, dev: DeviceId, step: usize, attempt: u32) {
        // Inject the plan's seeded bit flips into the pending uplink body
        // and drive the decoder over the corrupted bytes — the live-path
        // proof that decode fails *closed* (an `Err` or garbage output,
        // never a panic or a round abort). The clean body is restored
        // afterwards: a retransmission resends the original payload.
        let Some(plan) = self.fault else { return };
        let d = &mut self.devices[self.participants[dev]];
        let Some(pending) = d.pending.as_mut() else { return };
        if pending.uplink.body.is_empty() {
            return;
        }
        d.clean_body.clear();
        d.clean_body.extend_from_slice(&pending.uplink.body);
        let n_bits = pending.uplink.body.len() * 8;
        for i in 0..CORRUPT_FLIPS {
            let bit = plan.flip_bit(dev, step, attempt, i, n_bits);
            pending.uplink.body[bit / 8] ^= 1 << (bit % 8);
        }
        let _ = self
            .codec
            .decompress_into(&pending.uplink, &mut d.scratch, &mut d.decode);
        pending.uplink.body.clear();
        pending.uplink.body.extend_from_slice(&d.clean_body);
    }

    fn charge_retransmit_uplink(&mut self, dev: DeviceId, bytes: usize, busy_s: f64) {
        self.devices[self.participants[dev]]
            .link
            .charge(Direction::Uplink, bytes, busy_s);
    }

    fn charge_retransmit_downlink(&mut self, dev: DeviceId, bytes: usize, busy_s: f64) {
        self.devices[self.participants[dev]]
            .link
            .charge(Direction::Downlink, bytes, busy_s);
    }
}

/// Fan-out body (shared by all modes): client forward + codec encode +
/// uplink charge (private mode only — in shared-uplink mode the scheduler
/// charges the link once the fair-share model decides the duration).
/// Returns the payload's wire size and the private-mode transfer seconds.
///
/// With a resident session the forward runs on the device slot (weights in
/// place, activations stashed for the backward) and the batch stays in the
/// device's reusable buffers — zero steady-state allocations. Without one,
/// the historical artifact `execute` path runs; both produce bit-identical
/// wire bytes.
fn device_fanout_impl(
    dev: &mut DeviceCtx,
    resident: Option<&ResidentSession>,
    exec: &ExecutorHandle,
    codec: &dyn ActivationCodec,
    cfg: &ExperimentConfig,
    preset: &str,
    train: &Dataset,
) -> Result<UplinkMsg> {
    let freq = codec.frequency_domain();
    // zero-allocation steady state: recycled body + per-device scratch
    // arena (bit-identical to `compress_with_rng` — the codec contract)
    let mut payload = Payload::empty();
    payload.body = dev.scratch.take_body();
    let (x, y) = if let Some(res) = resident {
        dev.loader
            .next_batch_into(train, &mut dev.x_buf, &mut dev.y_buf);
        res.client_fwd(dev.id, &dev.x_buf, freq, &mut dev.wire)?;
        codec.compress_into(&dev.wire, &mut dev.codec_rng, &mut dev.scratch, &mut payload)?;
        (None, None)
    } else {
        let (images, labels) = dev.loader.next_batch(train);
        let x = HostTensor::f32(
            &[cfg.batch_size, train.channels, train.height, train.width],
            images,
        );
        let y = HostTensor::i32(
            &[cfg.batch_size],
            labels.into_iter().map(|l| l as i32).collect(),
        );
        let mut inputs: Vec<HostTensor> = dev.cp.iter().cloned().collect();
        inputs.push(x.clone());
        let mut out = exec.execute(preset, "client_fwd", inputs)?.into_iter();
        let act = out.next().context("act output")?;
        let act_dct = out.next().context("act_dct output")?;
        let wire_input: Tensor = if freq {
            act_dct.into_tensor()?
        } else {
            act.into_tensor()?
        };
        codec.compress_into(&wire_input, &mut dev.codec_rng, &mut dev.scratch, &mut payload)?;
        (Some(x), Some(y))
    };
    let wire_bytes = payload.wire_bytes();
    let cost_s = match cfg.uplink {
        UplinkMode::Private => dev.link.transfer(Direction::Uplink, wire_bytes),
        UplinkMode::Shared => {
            // charge-at-send, exactly like the private path: the bytes
            // count even if a deadline later abandons the flow mid-pipe.
            // Occupancy seconds are charged when the fair-share model
            // drains the flow (RoundOps::charge_uplink).
            dev.link.charge(Direction::Uplink, wire_bytes, 0.0);
            0.0
        }
    };
    dev.pending = Some(StepCtx {
        x,
        y,
        uplink: payload,
        grad: None,
    });
    Ok(UplinkMsg { wire_bytes, cost_s })
}

/// Server-step body (shared by all modes): decompress the pending uplink,
/// run the server training step, compress + charge the downlink gradient.
///
/// Returns `Ok(None)` when the uplink payload fails to decode (corrupted
/// bytes that escaped the transport checksum): the device's pending step
/// is left intact — nothing is consumed, no server state is touched — and
/// the caller decides between retransmit/drop (`server_step_checked`) and
/// the legacy round abort (`server_step`).
///
/// With a resident session the step updates `W_s`/`M_s` in place on the
/// server slot (fused softmax, maintained `W_sᵀ` for the activation
/// gradient) and stages the downlink gradient in the device's reusable
/// `wire` tensor; the artifact path round-trips full parameter tensors.
fn server_step_impl(
    dev: &mut DeviceCtx,
    resident: Option<&ResidentSession>,
    exec: &ExecutorHandle,
    codec: &dyn ActivationCodec,
    cfg: &ExperimentConfig,
    preset: &str,
    server: &Mutex<(Vec<HostTensor>, Vec<HostTensor>)>,
) -> Result<Option<ServerOut>> {
    let freq = codec.frequency_domain();
    let step = dev.pending.as_mut().context("phase order violation")?;

    // decompress uplink → activations (into the reusable decode target),
    // then recycle the payload body for the gradient below. Fail closed on
    // a decode error: the pending payload stays untouched for the caller's
    // retransmit/drop decision, and no other device is affected.
    if let Err(e) = codec.decompress_into(&step.uplink, &mut dev.scratch, &mut dev.decode) {
        crate::warn!("device {}: uplink decode failed: {e:#}", dev.id);
        return Ok(None);
    }
    dev.scratch.recycle_body(std::mem::take(&mut step.uplink.body));

    if let Some(res) = resident {
        let act: &Tensor = if freq {
            res.idct(dev.id, &dev.decode, &mut dev.spatial)?;
            &dev.spatial
        } else {
            &dev.decode
        };
        // the gradient travels in the codec's domain when compressed,
        // spatially when raw — exactly like the artifact path
        let freq_grad = cfg.compress_gradients && freq;
        let (loss_f32, correct) =
            res.server_step(act, &dev.y_buf, cfg.lr, freq_grad, &mut dev.wire)?;
        let batch = dev.y_buf.len() as u64;
        let (downlink_s, wire_bytes) = if cfg.compress_gradients {
            let mut payload = Payload::empty();
            payload.body = dev.scratch.take_body();
            codec.compress_into(&dev.wire, &mut dev.codec_rng, &mut dev.scratch, &mut payload)?;
            let wire = payload.wire_bytes();
            let t = downlink_send(dev, cfg, wire);
            step.grad = Some(GradMsg::Compressed(payload));
            (t, wire)
        } else {
            let wire = dev.wire.numel() * 4;
            let t = downlink_send(dev, cfg, wire);
            step.grad = Some(GradMsg::Stashed);
            (t, wire)
        };
        return Ok(Some(ServerOut {
            downlink_s,
            wire_bytes,
            loss: loss_f32 as f64,
            correct,
            samples: batch,
        }));
    }

    let act = if freq {
        let out = exec.execute(
            preset,
            "idct",
            vec![HostTensor::from_tensor(&dev.decode)],
        )?;
        out.into_iter().next().context("idct output")?
    } else {
        HostTensor::from_tensor(&dev.decode)
    };

    // server training step
    let y = step.y.as_ref().context("reference step without labels")?;
    let mut guard = server.lock().unwrap();
    let (sp, sm) = &mut *guard;
    let n_s = sp.len();
    let mut inputs = Vec::with_capacity(2 * n_s + 3);
    inputs.extend(sp.iter().cloned());
    inputs.extend(sm.iter().cloned());
    inputs.push(act);
    inputs.push(y.clone());
    inputs.push(HostTensor::scalar_f32(cfg.lr));
    let mut out = exec
        .execute(preset, "server_step", inputs)?
        .into_iter();
    let new_sp: Vec<HostTensor> = (&mut out).take(n_s).collect();
    let new_sm: Vec<HostTensor> = (&mut out).take(n_s).collect();
    let loss = out.next().context("loss output")?.first();
    let correct = out.next().context("correct output")?.first() as u64;
    let gact = out.next().context("gact output")?;
    let gact_dct = out.next().context("gact_dct output")?;
    *sp = new_sp;
    *sm = new_sm;
    drop(guard);

    // downlink gradient
    let batch = y.numel() as u64;
    let (downlink_s, wire_bytes) = if cfg.compress_gradients {
        let g = if freq { gact_dct } else { gact };
        let mut payload = Payload::empty();
        payload.body = dev.scratch.take_body();
        codec.compress_into(
            &g.into_tensor()?,
            &mut dev.codec_rng,
            &mut dev.scratch,
            &mut payload,
        )?;
        let wire = payload.wire_bytes();
        let t = downlink_send(dev, cfg, wire);
        step.grad = Some(GradMsg::Compressed(payload));
        (t, wire)
    } else {
        let wire = gact.raw_bytes();
        let t = downlink_send(dev, cfg, wire);
        step.grad = Some(GradMsg::Raw(gact));
        (t, wire)
    };
    Ok(Some(ServerOut {
        downlink_s,
        wire_bytes,
        loss,
        correct,
        samples: batch,
    }))
}

/// Downlink send accounting, symmetric to the uplink side of
/// [`device_fanout_impl`]: private mode charges the device link for the
/// full transfer and returns its duration; `downlink = "shared"` mode
/// charges the bytes at send time (they count even if a deadline later
/// abandons the flow mid-pipe) and returns `0.0` — the fair-share model
/// decides the duration and the scheduler adds the occupancy seconds at
/// drain via [`RoundOps::charge_downlink`].
fn downlink_send(dev: &mut DeviceCtx, cfg: &ExperimentConfig, wire_bytes: usize) -> f64 {
    match cfg.downlink {
        DownlinkMode::Private => dev.link.transfer(Direction::Downlink, wire_bytes),
        DownlinkMode::Shared => {
            dev.link.charge(Direction::Downlink, wire_bytes, 0.0);
            0.0
        }
    }
}

/// Fan-in body (shared by all modes): gradient decode + client backward.
///
/// With a resident session the backward runs on the device slot: `dz` from
/// the stashed forward activations (no forward recompute), `gW_c`, and an
/// in-place SGD update — no parameter tensors cross the call.
fn device_fanin_impl(
    dev: &mut DeviceCtx,
    resident: Option<&ResidentSession>,
    exec: &ExecutorHandle,
    codec: &dyn ActivationCodec,
    cfg: &ExperimentConfig,
    preset: &str,
) -> Result<()> {
    let step = dev.pending.take().context("phase order violation")?;
    let grad = step.grad.context("server step did not run")?;

    if let Some(res) = resident {
        match grad {
            GradMsg::Compressed(mut p) => {
                codec.decompress_into(&p, &mut dev.scratch, &mut dev.decode)?;
                dev.scratch.recycle_body(std::mem::take(&mut p.body));
                if codec.frequency_domain() {
                    res.idct(dev.id, &dev.decode, &mut dev.spatial)?;
                    res.client_step(dev.id, &dev.x_buf, &dev.spatial, cfg.lr)?;
                } else {
                    res.client_step(dev.id, &dev.x_buf, &dev.decode, cfg.lr)?;
                }
            }
            // uncompressed gradient: the spatial gact is still staged in
            // the device's wire tensor
            GradMsg::Stashed => {
                res.client_step(dev.id, &dev.x_buf, &dev.wire, cfg.lr)?;
            }
            GradMsg::Raw(_) => anyhow::bail!("raw HostTensor gradient on the resident path"),
        }
        return Ok(());
    }

    let gact = match grad {
        GradMsg::Raw(g) => g,
        GradMsg::Compressed(mut p) => {
            codec.decompress_into(&p, &mut dev.scratch, &mut dev.decode)?;
            dev.scratch.recycle_body(std::mem::take(&mut p.body));
            if codec.frequency_domain() {
                exec.execute(preset, "idct", vec![HostTensor::from_tensor(&dev.decode)])?
                    .into_iter()
                    .next()
                    .context("idct output")?
            } else {
                HostTensor::from_tensor(&dev.decode)
            }
        }
        GradMsg::Stashed => anyhow::bail!("stashed gradient on the reference path"),
    };
    let n_c = dev.cp.len();
    let mut inputs = Vec::with_capacity(2 * n_c + 3);
    inputs.extend(dev.cp.iter().cloned());
    inputs.extend(dev.cm.iter().cloned());
    inputs.push(step.x.context("reference step without batch tensor")?);
    inputs.push(gact);
    inputs.push(HostTensor::scalar_f32(cfg.lr));
    let mut out = exec.execute(preset, "client_step", inputs)?.into_iter();
    dev.cp = (&mut out).take(n_c).collect();
    dev.cm = out.collect();
    anyhow::ensure!(dev.cm.len() == n_c, "client_step output arity");
    Ok(())
}
