//! FedAvg aggregation of client-side sub-models (SplitFed protocol).
//!
//! The paper runs 5 devices but does not spell out the client-weight sync;
//! SplitFed-style FedAvg each round is the standard multi-device SL
//! protocol (DESIGN.md §3). Weights are averaged proportionally to shard
//! sizes so unbalanced non-IID partitions do not bias toward small shards.
//!
//! **Order-stable reduction:** each parameter's accumulator always folds
//! devices in id order (`d0, d1, …`), so the f64 sums — and therefore the
//! rounded f32 results — are bit-identical no matter how many worker
//! threads [`fedavg_sharded`] spreads the *parameters* across.

use super::engine;
use crate::runtime::HostTensor;
use anyhow::{ensure, Result};

/// Weighted average of per-device flat parameter lists.
///
/// `per_device[d]` is device `d`'s parameter list; `weights[d]` its
/// aggregation weight (e.g. shard size). All lists must be congruent.
pub fn fedavg(per_device: &[Vec<HostTensor>], weights: &[f64]) -> Result<Vec<HostTensor>> {
    fedavg_sharded(per_device, weights, 1)
}

/// [`fedavg`], sharding independent parameter tensors across up to
/// `workers` threads. Bit-identical to `workers = 1` for every worker
/// count (each parameter is computed independently with a fixed
/// device-order fold).
pub fn fedavg_sharded(
    per_device: &[Vec<HostTensor>],
    weights: &[f64],
    workers: usize,
) -> Result<Vec<HostTensor>> {
    ensure!(!per_device.is_empty(), "fedavg over zero devices");
    ensure!(per_device.len() == weights.len(), "weights/devices mismatch");
    let total: f64 = weights.iter().sum();
    ensure!(total > 0.0, "fedavg with zero total weight");
    let n_params = per_device[0].len();
    for (d, params) in per_device.iter().enumerate() {
        ensure!(
            params.len() == n_params,
            "device {d} has {} params, expected {n_params}",
            params.len()
        );
    }

    let mut out: Vec<Option<HostTensor>> = (0..n_params).map(|_| None).collect();
    engine::run_sharded(&mut out, workers, |i, slot| {
        let dims = per_device[0][i].dims().to_vec();
        let mut acc = vec![0.0f64; per_device[0][i].numel()];
        for (params, &w) in per_device.iter().zip(weights) {
            ensure!(
                params[i].dims() == dims.as_slice(),
                "param {i} shape mismatch across devices"
            );
            let frac = w / total;
            for (a, &v) in acc.iter_mut().zip(params[i].as_f32()?) {
                *a += frac * v as f64;
            }
        }
        *slot = Some(HostTensor::f32(
            &dims,
            acc.into_iter().map(|v| v as f32).collect(),
        ));
        Ok(())
    })?;
    Ok(out
        .into_iter()
        .map(|t| t.expect("every param slot filled"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[f32]) -> Vec<HostTensor> {
        vec![HostTensor::f32(&[vals.len()], vals.to_vec())]
    }

    #[test]
    fn equal_weights_is_plain_mean() {
        let avg = fedavg(&[p(&[1.0, 2.0]), p(&[3.0, 4.0])], &[1.0, 1.0]).unwrap();
        assert_eq!(avg[0].as_f32().unwrap(), &[2.0, 3.0]);
    }

    #[test]
    fn weighted_mean() {
        let avg = fedavg(&[p(&[0.0]), p(&[10.0])], &[3.0, 1.0]).unwrap();
        assert!((avg[0].as_f32().unwrap()[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn single_device_identity() {
        let avg = fedavg(&[p(&[5.0, -1.0])], &[7.0]).unwrap();
        assert_eq!(avg[0].as_f32().unwrap(), &[5.0, -1.0]);
    }

    #[test]
    fn rejects_mismatches() {
        assert!(fedavg(&[], &[]).is_err());
        assert!(fedavg(&[p(&[1.0])], &[1.0, 2.0]).is_err());
        assert!(fedavg(&[p(&[1.0]), p(&[1.0, 2.0])], &[1.0, 1.0]).is_err());
        assert!(fedavg(&[p(&[1.0]), p(&[2.0])], &[0.0, 0.0]).is_err());
    }

    #[test]
    fn sharded_is_bit_identical_to_sequential() {
        let mut g = crate::rng::Pcg32::seeded(314);
        let devices = 5;
        let n_params = 9;
        let per: Vec<Vec<HostTensor>> = (0..devices)
            .map(|_| {
                (0..n_params)
                    .map(|p| {
                        let n = 3 + p;
                        HostTensor::f32(&[n], (0..n).map(|_| g.normal()).collect())
                    })
                    .collect()
            })
            .collect();
        let weights: Vec<f64> = (1..=devices).map(|d| d as f64).collect();
        let reference = fedavg_sharded(&per, &weights, 1).unwrap();
        for workers in [2, 3, 8] {
            let got = fedavg_sharded(&per, &weights, workers).unwrap();
            assert_eq!(got.len(), reference.len());
            for (a, b) in got.iter().zip(&reference) {
                let ab: Vec<u32> = a.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "workers={workers}");
            }
        }
    }

    #[test]
    fn property_average_within_bounds() {
        crate::testing::prop("fedavg bounds", 50, |g| {
            let devices = g.usize_in(1, 6);
            let n = g.usize_in(1, 20);
            let per: Vec<Vec<HostTensor>> = (0..devices)
                .map(|_| vec![HostTensor::f32(&[n], g.normal_vec(n))])
                .collect();
            let weights: Vec<f64> = (0..devices)
                .map(|_| 0.1 + g.f32_in(0.0, 5.0) as f64)
                .collect();
            let avg = fedavg(&per, &weights).unwrap();
            for i in 0..n {
                let vals: Vec<f32> = per.iter().map(|d| d[0].as_f32().unwrap()[i]).collect();
                let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let a = avg[0].as_f32().unwrap()[i];
                assert!(a >= lo - 1e-4 && a <= hi + 1e-4);
            }
        });
    }
}
