//! The sharded, thread-parallel round engine.
//!
//! Device state (`DeviceCtx` in [`super::trainer`]) is split into
//! contiguous shards across a small pool of **scoped worker threads** (no
//! external thread-pool dependency): with `W` workers and `N` devices,
//! worker `w` owns devices `[w·⌈N/W⌉, (w+1)·⌈N/W⌉)` exclusively for the
//! duration of one phase. Phases that are embarrassingly parallel across
//! devices (client forward + encode + uplink; gradient decode + client
//! backward) run through [`run_sharded`]; the server step and aggregation
//! remain explicit barriers executed in device-id order by the caller.
//!
//! # Determinism contract
//!
//! A parallel run must be **bit-identical** to the sequential run at the
//! same seed. The engine guarantees its part of that contract by
//! construction:
//!
//! * each device's mutable state (loader RNG, link accounting, codec RNG
//!   stream, pending step) is owned by exactly one worker per phase — no
//!   shared mutable state, so no interleaving effects;
//! * all randomness consumed inside a phase comes from per-device streams
//!   derived from the root seed ([`crate::rng::derive_seed`]), never from
//!   a generator shared across devices;
//! * error reporting is order-stable: the failure surfaced to the caller
//!   is always the one from the lowest device id, regardless of which
//!   worker hit an error first.
//!
//! Reductions over per-device results (loss sums, byte counts, FedAvg)
//! are performed by the caller *after* the phase barrier, iterating in
//! device-id order — see [`super::aggregate`] and the trainer's
//! round-metrics accounting. The transport-layer round schedulers
//! ([`crate::transport::scheduler`]) dispatch their device batches through
//! [`run_sharded`] too, so the same bit-transparency argument covers the
//! event-driven async mode: batch *composition* comes from deterministic
//! event order, batch *execution* from this pool.
//!
//! # Scratch-arena ownership
//!
//! The codec hot path is allocation-free in steady state because each
//! `DeviceCtx` owns a [`crate::codec::CodecScratch`] arena (work buffers +
//! recycled payload bodies) threaded through
//! `ActivationCodec::{compress_into, decompress_into}`. The arena rides
//! inside the device item handed to [`run_sharded`], so the exclusive-
//! ownership guarantee above covers it: one worker per phase, no sharing,
//! no locks. Arena *contents* are write-before-read by contract (every
//! buffer fully overwritten before use), so reuse across phases, rounds,
//! or worker counts can never perturb results — `parallel_determinism.rs`
//! pins this differentially (same bytes for `workers = 1/4/0` and for
//! fresh-vs-reused arenas).
//!
//! The compute fast path extends the same discipline to **model state**:
//! each device's weights/momenta/forward stash live in a per-device slot
//! of the shared [`crate::runtime::ResidentSession`] (its own mutex,
//! uncontended because of the shard ownership above), and the server slot
//! is only touched from the serial `server_step` phase. Slot scratch is
//! write-before-read like the codec arenas, so `compute_fast_path` ×
//! worker count is bit-transparent too — same differential pin.

use anyhow::Result;

/// Resolve a configured worker count: `0` means "one worker per available
/// CPU", and the result is clamped to `[1, devices]`. The resolved value
/// affects wall-clock only, never results.
pub fn effective_workers(configured: usize, devices: usize) -> usize {
    let auto = || {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    };
    let w = if configured == 0 { auto() } else { configured };
    w.clamp(1, devices.max(1))
}

/// Run `f(index, &mut item)` over every item, sharded across at most
/// `workers` scoped threads. Barrier semantics: returns only after every
/// item has been processed. With `workers <= 1` (or a single item) the
/// loop runs inline on the caller's thread — zero spawn overhead, and the
/// exact code path a sequential run takes.
///
/// Errors: every item is still visited regardless of the worker count (a
/// failing item does not poison its shard-mates, and side effects — RNG
/// advances, link accounting — stay identical across worker counts even
/// on failure paths); the error returned is the one with the **lowest
/// index**, so failure reporting does not depend on scheduling. Items are
/// domain-neutral (the trainer shards devices, FedAvg shards parameters),
/// so the context label is `item {i}`.
pub fn run_sharded<T, F>(items: &mut [T], workers: usize, f: F) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut T) -> Result<()> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(());
    }
    let w = workers.clamp(1, n);
    if w == 1 {
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for (i, item) in items.iter_mut().enumerate() {
            if let Err(e) = f(i, item) {
                first_err.get_or_insert((i, e));
            }
        }
        return match first_err {
            Some((i, e)) => Err(e.context(format!("item {i}"))),
            None => Ok(()),
        };
    }

    let chunk = (n + w - 1) / w;
    let f = &f;
    let mut failures: Vec<(usize, anyhow::Error)> = std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, shard)| {
                s.spawn(move || {
                    let base = ci * chunk;
                    let mut errs = Vec::new();
                    for (j, item) in shard.iter_mut().enumerate() {
                        if let Err(e) = f(base + j, item) {
                            errs.push((base + j, e));
                        }
                    }
                    errs
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("round-engine worker panicked"))
            .collect()
    });
    failures.sort_by_key(|(i, _)| *i);
    match failures.into_iter().next() {
        Some((i, e)) => Err(e.context(format!("item {i}"))),
        None => Ok(()),
    }
}

/// Covariant raw-pointer wrapper that lets scoped workers take disjoint
/// `&mut` borrows of a slice through an index list. Safe only under the
/// duplicate-free contract checked in [`run_sharded_indexed`].
struct Ptr<T>(*mut T);

impl<T> Clone for Ptr<T> {
    fn clone(&self) -> Self {
        Ptr(self.0)
    }
}
impl<T> Copy for Ptr<T> {}

// SAFETY: the pointer is only dereferenced at indices proven distinct
// across workers (bounds- and duplicate-checked by the caller contract),
// so sending a copy to each scoped worker creates no aliasing.
unsafe impl<T: Send> Send for Ptr<T> {}

/// Like [`run_sharded`], but over an **index list** into `items`:
/// `f(k, &mut items[idx[k]])` runs for every position `k`, and its result
/// lands in `out[k]`. This is the zero-allocation batch dispatch the
/// trainer's fan-out uses — the scheduler hands it an arbitrary device
/// subset (event-ordered, not contiguous), and both `idx` and `out` are
/// round-persistent buffers, so no per-batch `Vec` is built.
///
/// Contract: `idx` entries must be in-bounds (asserted) and pairwise
/// distinct — duplicates would alias `&mut` across workers. Distinctness
/// is debug-asserted with an O(k) strictly-increasing fast path (the
/// common case: batches are built in ascending device order) and an
/// allocation-free O(k²) pair scan otherwise.
///
/// Error semantics match [`run_sharded`]: every position is visited
/// regardless of worker count, and the error surfaced is the one at the
/// **lowest position**, labeled `item {k}`. `out[k]` is untouched for a
/// failing position.
pub fn run_sharded_indexed<T, R, F>(
    items: &mut [T],
    idx: &[usize],
    out: &mut [R],
    workers: usize,
    f: F,
) -> Result<()>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> Result<R> + Sync,
{
    let k = idx.len();
    assert_eq!(out.len(), k, "out buffer must be as long as the index list");
    let n = items.len();
    for &i in idx {
        assert!(i < n, "index {i} out of bounds for {n} items");
    }
    if cfg!(debug_assertions) && !idx.windows(2).all(|w| w[0] < w[1]) {
        for a in 0..k {
            for b in a + 1..k {
                assert_ne!(idx[a], idx[b], "duplicate index {}", idx[a]);
            }
        }
    }
    if k == 0 {
        return Ok(());
    }
    let w = workers.clamp(1, k);
    if w == 1 {
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        for (j, (&i, slot)) in idx.iter().zip(out.iter_mut()).enumerate() {
            match f(j, &mut items[i]) {
                Ok(r) => *slot = r,
                Err(e) => {
                    first_err.get_or_insert((j, e));
                }
            }
        }
        return match first_err {
            Some((j, e)) => Err(e.context(format!("item {j}"))),
            None => Ok(()),
        };
    }

    let chunk = (k + w - 1) / w;
    let base = Ptr(items.as_mut_ptr());
    let f = &f;
    let mut failures: Vec<(usize, anyhow::Error)> = std::thread::scope(|s| {
        let handles: Vec<_> = idx
            .chunks(chunk)
            .zip(out.chunks_mut(chunk))
            .enumerate()
            .map(|(ci, (ishard, oshard))| {
                s.spawn(move || {
                    let start = ci * chunk;
                    let mut errs = Vec::new();
                    for (j, (&i, slot)) in ishard.iter().zip(oshard.iter_mut()).enumerate() {
                        // SAFETY: `i` is bounds-checked above, and the
                        // duplicate-free contract makes this the only
                        // `&mut` to `items[i]` across all workers.
                        let item = unsafe { &mut *base.0.add(i) };
                        match f(start + j, item) {
                            Ok(r) => *slot = r,
                            Err(e) => errs.push((start + j, e)),
                        }
                    }
                    errs
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("round-engine worker panicked"))
            .collect()
    });
    failures.sort_by_key(|(j, _)| *j);
    match failures.into_iter().next() {
        Some((j, e)) => Err(e.context(format!("item {j}"))),
        None => Ok(()),
    }
}

/// Compile-time guard: types crossing the engine's thread boundary. The
/// phase closures are shared by reference across workers, so the executor
/// handle must be `Sync` too (true since Rust 1.72, where
/// `mpsc::Sender: Sync`).
#[allow(dead_code)]
fn assert_engine_types_are_send() {
    fn is_send<T: Send>() {}
    fn is_sync<T: Sync>() {}
    is_send::<crate::net::Link>();
    is_send::<crate::codec::Payload>();
    is_send::<crate::runtime::HostTensor>();
    is_send::<crate::runtime::ExecutorHandle>();
    is_sync::<crate::runtime::ExecutorHandle>();
    // the resident session is shared by reference across the phase workers
    is_sync::<crate::runtime::ResidentSession>();
    is_send::<crate::data::BatchLoader>();
    is_send::<crate::rng::Pcg32>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_item_exactly_once_any_worker_count() {
        for workers in [1, 2, 3, 4, 7, 16] {
            let mut items: Vec<usize> = vec![0; 11];
            run_sharded(&mut items, workers, |i, item| {
                *item += i + 1;
                Ok(())
            })
            .unwrap();
            let want: Vec<usize> = (1..=11).collect();
            assert_eq!(items, want, "workers={workers}");
        }
    }

    #[test]
    fn ids_match_slice_positions() {
        let mut items: Vec<usize> = (0..23).collect();
        run_sharded(&mut items, 4, |i, item| {
            assert_eq!(i, *item);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn empty_and_single_item_work() {
        let mut none: Vec<u8> = vec![];
        run_sharded(&mut none, 4, |_, _| Ok(())).unwrap();
        let mut one = vec![5u8];
        run_sharded(&mut one, 4, |_, v| {
            *v = 9;
            Ok(())
        })
        .unwrap();
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn lowest_item_error_wins_regardless_of_workers() {
        for workers in [1, 2, 4, 8] {
            let mut items = vec![(); 8];
            let err = run_sharded(&mut items, workers, |i, _| {
                if i == 2 || i == 6 {
                    anyhow::bail!("boom {i}")
                }
                Ok(())
            })
            .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("item 2"), "workers={workers}: {msg}");
            assert!(msg.contains("boom 2"), "workers={workers}: {msg}");
        }
    }

    #[test]
    fn all_items_visited_even_when_some_fail() {
        // identical visit counts sequential and parallel: error paths must
        // not make side effects depend on the worker count
        for workers in [1, 3] {
            let count = AtomicUsize::new(0);
            let mut items = vec![(); 10];
            let _ = run_sharded(&mut items, workers, |i, _| {
                count.fetch_add(1, Ordering::Relaxed);
                if i % 2 == 0 {
                    anyhow::bail!("even")
                }
                Ok(())
            });
            assert_eq!(count.load(Ordering::Relaxed), 10, "workers={workers}");
        }
    }

    #[test]
    fn really_runs_concurrently_with_multiple_workers() {
        use std::sync::atomic::AtomicBool;
        use std::time::{Duration, Instant};
        // two workers must overlap: each item waits until *both* shards
        // have started (with a timeout so a regression fails, not hangs)
        static STARTED: AtomicUsize = AtomicUsize::new(0);
        static OVERLAPPED: AtomicBool = AtomicBool::new(false);
        STARTED.store(0, Ordering::SeqCst);
        let mut items = vec![(); 2];
        run_sharded(&mut items, 2, |_, _| {
            STARTED.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_secs(5) {
                if STARTED.load(Ordering::SeqCst) == 2 {
                    OVERLAPPED.store(true, Ordering::SeqCst);
                    return Ok(());
                }
                std::thread::yield_now();
            }
            Ok(())
        })
        .unwrap();
        assert!(OVERLAPPED.load(Ordering::SeqCst), "workers never overlapped");
    }

    #[test]
    fn indexed_visits_selected_items_in_position_order() {
        for workers in [1, 2, 4, 16] {
            let mut items: Vec<u64> = vec![0; 12];
            let idx = [7usize, 2, 9, 0, 5];
            let mut out = [0u64; 5];
            run_sharded_indexed(&mut items, &idx, &mut out, workers, |k, item| {
                *item = 100 + k as u64;
                Ok(*item * 2)
            })
            .unwrap();
            for (k, &i) in idx.iter().enumerate() {
                assert_eq!(items[i], 100 + k as u64, "workers={workers}");
                assert_eq!(out[k], (100 + k as u64) * 2, "workers={workers}");
            }
            // untouched items stay untouched
            assert_eq!(items[1], 0);
            assert_eq!(items[11], 0);
        }
    }

    #[test]
    fn indexed_parallel_matches_sequential_bitwise() {
        let run = |workers: usize| -> (Vec<u64>, Vec<u64>) {
            let mut items: Vec<u64> = (0..31).map(|i| i * 13 + 5).collect();
            let idx: Vec<usize> = (0..31).rev().step_by(2).collect();
            let mut out = vec![0u64; idx.len()];
            run_sharded_indexed(&mut items, &idx, &mut out, workers, |k, item| {
                let mut rng = crate::rng::Pcg32::derived(7, 0x1D, k as u64);
                for _ in 0..20 {
                    *item = item.wrapping_add(rng.next_u32() as u64);
                }
                Ok(*item ^ 0xABCD)
            })
            .unwrap();
            (items, out)
        };
        let reference = run(1);
        for workers in [2, 3, 8] {
            assert_eq!(run(workers), reference, "workers={workers}");
        }
    }

    #[test]
    fn indexed_lowest_position_error_wins() {
        for workers in [1, 2, 4] {
            let mut items = vec![(); 8];
            let idx = [6usize, 1, 3, 7];
            let mut out = vec![(); 4];
            let err = run_sharded_indexed(&mut items, &idx, &mut out, workers, |k, _| {
                if k == 1 || k == 3 {
                    anyhow::bail!("boom {k}")
                }
                Ok(())
            })
            .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("item 1"), "workers={workers}: {msg}");
            assert!(msg.contains("boom 1"), "workers={workers}: {msg}");
        }
    }

    #[test]
    fn indexed_empty_and_zst_out() {
        let mut items: Vec<u32> = vec![1, 2, 3];
        let mut out: Vec<()> = vec![];
        run_sharded_indexed(&mut items, &[], &mut out, 4, |_, _| Ok(())).unwrap();
        // ZST results (fan-in uses R = ()) never allocate in `out`
        let idx = [2usize, 0];
        let mut out = vec![(); 2];
        run_sharded_indexed(&mut items, &idx, &mut out, 4, |_, item| {
            *item += 10;
            Ok(())
        })
        .unwrap();
        assert_eq!(items, vec![11, 2, 13]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn indexed_rejects_out_of_bounds() {
        let mut items = vec![0u8; 3];
        let mut out = vec![(); 1];
        let _ = run_sharded_indexed(&mut items, &[3], &mut out, 1, |_, _| Ok(()));
    }

    #[test]
    fn effective_workers_resolution() {
        assert_eq!(effective_workers(1, 10), 1);
        assert_eq!(effective_workers(4, 10), 4);
        assert_eq!(effective_workers(100, 10), 10);
        assert_eq!(effective_workers(3, 0), 1);
        assert!(effective_workers(0, 64) >= 1);
    }

    #[test]
    fn sequential_and_parallel_mutations_are_identical() {
        // the core differential property at the engine level: same final
        // state for any worker count, even though work interleaves
        let run = |workers: usize| -> Vec<u64> {
            let mut items: Vec<u64> = (0..17).map(|i| i * 31 + 7).collect();
            run_sharded(&mut items, workers, |i, item| {
                let mut rng = crate::rng::Pcg32::derived(42, 0xE2E, i as u64);
                for _ in 0..50 {
                    *item = item.wrapping_add(rng.next_u32() as u64);
                }
                Ok(())
            })
            .unwrap();
            items
        };
        let reference = run(1);
        for workers in [2, 4, 16] {
            assert_eq!(run(workers), reference, "workers={workers}");
        }
    }
}
