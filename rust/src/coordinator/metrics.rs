//! Per-round training metrics and history (the data behind Fig. 2–4).
//!
//! Everything here is O(1) per round in the fleet size: [`RoundMetrics`]
//! carries only scalars, and per-device quantities reach it through
//! [`StreamFold`]-style running reductions (count/sum/min/max) instead of
//! materialized per-device vectors — at a million devices a single
//! `Vec<f64>` per round would dwarf the round itself.

use std::fmt::Write as _;

/// Order-stable streaming fold over `f64` samples: count, sum, min, max —
/// the per-round reduction primitive at fleet scale (no per-device vector
/// is ever built).
///
/// Determinism: `sum` accumulates in `observe` order, so callers must feed
/// samples in a schedule-independent order (device-id order, like every
/// other fold in the trainer — see `coordinator::engine`). `min`/`max`
/// over finite non-NaN samples are order-independent, so they are
/// bit-stable under any feed order.
#[derive(Debug, Clone, Copy)]
pub struct StreamFold {
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for StreamFold {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamFold {
    /// An empty fold.
    pub fn new() -> Self {
        StreamFold {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample in.
    pub fn observe(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Merge another fold in (for sharded reductions: merge shard folds in
    /// shard order to keep `sum` bit-stable).
    pub fn merge(&mut self, other: &StreamFold) {
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples folded.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running sum (in observe order).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean, or `0.0` for an empty fold.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Minimum, or `default` for an empty fold.
    pub fn min_or(&self, default: f64) -> f64 {
        if self.n == 0 {
            default
        } else {
            self.min
        }
    }

    /// Maximum, or `default` for an empty fold.
    pub fn max_or(&self, default: f64) -> f64 {
        if self.n == 0 {
            default
        } else {
            self.max
        }
    }
}

/// Everything measured in one communication round.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// 1-based round index.
    pub round: usize,
    /// Mean training loss across devices/batches this round.
    pub train_loss: f64,
    /// Training accuracy across devices/batches this round.
    pub train_acc: f64,
    /// Test accuracy of the aggregated model after this round.
    pub test_acc: f64,
    /// Test loss.
    pub test_loss: f64,
    /// Uplink bytes this round (all devices).
    pub uplink_bytes: u64,
    /// Downlink bytes this round (all devices).
    pub downlink_bytes: u64,
    /// Simulated communication makespan this round: max per-device link
    /// busy time within the round (parallel links), s.
    pub comm_time_s: f64,
    /// Simulated event-clock duration of the round (compute + transfers +
    /// queueing under the round scheduler; capped at the deadline for
    /// `deadline-drop` rounds), s.
    pub sim_time_s: f64,
    /// Total simulated seconds uplinks spent queued for the server busy
    /// resource this round (0 when `server_service_s = 0`), s.
    pub queue_wait_s: f64,
    /// Devices dropped by the straggler policy this round (0 under the
    /// sync scheduler and `wait-all`). Counts sampled participants only —
    /// devices left out by client sampling are not "dropped".
    pub dropped_devices: u64,
    /// Devices sampled into this round (`devices` when sampling is off).
    pub sampled_devices: u64,
    /// Retransmitted message copies this round (fault injection; 0 with
    /// the fault layer off).
    pub retransmits: u64,
    /// Wire bytes of message copies lost in flight this round.
    pub lost_bytes: u64,
    /// Corrupted uplink deliveries this round (transport-checksum NACKs
    /// plus serve-time decode failures).
    pub corrupt_payloads: u64,
    /// Simulated seconds arrivals waited out server outage windows, s.
    pub recovery_wait_s: f64,
    /// Whether the round was skipped by aggregation: every participant was
    /// dropped (deadline/quorum/fault exhaustion), so the aggregate model
    /// carried forward unchanged instead of dividing by a zero FedAvg
    /// weight.
    pub skipped: bool,
    /// Wall-clock compute time this round, s.
    pub wall_time_s: f64,
}

impl RoundMetrics {
    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Bit-exact equality of everything *deterministic* in a round.
    ///
    /// All simulated quantities (losses, accuracies, bytes, simulated comm
    /// time) must reproduce bit-for-bit at a fixed seed regardless of the
    /// worker count; `wall_time_s` is host wall-clock and is deliberately
    /// excluded. Float fields compare by bit pattern — the reductions
    /// feeding them are order-stable (see `coordinator::engine`), so even
    /// the f64 sums must match exactly.
    pub fn bit_eq(&self, other: &RoundMetrics) -> bool {
        self.round == other.round
            && self.train_loss.to_bits() == other.train_loss.to_bits()
            && self.train_acc.to_bits() == other.train_acc.to_bits()
            && self.test_acc.to_bits() == other.test_acc.to_bits()
            && self.test_loss.to_bits() == other.test_loss.to_bits()
            && self.uplink_bytes == other.uplink_bytes
            && self.downlink_bytes == other.downlink_bytes
            && self.comm_time_s.to_bits() == other.comm_time_s.to_bits()
            && self.sim_time_s.to_bits() == other.sim_time_s.to_bits()
            && self.queue_wait_s.to_bits() == other.queue_wait_s.to_bits()
            && self.dropped_devices == other.dropped_devices
            && self.sampled_devices == other.sampled_devices
            && self.retransmits == other.retransmits
            && self.lost_bytes == other.lost_bytes
            && self.corrupt_payloads == other.corrupt_payloads
            && self.recovery_wait_s.to_bits() == other.recovery_wait_s.to_bits()
            && self.skipped == other.skipped
    }
}

/// Full history of a run plus identifying metadata.
#[derive(Debug, Clone, Default)]
pub struct TrainingHistory {
    /// Experiment name.
    pub name: String,
    /// Codec name.
    pub codec: String,
    /// Rounds, in order.
    pub rounds: Vec<RoundMetrics>,
    /// Running cumulative byte totals, maintained by [`TrainingHistory::push`]:
    /// `cum[i] = Σ_{r ≤ i} total_bytes(r)` — O(1) per round instead of the
    /// historical per-query prefix re-sum. Private so it can only drift
    /// from `rounds` when callers push into `rounds` directly, which the
    /// accessors below detect and fall back from.
    cum: Vec<u64>,
}

impl TrainingHistory {
    /// Empty history with identifying metadata.
    pub fn new(name: &str, codec: &str) -> Self {
        TrainingHistory {
            name: name.to_string(),
            codec: codec.to_string(),
            ..Default::default()
        }
    }

    /// [`TrainingHistory::new`] with both vectors pre-sized (the trainer
    /// knows the round count up front, so steady-state pushes never grow).
    pub fn with_capacity(name: &str, codec: &str, rounds: usize) -> Self {
        let mut h = Self::new(name, codec);
        h.rounds.reserve(rounds);
        h.cum.reserve(rounds);
        h
    }

    /// Append a round, extending the running byte total in O(1).
    pub fn push(&mut self, m: RoundMetrics) {
        let prev = self.cum.last().copied().unwrap_or(0);
        self.cum.push(prev + m.total_bytes());
        self.rounds.push(m);
    }
    /// Best test accuracy seen.
    pub fn best_test_acc(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    /// Final test accuracy.
    pub fn final_test_acc(&self) -> f64 {
        self.rounds.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    /// First round whose test accuracy reaches `target`, if any.
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.rounds.iter().find(|r| r.test_acc >= target).map(|r| r.round)
    }

    /// Whether the running totals cover every round (false only when a
    /// caller pushed into `rounds` directly, bypassing `push`).
    fn cum_valid(&self) -> bool {
        self.cum.len() == self.rounds.len()
    }

    /// Cumulative bytes transmitted up to and including round `i` (0-based).
    /// O(1) from the running total; falls back to a prefix sum for
    /// hand-assembled histories.
    pub fn cumulative_bytes(&self, i: usize) -> u64 {
        if self.cum_valid() {
            self.cum[i]
        } else {
            self.rounds[..=i].iter().map(|r| r.total_bytes()).sum()
        }
    }

    /// Total bytes for the whole run (O(1) from the running total).
    pub fn total_bytes(&self) -> u64 {
        if self.cum_valid() {
            self.cum.last().copied().unwrap_or(0)
        } else {
            self.rounds.iter().map(|r| r.total_bytes()).sum()
        }
    }

    /// Whether any round recorded fault-layer activity. Gates the fault
    /// CSV columns so fault-free runs keep the historical CSV bytes.
    fn has_fault_activity(&self) -> bool {
        self.rounds.iter().any(|r| {
            r.retransmits > 0
                || r.lost_bytes > 0
                || r.corrupt_payloads > 0
                || r.recovery_wait_s != 0.0
        })
    }

    /// Whether any round was skipped by aggregation (all participants
    /// dropped). Gates the `skipped` CSV column the same way the fault
    /// columns are gated.
    fn has_skipped(&self) -> bool {
        self.rounds.iter().any(|r| r.skipped)
    }

    /// Render as CSV (header + one row per round); the `cum_bytes` column
    /// reuses the running totals.
    ///
    /// The fault columns (`retransmits,lost_bytes,corrupt_payloads,
    /// recovery_wait_s`) are emitted only when some round recorded fault
    /// activity — a fault-free run's CSV is byte-identical to the
    /// pre-fault-layer format (pinned by the fault-determinism tests).
    /// Likewise the `skipped` column (0/1) appears only when some round
    /// was skipped by aggregation.
    pub fn to_csv(&self) -> String {
        let faulty = self.has_fault_activity();
        let any_skipped = self.has_skipped();
        let mut s = String::from(
            "round,train_loss,train_acc,test_loss,test_acc,uplink_bytes,downlink_bytes,cum_bytes,comm_time_s,sim_time_s,queue_wait_s,dropped,sampled",
        );
        if faulty {
            s.push_str(",retransmits,lost_bytes,corrupt_payloads,recovery_wait_s");
        }
        if any_skipped {
            s.push_str(",skipped");
        }
        s.push_str(",wall_time_s\n");
        for (i, r) in self.rounds.iter().enumerate() {
            let _ = write!(
                s,
                "{},{:.5},{:.4},{:.5},{:.4},{},{},{},{:.4},{:.4},{:.4},{},{}",
                r.round,
                r.train_loss,
                r.train_acc,
                r.test_loss,
                r.test_acc,
                r.uplink_bytes,
                r.downlink_bytes,
                self.cumulative_bytes(i),
                r.comm_time_s,
                r.sim_time_s,
                r.queue_wait_s,
                r.dropped_devices,
                r.sampled_devices,
            );
            if faulty {
                let _ = write!(
                    s,
                    ",{},{},{},{:.4}",
                    r.retransmits, r.lost_bytes, r.corrupt_payloads, r.recovery_wait_s
                );
            }
            if any_skipped {
                let _ = write!(s, ",{}", r.skipped as u8);
            }
            let _ = writeln!(s, ",{:.3}", r.wall_time_s);
        }
        s
    }

    /// Write the CSV to `path` (creating parent dirs) atomically — temp
    /// file + fsync + rename via the shared checkpoint writer, so a crash
    /// mid-write never leaves a torn CSV for the sweep report to ingest.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        super::checkpoint::write_atomic(path, self.to_csv().as_bytes())
    }

    /// Bit-exact equality over all rounds (see [`RoundMetrics::bit_eq`];
    /// wall-clock excluded). Used by the differential determinism tests to
    /// compare `workers = 1` against `workers = N` runs.
    pub fn bit_eq(&self, other: &TrainingHistory) -> bool {
        self.rounds.len() == other.rounds.len()
            && self
                .rounds
                .iter()
                .zip(&other.rounds)
                .all(|(a, b)| a.bit_eq(b))
    }

    /// One-line summary for logs/tables.
    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<10} final acc {:.2}%  best {:.2}%  total {:.2} MB  comm {:.2}s",
            self.name,
            self.codec,
            self.final_test_acc() * 100.0,
            self.best_test_acc() * 100.0,
            self.total_bytes() as f64 / 1e6,
            self.rounds.iter().map(|r| r.comm_time_s).sum::<f64>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_fold_basics() {
        let mut f = StreamFold::new();
        assert_eq!(f.count(), 0);
        assert_eq!(f.mean(), 0.0);
        assert_eq!(f.min_or(7.0), 7.0);
        assert_eq!(f.max_or(0.0), 0.0);
        for v in [3.0, 1.0, 2.0] {
            f.observe(v);
        }
        assert_eq!(f.count(), 3);
        assert_eq!(f.sum(), 6.0);
        assert_eq!(f.mean(), 2.0);
        assert_eq!(f.min_or(0.0), 1.0);
        assert_eq!(f.max_or(0.0), 3.0);
    }

    #[test]
    fn stream_fold_matches_materialized_fold_bitwise() {
        // the fold must be bit-identical to the vector it replaces:
        // sum in feed order, max order-independent
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.001 + 1.0 / (i + 1) as f64).collect();
        let mut f = StreamFold::new();
        let mut sum = 0.0f64;
        let mut mx = 0.0f64;
        for &x in &xs {
            f.observe(x);
            sum += x;
            mx = mx.max(x);
        }
        assert_eq!(f.sum().to_bits(), sum.to_bits());
        // non-negative samples: NEG_INFINITY seed folds to the same max
        // as a 0.0 seed
        assert_eq!(f.max_or(0.0).to_bits(), mx.to_bits());
    }

    #[test]
    fn stream_fold_merge_in_shard_order_is_bit_stable() {
        let xs: Vec<f64> = (0..64).map(|i| 0.1 * i as f64).collect();
        let mut whole = StreamFold::new();
        for &x in &xs {
            whole.observe(x);
        }
        let mut merged = StreamFold::new();
        for shard in xs.chunks(16) {
            let mut f = StreamFold::new();
            for &x in shard {
                f.observe(x);
            }
            merged.merge(&f);
        }
        assert_eq!(merged.count(), whole.count());
        // shard-ordered merge reassociates the sum the same way the
        // engine's shard fold does; min/max are exactly order-independent
        assert_eq!(merged.min_or(0.0).to_bits(), whole.min_or(0.0).to_bits());
        assert_eq!(merged.max_or(0.0).to_bits(), whole.max_or(0.0).to_bits());
        assert!((merged.sum() - whole.sum()).abs() < 1e-9);
    }

    fn mk(round: usize, acc: f64, bytes: u64) -> RoundMetrics {
        RoundMetrics {
            round,
            train_loss: 1.0,
            train_acc: acc,
            test_acc: acc,
            test_loss: 1.0,
            uplink_bytes: bytes,
            downlink_bytes: bytes / 2,
            comm_time_s: 0.1,
            sim_time_s: 0.2,
            queue_wait_s: 0.0,
            dropped_devices: 0,
            sampled_devices: 5,
            retransmits: 0,
            lost_bytes: 0,
            corrupt_payloads: 0,
            recovery_wait_s: 0.0,
            skipped: false,
            wall_time_s: 0.5,
        }
    }

    fn hist(rounds: Vec<RoundMetrics>) -> TrainingHistory {
        let mut h = TrainingHistory::new("t", "x");
        for m in rounds {
            h.push(m);
        }
        h
    }

    #[test]
    fn accuracy_queries() {
        let h = hist(vec![mk(1, 0.5, 100), mk(2, 0.8, 100), mk(3, 0.7, 100)]);
        assert_eq!(h.best_test_acc(), 0.8);
        assert_eq!(h.final_test_acc(), 0.7);
        assert_eq!(h.rounds_to_accuracy(0.75), Some(2));
        assert_eq!(h.rounds_to_accuracy(0.95), None);
    }

    #[test]
    fn byte_accounting() {
        let h = hist(vec![mk(1, 0.1, 100), mk(2, 0.2, 200)]);
        assert_eq!(h.cumulative_bytes(0), 150);
        assert_eq!(h.cumulative_bytes(1), 450);
        assert_eq!(h.total_bytes(), 450);
    }

    #[test]
    fn running_totals_match_prefix_recompute_and_survive_raw_pushes() {
        // push() path: cum cache equals the O(n) prefix re-sum
        let rounds: Vec<RoundMetrics> =
            (1..=6).map(|r| mk(r, 0.1, (r as u64) * 37)).collect();
        let h = hist(rounds.clone());
        for i in 0..h.rounds.len() {
            let want: u64 = h.rounds[..=i].iter().map(|r| r.total_bytes()).sum();
            assert_eq!(h.cumulative_bytes(i), want, "round {i}");
        }
        // hand-assembled history (rounds pushed directly, cache bypassed):
        // the accessors must fall back to recomputation, not panic or lie
        let mut raw = TrainingHistory::new("raw", "x");
        for m in rounds {
            raw.rounds.push(m);
        }
        assert_eq!(raw.cumulative_bytes(2), h.cumulative_bytes(2));
        assert_eq!(raw.total_bytes(), h.total_bytes());
    }

    #[test]
    fn bit_eq_ignores_wall_clock_only() {
        let a = mk(1, 0.5, 100);
        let mut b = a.clone();
        b.wall_time_s = 99.0;
        assert!(a.bit_eq(&b), "wall clock must not affect bit_eq");
        let mut c = a.clone();
        c.train_loss = f64::from_bits(a.train_loss.to_bits() + 1);
        assert!(!a.bit_eq(&c), "1-ulp loss drift must be detected");
        let mut d = a.clone();
        d.sim_time_s = f64::from_bits(a.sim_time_s.to_bits() + 1);
        assert!(!a.bit_eq(&d), "1-ulp sim-time drift must be detected");
        let mut e = a.clone();
        e.dropped_devices = 1;
        assert!(!a.bit_eq(&e), "straggler drops must affect bit_eq");
        let mut f = a.clone();
        f.queue_wait_s = f64::from_bits(a.queue_wait_s.to_bits() + 1);
        assert!(!a.bit_eq(&f), "1-ulp queue-wait drift must be detected");
        let mut g = a.clone();
        g.sampled_devices = 4;
        assert!(!a.bit_eq(&g), "sampling membership must affect bit_eq");
        let ha = hist(vec![a.clone(), b]);
        let hb = hist(vec![a.clone(), a.clone()]);
        assert!(ha.bit_eq(&hb));
        let short = hist(vec![a]);
        assert!(!ha.bit_eq(&short));
    }

    #[test]
    fn csv_shape() {
        let h = hist(vec![mk(1, 0.5, 64)]);
        let csv = h.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].split(',').count(), lines[1].split(',').count());
    }

    #[test]
    fn bit_eq_detects_fault_counter_drift() {
        let a = mk(1, 0.5, 100);
        let mut b = a.clone();
        b.retransmits = 1;
        assert!(!a.bit_eq(&b), "retransmit drift must be detected");
        let mut c = a.clone();
        c.corrupt_payloads = 1;
        assert!(!a.bit_eq(&c), "corruption drift must be detected");
        let mut d = a.clone();
        d.lost_bytes = 7;
        assert!(!a.bit_eq(&d), "lost-byte drift must be detected");
        let mut e = a.clone();
        e.recovery_wait_s = f64::from_bits(a.recovery_wait_s.to_bits() + 1);
        assert!(!a.bit_eq(&e), "1-ulp recovery-wait drift must be detected");
    }

    #[test]
    fn csv_fault_columns_appear_only_with_fault_activity() {
        // fault-free: the historical 14-column format, byte-stable
        let clean = hist(vec![mk(1, 0.5, 64)]);
        let clean_csv = clean.to_csv();
        assert!(clean_csv.starts_with("round,"));
        assert!(!clean_csv.contains("retransmits"));
        assert_eq!(clean_csv.lines().next().unwrap().split(',').count(), 14);
        // any fault activity switches every row to the 18-column format
        let mut m = mk(1, 0.5, 64);
        m.retransmits = 3;
        m.lost_bytes = 128;
        m.corrupt_payloads = 1;
        m.recovery_wait_s = 0.25;
        let faulty = hist(vec![mk(2, 0.6, 64), m]);
        let csv = faulty.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert!(lines[0].ends_with(
            "dropped,sampled,retransmits,lost_bytes,corrupt_payloads,recovery_wait_s,wall_time_s"
        ));
        for l in &lines {
            assert_eq!(l.split(',').count(), 18, "row {l:?}");
        }
        assert!(lines[2].contains(",3,128,1,0.2500,"));
    }

    #[test]
    fn bit_eq_detects_skipped_round_drift() {
        let a = mk(1, 0.5, 100);
        let mut b = a.clone();
        b.skipped = true;
        assert!(!a.bit_eq(&b), "skipped-round drift must be detected");
    }

    #[test]
    fn csv_skipped_column_appears_only_when_a_round_was_skipped() {
        // no skipped rounds: the historical 14-column format, byte-stable
        let clean = hist(vec![mk(1, 0.5, 64)]);
        let clean_csv = clean.to_csv();
        assert!(!clean_csv.contains("skipped"));
        assert_eq!(clean_csv.lines().next().unwrap().split(',').count(), 14);
        // a skipped round switches every row to carry the 0/1 column,
        // placed between the (optional) fault columns and wall_time_s
        let mut m = mk(2, 0.5, 0);
        m.skipped = true;
        m.dropped_devices = 5;
        let h = hist(vec![mk(1, 0.5, 64), m]);
        let csv = h.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert!(lines[0].ends_with("dropped,sampled,skipped,wall_time_s"));
        for l in &lines {
            assert_eq!(l.split(',').count(), 15, "row {l:?}");
        }
        let col = |line: &str| line.split(',').nth(13).unwrap().to_string();
        assert_eq!(col(lines[1]), "0");
        assert_eq!(col(lines[2]), "1");
    }
}
