//! Quantization substrate: bit packing, min-max linear quantization
//! (paper Eq. 8/9), FQC bit-width allocation (Eq. 5–7), and the two
//! published quantizers used as baselines/ablations — PowerQuant [39]
//! and EasyQuant [40].

pub mod allocation;
pub mod bitpack;
pub mod easy;
pub mod linear;
pub mod power;

pub use allocation::{allocate_bits, group_bits, log_energy, AllocationConfig};
pub use bitpack::{pack_uniform, unpack_uniform, BitPacker, BitReader, BitWriter};
pub use easy::EasyQuant;
pub use linear::LinearQuantizer;
pub use power::PowerQuant;

use crate::codec::wire::{BodyReader, BodyWriter};
use anyhow::Result;

/// Quantize `xs` with `q` and append the bit-packed levels to a body writer
/// (shared by the channel-wise codecs). Packs straight into the body via
/// [`BodyWriter::packer`] — no intermediate buffer, no per-call allocation;
/// the byte stream is identical to the historical buffer-then-copy path.
pub fn pack_levels_into(xs: &[f32], q: &LinearQuantizer, w: &mut BodyWriter) {
    let mut p = w.packer();
    for &x in xs {
        p.put(q.quantize(x), q.bits);
    }
    p.finish();
}

/// Read `count` levels packed at `q.bits` wide and dequantize into `out`.
pub fn unpack_levels(
    r: &mut BodyReader,
    q: &LinearQuantizer,
    count: usize,
    out: &mut [f32],
) -> Result<()> {
    assert_eq!(out.len(), count);
    let bytes = (count * q.bits as usize + 7) / 8;
    let packed = r.bytes(bytes)?;
    let mut br = BitReader::new(packed);
    for o in out.iter_mut() {
        *o = q.dequantize(br.get(q.bits));
    }
    Ok(())
}

/// [`unpack_levels`] through a dequantization lookup table held in `lut`
/// (rebuilt in place per call, ≤ `2^bits` entries for `bits ≤ 8`; wider
/// widths fall back to direct dequantization). Table entries come from the
/// *same* [`LinearQuantizer::dequantize`], so decoded values are
/// bit-identical to the direct path.
pub fn unpack_levels_lut(
    r: &mut BodyReader,
    q: &LinearQuantizer,
    count: usize,
    lut: &mut Vec<f32>,
    out: &mut [f32],
) -> Result<()> {
    if q.bits > 8 {
        return unpack_levels(r, q, count, out);
    }
    assert_eq!(out.len(), count);
    let bytes = (count * q.bits as usize + 7) / 8;
    let packed = r.bytes(bytes)?;
    lut.clear();
    lut.extend((0..=q.qmax()).map(|l| q.dequantize(l)));
    let mut br = BitReader::new(packed);
    for o in out.iter_mut() {
        *o = lut[br.get(q.bits) as usize];
    }
    Ok(())
}

#[cfg(test)]
mod pack_tests {
    use super::*;

    #[test]
    fn pack_unpack_levels_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 10.0).collect();
        let q = LinearQuantizer::fit(5, &xs);
        let mut w = BodyWriter::new();
        pack_levels_into(&xs, &q, &mut w);
        let buf = w.finish();
        let mut r = BodyReader::new(&buf);
        let mut out = vec![0.0f32; 100];
        unpack_levels(&mut r, &q, 100, &mut out).unwrap();
        for (&a, &b) in xs.iter().zip(&out) {
            assert!((a - b).abs() <= q.step() / 2.0 + 1e-6);
        }
    }
}
