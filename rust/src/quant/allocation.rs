//! FQC bit-width allocation (paper Eq. 5–7).
//!
//! Given the mean spectral energies of the low/high frequency groups of one
//! channel, compute each group's quantization bit width:
//!
//! ```text
//! E*   = ln(mean_energy + 1)                       (Eq. 6)
//! τ_c  = max(E*_l, E*_h)                           (dynamic scaling factor)
//! b_f  = round(b_min + (b_max-b_min)·tanh(π/2 · E*_f/τ_c))   (Eq. 7)
//! ```
//!
//! The log map compresses the large energy gap between `F_l` and `F_h` so
//! the high-frequency group is not starved of bits (paper §II-C).

/// Bounds for Eq. 7.
#[derive(Debug, Clone, Copy)]
pub struct AllocationConfig {
    /// Minimum bit width `b_min` (paper: 2).
    pub b_min: u32,
    /// Maximum bit width `b_max` (paper: 8).
    pub b_max: u32,
}

impl Default for AllocationConfig {
    fn default() -> Self {
        AllocationConfig { b_min: 2, b_max: 8 }
    }
}

impl AllocationConfig {
    /// Validate bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.b_min == 0 || self.b_max > 16 || self.b_min > self.b_max {
            return Err(format!(
                "invalid bit bounds [{}, {}] (need 1 <= b_min <= b_max <= 16)",
                self.b_min, self.b_max
            ));
        }
        Ok(())
    }
}

/// Scaling function φ(x) = tanh(π/2 · x) from Eq. 7.
#[inline]
pub fn phi(x: f64) -> f64 {
    (std::f64::consts::FRAC_PI_2 * x).tanh()
}

/// Log-energy map `E* = ln(mean_energy + 1)` (Eq. 6), shared by every
/// energy-adaptive allocator so their τ and bit widths agree exactly.
#[inline]
pub fn log_energy(mean_energy: f64) -> f64 {
    (mean_energy.max(0.0) + 1.0).ln()
}

/// Bit width for one group from its log energy `E*` and the dynamic
/// scaling factor `τ` (the max `E*` over the groups sharing the budget).
/// This is Eq. 7 for an arbitrary group count: the two-group FQC
/// [`allocate_bits`] and the channel-wise SL-ACC codec both route
/// through it, so an N-way allocation degenerates to the paper's rule at
/// N = 2.
#[inline]
pub fn group_bits(cfg: &AllocationConfig, e_star: f64, tau: f64) -> u32 {
    let frac = if tau <= 0.0 { 0.0 } else { phi(e_star / tau) };
    let b = cfg.b_min as f64 + (cfg.b_max - cfg.b_min) as f64 * frac;
    // ⌊·⌉ rounding, clamped to the bounds.
    (b + 0.5).floor().clamp(cfg.b_min as f64, cfg.b_max as f64) as u32
}

/// Allocate bit widths `(b_low, b_high)` for one channel from the mean
/// spectral energies of its two groups (Eq. 5 outputs).
pub fn allocate_bits(
    cfg: &AllocationConfig,
    mean_energy_low: f64,
    mean_energy_high: f64,
) -> (u32, u32) {
    // Eq. 6 — log map.
    let e_low = log_energy(mean_energy_low);
    let e_high = log_energy(mean_energy_high);
    // τ_c — dynamic scaling factor.
    let tau = e_low.max(e_high);
    (group_bits(cfg, e_low, tau), group_bits(cfg, e_high, tau))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_bounds_match_paper() {
        let c = AllocationConfig::default();
        assert_eq!((c.b_min, c.b_max), (2, 8));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_bounds() {
        assert!(AllocationConfig { b_min: 0, b_max: 8 }.validate().is_err());
        assert!(AllocationConfig { b_min: 9, b_max: 8 }.validate().is_err());
        assert!(AllocationConfig { b_min: 2, b_max: 17 }.validate().is_err());
    }

    #[test]
    fn dominant_group_gets_near_bmax() {
        // The group holding τ_c gets φ(1) = tanh(π/2) ≈ 0.917 of the range.
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 1e6, 1e-3);
        // b_l = round(2 + 6·0.917) = round(7.5) ≈ 8 or 7
        assert!(bl >= 7, "b_low={bl}");
        assert!(bh >= cfg.b_min && bh < bl, "b_high={bh}");
    }

    #[test]
    fn equal_energies_equal_bits() {
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 42.0, 42.0);
        assert_eq!(bl, bh);
    }

    #[test]
    fn zero_energy_gets_bmin() {
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 0.0, 0.0);
        assert_eq!(bl, cfg.b_min);
        assert_eq!(bh, cfg.b_min);
    }

    #[test]
    fn bits_within_bounds_for_random_energies() {
        let cfg = AllocationConfig { b_min: 3, b_max: 10 };
        let mut rng = crate::rng::Pcg32::seeded(21);
        for _ in 0..500 {
            let el = rng.uniform_f64() * 1e8;
            let eh = rng.uniform_f64() * 1e2;
            let (bl, bh) = allocate_bits(&cfg, el, eh);
            for b in [bl, bh] {
                assert!(b >= cfg.b_min && b <= cfg.b_max);
            }
        }
    }

    #[test]
    fn monotone_in_energy() {
        // More energetic group never gets fewer bits than a less energetic
        // one under the same τ.
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 1000.0, 10.0);
        assert!(bl >= bh);
        let (bl2, bh2) = allocate_bits(&cfg, 10.0, 1000.0);
        assert!(bh2 >= bl2);
    }

    #[test]
    fn group_bits_generalizes_the_two_group_rule() {
        // allocate_bits is exactly group_bits applied to the two log
        // energies under their shared τ — the N-way generalization must
        // degenerate to the paper's rule at N = 2
        let cfg = AllocationConfig { b_min: 3, b_max: 11 };
        let mut rng = crate::rng::Pcg32::seeded(31);
        for _ in 0..200 {
            let el = rng.uniform_f64() * 1e7;
            let eh = rng.uniform_f64() * 1e3;
            let (bl, bh) = allocate_bits(&cfg, el, eh);
            let tau = log_energy(el).max(log_energy(eh));
            assert_eq!(bl, group_bits(&cfg, log_energy(el), tau));
            assert_eq!(bh, group_bits(&cfg, log_energy(eh), tau));
        }
        // τ = 0 (all-zero energies) pins every group to b_min
        assert_eq!(group_bits(&cfg, 0.0, 0.0), cfg.b_min);
    }

    #[test]
    fn log_map_reduces_polarization() {
        // Without the log map a 1e6:1 ratio would drive the small group to
        // b_min with φ(≈0); with it the small group still gets > b_min when
        // its absolute energy is non-trivial.
        let cfg = AllocationConfig::default();
        let (_, bh) = allocate_bits(&cfg, 1e6, 50.0);
        assert!(bh > cfg.b_min, "b_high={bh} should exceed b_min");
    }
}
