//! FQC bit-width allocation (paper Eq. 5–7).
//!
//! Given the mean spectral energies of the low/high frequency groups of one
//! channel, compute each group's quantization bit width:
//!
//! ```text
//! E*   = ln(mean_energy + 1)                       (Eq. 6)
//! τ_c  = max(E*_l, E*_h)                           (dynamic scaling factor)
//! b_f  = round(b_min + (b_max-b_min)·tanh(π/2 · E*_f/τ_c))   (Eq. 7)
//! ```
//!
//! The log map compresses the large energy gap between `F_l` and `F_h` so
//! the high-frequency group is not starved of bits (paper §II-C).

/// Bounds for Eq. 7.
#[derive(Debug, Clone, Copy)]
pub struct AllocationConfig {
    /// Minimum bit width `b_min` (paper: 2).
    pub b_min: u32,
    /// Maximum bit width `b_max` (paper: 8).
    pub b_max: u32,
}

impl Default for AllocationConfig {
    fn default() -> Self {
        AllocationConfig { b_min: 2, b_max: 8 }
    }
}

impl AllocationConfig {
    /// Validate bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.b_min == 0 || self.b_max > 16 || self.b_min > self.b_max {
            return Err(format!(
                "invalid bit bounds [{}, {}] (need 1 <= b_min <= b_max <= 16)",
                self.b_min, self.b_max
            ));
        }
        Ok(())
    }
}

/// Scaling function φ(x) = tanh(π/2 · x) from Eq. 7.
#[inline]
pub fn phi(x: f64) -> f64 {
    (std::f64::consts::FRAC_PI_2 * x).tanh()
}

/// Allocate bit widths `(b_low, b_high)` for one channel from the mean
/// spectral energies of its two groups (Eq. 5 outputs).
pub fn allocate_bits(
    cfg: &AllocationConfig,
    mean_energy_low: f64,
    mean_energy_high: f64,
) -> (u32, u32) {
    // Eq. 6 — log map.
    let e_low = (mean_energy_low.max(0.0) + 1.0).ln();
    let e_high = (mean_energy_high.max(0.0) + 1.0).ln();
    // τ_c — dynamic scaling factor.
    let tau = e_low.max(e_high);
    let alloc = |e: f64| -> u32 {
        let frac = if tau <= 0.0 { 0.0 } else { phi(e / tau) };
        let b = cfg.b_min as f64 + (cfg.b_max - cfg.b_min) as f64 * frac;
        // ⌊·⌉ rounding, clamped to the bounds.
        (b + 0.5).floor().clamp(cfg.b_min as f64, cfg.b_max as f64) as u32
    };
    (alloc(e_low), alloc(e_high))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_bounds_match_paper() {
        let c = AllocationConfig::default();
        assert_eq!((c.b_min, c.b_max), (2, 8));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_bounds() {
        assert!(AllocationConfig { b_min: 0, b_max: 8 }.validate().is_err());
        assert!(AllocationConfig { b_min: 9, b_max: 8 }.validate().is_err());
        assert!(AllocationConfig { b_min: 2, b_max: 17 }.validate().is_err());
    }

    #[test]
    fn dominant_group_gets_near_bmax() {
        // The group holding τ_c gets φ(1) = tanh(π/2) ≈ 0.917 of the range.
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 1e6, 1e-3);
        // b_l = round(2 + 6·0.917) = round(7.5) ≈ 8 or 7
        assert!(bl >= 7, "b_low={bl}");
        assert!(bh >= cfg.b_min && bh < bl, "b_high={bh}");
    }

    #[test]
    fn equal_energies_equal_bits() {
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 42.0, 42.0);
        assert_eq!(bl, bh);
    }

    #[test]
    fn zero_energy_gets_bmin() {
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 0.0, 0.0);
        assert_eq!(bl, cfg.b_min);
        assert_eq!(bh, cfg.b_min);
    }

    #[test]
    fn bits_within_bounds_for_random_energies() {
        let cfg = AllocationConfig { b_min: 3, b_max: 10 };
        let mut rng = crate::rng::Pcg32::seeded(21);
        for _ in 0..500 {
            let el = rng.uniform_f64() * 1e8;
            let eh = rng.uniform_f64() * 1e2;
            let (bl, bh) = allocate_bits(&cfg, el, eh);
            for b in [bl, bh] {
                assert!(b >= cfg.b_min && b <= cfg.b_max);
            }
        }
    }

    #[test]
    fn monotone_in_energy() {
        // More energetic group never gets fewer bits than a less energetic
        // one under the same τ.
        let cfg = AllocationConfig::default();
        let (bl, bh) = allocate_bits(&cfg, 1000.0, 10.0);
        assert!(bl >= bh);
        let (bl2, bh2) = allocate_bits(&cfg, 10.0, 1000.0);
        assert!(bh2 >= bl2);
    }

    #[test]
    fn log_map_reduces_polarization() {
        // Without the log map a 1e6:1 ratio would drive the small group to
        // b_min with φ(≈0); with it the small group still gets > b_min when
        // its absolute energy is non-trivial.
        let cfg = AllocationConfig::default();
        let (_, bh) = allocate_bits(&cfg, 1e6, 50.0);
        assert!(bh > cfg.b_min, "b_high={bh} should exceed b_min");
    }
}
