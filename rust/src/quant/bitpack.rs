//! Arbitrary-width bit packing.
//!
//! FQC emits per-group bit widths anywhere in `[b_min, b_max]` (2..=8 in the
//! paper, up to 16 supported here). The wire payload packs the quantized
//! levels back-to-back with no padding between values; this module is the
//! hot inner loop of the codec (see benches/bench_bitpack.rs).
//!
//! Both the writer and the reader work through a 64-bit accumulator and
//! move data **word-at-a-time**: the writer drains 4–7 whole bytes per
//! flush via one `extend_from_slice` (a memcpy, not a per-byte push), and
//! the reader refills 32 bits per load via one `u32::from_be_bytes`. Only
//! the stream tail falls back to byte-at-a-time handling. The byte layout
//! is MSB-first and **identical** to the historical per-byte loops — the
//! wire format is frozen (see ARCHITECTURE.md "Codec hot path"), and the
//! unit tests below pin exact byte sequences.
//!
//! [`BitPacker`] is the same writer over a *borrowed* `Vec<u8>`: the codec
//! hot path packs straight into the payload body, skipping the historical
//! intermediate buffer + copy (and its per-channel allocation).

/// Append the low `bits` bits of `value` (MSB-first) to `(acc, fill)`,
/// draining whole bytes into `buf` once a word's worth are pending.
///
/// Shared core of [`BitWriter`] / [`BitPacker`]; byte output is identical
/// to flushing one byte at a time.
#[inline]
fn put_bits(buf: &mut Vec<u8>, acc: &mut u64, fill: &mut u32, value: u32, bits: u32) {
    debug_assert!(bits <= 32);
    if bits == 0 {
        return;
    }
    debug_assert!(bits == 32 || value < (1u32 << bits), "value overflows width");
    // top `fill` bits of acc are pending; fill <= 31 on entry, so the
    // shifted value always fits (31 + 32 < 64).
    *acc |= ((value as u64) << (64 - bits)) >> *fill;
    *fill += bits;
    if *fill >= 32 {
        // drain whole bytes in one memcpy; to_be_bytes is exactly the
        // MSB-first byte order of the accumulator
        let nbytes = (*fill / 8) as usize;
        buf.extend_from_slice(&acc.to_be_bytes()[..nbytes]);
        *acc <<= nbytes * 8;
        *fill -= (nbytes * 8) as u32;
    }
}

/// Flush the final partial bytes (zero-padded) of `(acc, fill)` into `buf`.
#[inline]
fn flush_tail(buf: &mut Vec<u8>, acc: u64, fill: u32) {
    if fill > 0 {
        let nbytes = ((fill + 7) / 8) as usize;
        buf.extend_from_slice(&acc.to_be_bytes()[..nbytes]);
    }
}

/// Streaming MSB-first bit writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// bit accumulator; highest `fill` bits are pending
    acc: u64,
    /// number of valid bits in `acc` (≤ 31 between calls)
    fill: u32,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Writer with pre-reserved capacity (in bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bytes),
            acc: 0,
            fill: 0,
        }
    }

    /// Append the low `bits` bits of `value` (MSB-first). `bits` in 0..=32.
    #[inline]
    pub fn put(&mut self, value: u32, bits: u32) {
        put_bits(&mut self.buf, &mut self.acc, &mut self.fill, value, bits);
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.fill as usize
    }

    /// Flush the final partial bytes (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        flush_tail(&mut self.buf, self.acc, self.fill);
        self.buf
    }
}

/// MSB-first bit writer over a **borrowed** byte buffer — the zero-copy
/// variant the codec hot path uses to pack levels directly into the
/// payload body (`BodyWriter::packer`). Dropping a packer without calling
/// [`BitPacker::finish`] loses the pending tail bits; `finish` consumes it.
#[derive(Debug)]
pub struct BitPacker<'a> {
    buf: &'a mut Vec<u8>,
    acc: u64,
    fill: u32,
}

impl<'a> BitPacker<'a> {
    /// Packer appending to `buf` (existing contents are kept).
    pub fn new(buf: &'a mut Vec<u8>) -> Self {
        BitPacker {
            buf,
            acc: 0,
            fill: 0,
        }
    }

    /// Append the low `bits` bits of `value` (MSB-first). `bits` in 0..=32.
    #[inline]
    pub fn put(&mut self, value: u32, bits: u32) {
        put_bits(self.buf, &mut self.acc, &mut self.fill, value, bits);
    }

    /// Flush the final partial bytes (zero-padded) into the buffer.
    pub fn finish(self) {
        flush_tail(self.buf, self.acc, self.fill);
    }
}

/// MSB-first bit reader over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// next byte index
    pos: usize,
    acc: u64,
    fill: u32,
}

impl<'a> BitReader<'a> {
    /// Reader over `buf` starting at bit 0.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            pos: 0,
            acc: 0,
            fill: 0,
        }
    }

    /// Read `bits` bits (0..=32) MSB-first. Reading past the end yields
    /// zero bits (callers know exact counts from the payload header, so this
    /// only matters for corrupted payloads — which fail shape checks later).
    #[inline]
    pub fn get(&mut self, bits: u32) -> u32 {
        debug_assert!(bits <= 32);
        if bits == 0 {
            return 0;
        }
        if self.fill < bits {
            // word-level refill: one 32-bit big-endian load while it fits
            // (fill <= 31 here, so at most two iterations)
            while self.fill <= 32 && self.pos + 4 <= self.buf.len() {
                let w = u32::from_be_bytes(
                    self.buf[self.pos..self.pos + 4].try_into().unwrap(),
                );
                self.pos += 4;
                self.acc |= (w as u64) << (32 - self.fill);
                self.fill += 32;
            }
            // stream tail: byte-at-a-time, zeros past the end
            while self.fill < bits {
                let byte = if self.pos < self.buf.len() {
                    let b = self.buf[self.pos];
                    self.pos += 1;
                    b
                } else {
                    0
                };
                self.acc |= (byte as u64) << (56 - self.fill);
                self.fill += 8;
            }
        }
        let out = (self.acc >> (64 - bits)) as u32;
        self.acc <<= bits;
        self.fill -= bits;
        out
    }

    /// Number of whole bytes consumed from the underlying buffer. The
    /// word-level refill reads eagerly, so this can run ahead of the bit
    /// position by up to 7 bytes (diagnostics only — payload framing uses
    /// exact counts from the header, never this).
    pub fn bytes_consumed(&self) -> usize {
        self.pos
    }
}

/// Pack a slice of levels with a uniform width (helper for baselines).
pub fn pack_uniform(levels: &[u32], bits: u32) -> Vec<u8> {
    let mut w = BitWriter::with_capacity((levels.len() * bits as usize + 7) / 8);
    for &v in levels {
        w.put(v, bits);
    }
    w.finish()
}

/// Unpack `count` levels of a uniform width.
pub fn unpack_uniform(buf: &[u8], bits: u32, count: usize) -> Vec<u32> {
    let mut r = BitReader::new(buf);
    (0..count).map(|_| r.get(bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn roundtrip_uniform_widths() {
        let mut rng = Pcg32::seeded(1);
        for bits in 1..=16u32 {
            let vals: Vec<u32> = (0..257)
                .map(|_| rng.next_u32() & ((1u32 << bits) - 1))
                .collect();
            let packed = pack_uniform(&vals, bits);
            assert_eq!(packed.len(), (vals.len() * bits as usize + 7) / 8);
            let back = unpack_uniform(&packed, bits, vals.len());
            assert_eq!(vals, back);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        // FQC interleaves groups of different widths in one stream.
        let mut rng = Pcg32::seeded(2);
        let widths: Vec<u32> = (0..1000).map(|_| 1 + rng.below(16)).collect();
        let vals: Vec<u32> = widths
            .iter()
            .map(|&b| rng.next_u32() & ((1u32 << b) - 1))
            .collect();
        let mut w = BitWriter::new();
        for (&v, &b) in vals.iter().zip(&widths) {
            w.put(v, b);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for (&v, &b) in vals.iter().zip(&widths) {
            assert_eq!(r.get(b), v);
        }
    }

    #[test]
    fn zero_width_writes_nothing() {
        let mut w = BitWriter::new();
        w.put(0, 0);
        assert_eq!(w.bit_len(), 0);
        assert!(w.finish().is_empty());
    }

    #[test]
    fn bit_len_tracks_exactly() {
        let mut w = BitWriter::new();
        w.put(1, 3);
        w.put(5, 7);
        assert_eq!(w.bit_len(), 10);
        let buf = w.finish();
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn read_past_end_yields_zeros() {
        let buf = vec![0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(8), 0xFF);
        assert_eq!(r.get(8), 0);
    }

    #[test]
    fn msb_first_layout() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0b11, 2);
        // stream: 1 0 1 1 1 … → byte 0b10111000
        assert_eq!(w.finish(), vec![0b1011_1000]);
    }

    #[test]
    fn word_flush_boundaries_preserve_byte_layout() {
        // Cross the 32-bit flush threshold at every offset: the word-level
        // writer must emit the exact byte stream of a 1-bit-at-a-time
        // reference (the frozen wire layout).
        let mut rng = Pcg32::seeded(77);
        for lead in 0..16u32 {
            let vals: Vec<(u32, u32)> = (0..200)
                .map(|i| {
                    let b = if i == 0 && lead > 0 { lead } else { 1 + rng.below(16) };
                    (rng.next_u32() & ((1u64 << b) as u32).wrapping_sub(1), b)
                })
                .collect();
            let mut w = BitWriter::new();
            // bit-at-a-time reference stream
            let mut ref_bits: Vec<u8> = Vec::new();
            for &(v, b) in &vals {
                w.put(v, b);
                for k in (0..b).rev() {
                    ref_bits.push(((v >> k) & 1) as u8);
                }
            }
            let mut ref_bytes = vec![0u8; (ref_bits.len() + 7) / 8];
            for (i, bit) in ref_bits.iter().enumerate() {
                ref_bytes[i / 8] |= bit << (7 - (i % 8));
            }
            assert_eq!(w.finish(), ref_bytes, "lead={lead}");
        }
    }

    #[test]
    fn packer_into_vec_matches_bitwriter() {
        // BitPacker appends to an existing body exactly what BitWriter
        // would have produced standalone.
        let mut rng = Pcg32::seeded(78);
        let vals: Vec<(u32, u32)> = (0..500)
            .map(|_| {
                let b = 1 + rng.below(16);
                (rng.next_u32() & ((1u32 << b) - 1), b)
            })
            .collect();
        let mut w = BitWriter::new();
        let mut body = vec![0xEEu8, 0xFF]; // pre-existing header bytes
        let mut p = BitPacker::new(&mut body);
        for &(v, b) in &vals {
            w.put(v, b);
            p.put(v, b);
        }
        p.finish();
        let packed = w.finish();
        assert_eq!(&body[..2], &[0xEE, 0xFF]);
        assert_eq!(&body[2..], &packed[..]);
    }

    #[test]
    fn full_32bit_values() {
        let vals = [u32::MAX, 0, 0xDEADBEEF];
        let packed = pack_uniform(&vals, 32);
        assert_eq!(unpack_uniform(&packed, 32, 3), vals);
    }
}
