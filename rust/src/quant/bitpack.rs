//! Arbitrary-width bit packing.
//!
//! FQC emits per-group bit widths anywhere in `[b_min, b_max]` (2..=8 in the
//! paper, up to 16 supported here). The wire payload packs the quantized
//! levels back-to-back with no padding between values; this module is the
//! hot inner loop of the codec (see benches/bench_bitpack.rs), so both the
//! writer and reader work through a 64-bit accumulator and avoid per-value
//! branching beyond the flush check.

/// Streaming MSB-first bit writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// bit accumulator; highest `fill` bits are pending
    acc: u64,
    /// number of valid bits in `acc`
    fill: u32,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Writer with pre-reserved capacity (in bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bytes),
            acc: 0,
            fill: 0,
        }
    }

    /// Append the low `bits` bits of `value` (MSB-first). `bits` in 0..=32.
    #[inline]
    pub fn put(&mut self, value: u32, bits: u32) {
        debug_assert!(bits <= 32);
        if bits == 0 {
            return;
        }
        debug_assert!(bits == 32 || value < (1u32 << bits), "value overflows width");
        self.acc |= ((value as u64) << (64 - bits)) >> self.fill;
        self.fill += bits;
        while self.fill >= 8 {
            self.buf.push((self.acc >> 56) as u8);
            self.acc <<= 8;
            self.fill -= 8;
        }
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.fill as usize
    }

    /// Flush the final partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.fill > 0 {
            self.buf.push((self.acc >> 56) as u8);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// next byte index
    pos: usize,
    acc: u64,
    fill: u32,
}

impl<'a> BitReader<'a> {
    /// Reader over `buf` starting at bit 0.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            pos: 0,
            acc: 0,
            fill: 0,
        }
    }

    /// Read `bits` bits (0..=32) MSB-first. Reading past the end yields
    /// zero bits (callers know exact counts from the payload header, so this
    /// only matters for corrupted payloads — which fail shape checks later).
    #[inline]
    pub fn get(&mut self, bits: u32) -> u32 {
        debug_assert!(bits <= 32);
        if bits == 0 {
            return 0;
        }
        while self.fill < bits {
            let byte = if self.pos < self.buf.len() {
                let b = self.buf[self.pos];
                self.pos += 1;
                b
            } else {
                0
            };
            self.acc |= (byte as u64) << (56 - self.fill);
            self.fill += 8;
        }
        let out = (self.acc >> (64 - bits)) as u32;
        self.acc <<= bits;
        self.fill -= bits;
        out
    }

    /// Number of whole bytes consumed from the underlying buffer.
    pub fn bytes_consumed(&self) -> usize {
        self.pos
    }
}

/// Pack a slice of levels with a uniform width (helper for baselines).
pub fn pack_uniform(levels: &[u32], bits: u32) -> Vec<u8> {
    let mut w = BitWriter::with_capacity((levels.len() * bits as usize + 7) / 8);
    for &v in levels {
        w.put(v, bits);
    }
    w.finish()
}

/// Unpack `count` levels of a uniform width.
pub fn unpack_uniform(buf: &[u8], bits: u32, count: usize) -> Vec<u32> {
    let mut r = BitReader::new(buf);
    (0..count).map(|_| r.get(bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn roundtrip_uniform_widths() {
        let mut rng = Pcg32::seeded(1);
        for bits in 1..=16u32 {
            let vals: Vec<u32> = (0..257)
                .map(|_| rng.next_u32() & ((1u32 << bits) - 1))
                .collect();
            let packed = pack_uniform(&vals, bits);
            assert_eq!(packed.len(), (vals.len() * bits as usize + 7) / 8);
            let back = unpack_uniform(&packed, bits, vals.len());
            assert_eq!(vals, back);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        // FQC interleaves groups of different widths in one stream.
        let mut rng = Pcg32::seeded(2);
        let widths: Vec<u32> = (0..1000).map(|_| 1 + rng.below(16)).collect();
        let vals: Vec<u32> = widths
            .iter()
            .map(|&b| rng.next_u32() & ((1u32 << b) - 1))
            .collect();
        let mut w = BitWriter::new();
        for (&v, &b) in vals.iter().zip(&widths) {
            w.put(v, b);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for (&v, &b) in vals.iter().zip(&widths) {
            assert_eq!(r.get(b), v);
        }
    }

    #[test]
    fn zero_width_writes_nothing() {
        let mut w = BitWriter::new();
        w.put(0, 0);
        assert_eq!(w.bit_len(), 0);
        assert!(w.finish().is_empty());
    }

    #[test]
    fn bit_len_tracks_exactly() {
        let mut w = BitWriter::new();
        w.put(1, 3);
        w.put(5, 7);
        assert_eq!(w.bit_len(), 10);
        let buf = w.finish();
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn read_past_end_yields_zeros() {
        let buf = vec![0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(8), 0xFF);
        assert_eq!(r.get(8), 0);
    }

    #[test]
    fn msb_first_layout() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0b11, 2);
        // stream: 1 0 1 1 1 … → byte 0b10111000
        assert_eq!(w.finish(), vec![0b1011_1000]);
    }

    #[test]
    fn full_32bit_values() {
        let vals = [u32::MAX, 0, 0xDEADBEEF];
        let packed = pack_uniform(&vals, 32);
        assert_eq!(unpack_uniform(&packed, 32, 3), vals);
    }
}
