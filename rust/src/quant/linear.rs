//! Min-max linear quantization (paper Eq. 8/9).
//!
//! FQC quantizes each frequency group `F_{c,f}` with its own `[min, max]`
//! range: `x̂ = round((x - min)/(max - min) · (2^b - 1))` and the inverse
//! `x̃ = x̂/(2^b - 1) · (max - min) + min`.
//!
//! Note on Eq. 9: the paper typesets the denominator as `2^{b}−1` in Eq. 8
//! and `2^{b_{c,f}-1}` in Eq. 9; the only self-consistent reading (and the
//! only one that round-trips) is `2^b − 1` on both sides, which is what we
//! implement and what the reference implementation of min-max quantization
//! uses.

/// A min-max linear quantizer for a fixed bit width and value range.
#[derive(Debug, Clone, Copy)]
pub struct LinearQuantizer {
    /// Bit width `b` (1..=16 here).
    pub bits: u32,
    /// Range minimum.
    pub min: f32,
    /// Range maximum.
    pub max: f32,
}

impl LinearQuantizer {
    /// Build from a data slice's observed range.
    pub fn fit(bits: u32, data: &[f32]) -> Self {
        let (min, max) = crate::tensor::min_max(data);
        LinearQuantizer { bits, min, max }
    }

    /// Number of levels minus one (`2^b - 1`).
    #[inline]
    pub fn qmax(&self) -> u32 {
        if self.bits >= 32 {
            u32::MAX
        } else {
            (1u32 << self.bits) - 1
        }
    }

    /// Quantize one value to a level in `[0, 2^b - 1]` (Eq. 8).
    #[inline]
    pub fn quantize(&self, x: f32) -> u32 {
        let range = self.max - self.min;
        if range <= 0.0 || !range.is_finite() {
            return 0; // degenerate range: everything maps to min
        }
        let t = ((x - self.min) / range).clamp(0.0, 1.0);
        // round-half-away-from-zero is fine here; values are >= 0
        (t * self.qmax() as f32 + 0.5) as u32
    }

    /// Dequantize a level back to a float (Eq. 9).
    #[inline]
    pub fn dequantize(&self, level: u32) -> f32 {
        let range = self.max - self.min;
        if range <= 0.0 || !range.is_finite() {
            return self.min;
        }
        self.min + (level as f32 / self.qmax() as f32) * range
    }

    /// Quantize a slice into levels.
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize levels into floats.
    pub fn dequantize_all(&self, levels: &[u32]) -> Vec<f32> {
        levels.iter().map(|&l| self.dequantize(l)).collect()
    }

    /// Worst-case absolute reconstruction error (half a step).
    pub fn step(&self) -> f32 {
        let range = self.max - self.min;
        if range <= 0.0 {
            0.0
        } else {
            range / self.qmax() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn endpoints_are_exact() {
        let q = LinearQuantizer {
            bits: 4,
            min: -2.0,
            max: 6.0,
        };
        assert_eq!(q.quantize(-2.0), 0);
        assert_eq!(q.quantize(6.0), q.qmax());
        assert_eq!(q.dequantize(0), -2.0);
        assert_eq!(q.dequantize(q.qmax()), 6.0);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Pcg32::seeded(3);
        for bits in [2u32, 4, 8, 12] {
            let data: Vec<f32> = (0..500).map(|_| rng.normal() * 3.0).collect();
            let q = LinearQuantizer::fit(bits, &data);
            let half = q.step() / 2.0 + 1e-6;
            for &x in &data {
                let back = q.dequantize(q.quantize(x));
                assert!(
                    (back - x).abs() <= half,
                    "bits={bits} x={x} back={back} half={half}"
                );
            }
        }
    }

    #[test]
    fn degenerate_range_maps_to_min() {
        let q = LinearQuantizer {
            bits: 8,
            min: 1.5,
            max: 1.5,
        };
        assert_eq!(q.quantize(1.5), 0);
        assert_eq!(q.dequantize(0), 1.5);
        assert_eq!(q.dequantize(200), 1.5);
    }

    #[test]
    fn out_of_range_clamps() {
        let q = LinearQuantizer {
            bits: 3,
            min: 0.0,
            max: 1.0,
        };
        assert_eq!(q.quantize(-10.0), 0);
        assert_eq!(q.quantize(10.0), 7);
    }

    #[test]
    fn higher_bits_reduce_error() {
        let mut rng = Pcg32::seeded(4);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let mut last_err = f64::INFINITY;
        for bits in [2u32, 4, 6, 8] {
            let q = LinearQuantizer::fit(bits, &data);
            let err: f64 = data
                .iter()
                .map(|&x| ((q.dequantize(q.quantize(x)) - x) as f64).powi(2))
                .sum();
            assert!(err < last_err, "bits={bits}");
            last_err = err;
        }
    }
}
