//! EasyQuant (Tang et al., EMNLP 2023 [40]) — outlier-isolating uniform
//! quantization, used in the Fig. 4 row-2 ablation.
//!
//! EasyQuant's two ingredients, adapted from weight quantization to the
//! smashed-data setting:
//!
//! 1. **Outlier isolation** — elements with `|x| > k·σ` are kept in full
//!    precision (stored sparsely as (index, f32)) because a handful of
//!    outliers otherwise stretches the quantization range.
//! 2. **Range optimization** — the clip range `[-c, c]` for the remaining
//!    inliers is chosen by a golden-section-style grid search minimizing
//!    reconstruction MSE (the paper optimizes the reciprocal scale by
//!    gradient; a direct search is equivalent at this scale).

/// A fitted EasyQuant transform for one tensor/group.
#[derive(Debug, Clone)]
pub struct EasyQuant {
    /// Bit width for the inlier grid.
    pub bits: u32,
    /// Clip magnitude for inliers.
    pub clip: f32,
    /// Outlier threshold used at fit time.
    pub threshold: f32,
    /// Sparse outliers: (flat index, original value).
    pub outliers: Vec<(u32, f32)>,
}

/// σ-multiplier for outlier detection (EasyQuant keeps ≤ ~1% outliers).
pub const OUTLIER_SIGMA: f32 = 3.0;

impl EasyQuant {
    /// Fit on `data`: detect outliers, then grid-search the clip range.
    pub fn fit(bits: u32, data: &[f32]) -> Self {
        Self::fit_with(bits, data, Vec::new())
    }

    /// [`EasyQuant::fit`] reusing a caller-owned outlier buffer (cleared,
    /// capacity kept). With the buffer recycled across calls — the codec
    /// hot path threads it through `CodecScratch` — the fit performs zero
    /// steady-state heap allocations; the fitted transform is identical
    /// to `fit`'s.
    pub fn fit_with(bits: u32, data: &[f32], mut outliers: Vec<(u32, f32)>) -> Self {
        outliers.clear();
        let sigma = crate::tensor::std_dev(data);
        let mean = if data.is_empty() {
            0.0
        } else {
            data.iter().sum::<f32>() / data.len() as f32
        };
        let threshold = OUTLIER_SIGMA * sigma;
        let mut inlier_max = 0.0f32;
        for (i, &x) in data.iter().enumerate() {
            if (x - mean).abs() > threshold && sigma > 0.0 {
                outliers.push((i as u32, x));
            } else {
                inlier_max = inlier_max.max(x.abs());
            }
        }
        let inlier_max = inlier_max.max(1e-12);

        // Range search: candidate clips as fractions of the inlier max.
        let qmax = ((1u32 << (bits.max(2) - 1)) - 1) as f32;
        let mut best = (f64::INFINITY, inlier_max);
        for frac in [0.5f32, 0.65, 0.8, 0.9, 1.0] {
            let c = inlier_max * frac;
            let mut err = 0.0f64;
            let stride = (data.len() / 4096).max(1);
            let mut i = 0;
            while i < data.len() {
                let x = data[i];
                if (x - mean).abs() <= threshold || sigma <= 0.0 {
                    let t = (x / c).clamp(-1.0, 1.0);
                    let lvl = (t * qmax).round();
                    let back = lvl / qmax * c;
                    err += ((back - x) as f64).powi(2);
                }
                i += stride;
            }
            if err < best.0 {
                best = (err, c);
            }
        }

        EasyQuant {
            bits,
            clip: best.1,
            threshold,
            outliers,
        }
    }

    #[inline]
    fn qmax(&self) -> f32 {
        ((1u32 << (self.bits.max(2) - 1)) - 1) as f32
    }

    /// Quantize one inlier value to a signed level (two's-complement-free:
    /// sign bit + magnitude, like [`crate::quant::PowerQuant`]).
    #[inline]
    pub fn quantize(&self, x: f32) -> u32 {
        let t = (x / self.clip).clamp(-1.0, 1.0);
        let mag = (t.abs() * self.qmax() + 0.5) as u32;
        let sign = if t < 0.0 { 1u32 } else { 0 };
        (sign << (self.bits.max(2) - 1)) | mag.min(self.qmax() as u32)
    }

    /// Invert [`Self::quantize`].
    #[inline]
    pub fn dequantize(&self, level: u32) -> f32 {
        let b = self.bits.max(2);
        let sign = if level >> (b - 1) != 0 { -1.0f32 } else { 1.0 };
        let mag = (level & ((1u32 << (b - 1)) - 1)) as f32;
        sign * mag / self.qmax() * self.clip
    }

    /// Reconstruct a full tensor: dequantized inliers with outliers patched
    /// back at full precision.
    pub fn reconstruct(&self, levels: &[u32]) -> Vec<f32> {
        let mut out: Vec<f32> = levels.iter().map(|&l| self.dequantize(l)).collect();
        for &(i, v) in &self.outliers {
            if (i as usize) < out.len() {
                out[i as usize] = v;
            }
        }
        out
    }

    /// Wire cost of the sparse outlier side-channel, in bits.
    pub fn outlier_bits(&self) -> usize {
        // u32 index + f32 value per outlier
        self.outliers.len() * 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn outliers_survive_exactly() {
        let mut rng = Pcg32::seeded(41);
        let mut data: Vec<f32> = (0..1000).map(|_| rng.normal() * 0.1).collect();
        data[17] = 50.0;
        data[503] = -42.0;
        let q = EasyQuant::fit(4, &data);
        assert!(q.outliers.len() >= 2);
        let levels: Vec<u32> = data.iter().map(|&x| q.quantize(x)).collect();
        let back = q.reconstruct(&levels);
        assert_eq!(back[17], 50.0);
        assert_eq!(back[503], -42.0);
    }

    #[test]
    fn inlier_error_bounded() {
        let mut rng = Pcg32::seeded(42);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
        let q = EasyQuant::fit(8, &data);
        let levels: Vec<u32> = data.iter().map(|&x| q.quantize(x)).collect();
        let back = q.reconstruct(&levels);
        let mse: f64 = data
            .iter()
            .zip(&back)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / data.len() as f64;
        assert!(mse < 5e-3, "mse={mse}");
    }

    #[test]
    fn few_outliers_on_gaussian() {
        let mut rng = Pcg32::seeded(43);
        let data: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
        let q = EasyQuant::fit(4, &data);
        // 3σ two-sided ⇒ ~0.27% expected
        assert!(
            q.outliers.len() < data.len() / 50,
            "outliers={}",
            q.outliers.len()
        );
    }

    #[test]
    fn constant_data_roundtrips() {
        let data = vec![2.5f32; 64];
        let q = EasyQuant::fit(4, &data);
        let levels: Vec<u32> = data.iter().map(|&x| q.quantize(x)).collect();
        let back = q.reconstruct(&levels);
        for &b in &back {
            assert!((b - 2.5).abs() < 0.3, "b={b}");
        }
    }

    #[test]
    fn fit_with_reuses_buffer_and_matches_fit() {
        let mut rng = Pcg32::seeded(44);
        let mut data: Vec<f32> = (0..800).map(|_| rng.normal() * 0.2).collect();
        data[10] = 30.0;
        data[700] = -25.0;
        let plain = EasyQuant::fit(5, &data);
        // dirty recycled buffer: contents must not leak into the fit
        let recycled = vec![(99u32, 123.0f32); 16];
        let cap = recycled.capacity();
        let reused = EasyQuant::fit_with(5, &data, recycled);
        assert_eq!(plain.clip.to_bits(), reused.clip.to_bits());
        assert_eq!(plain.threshold.to_bits(), reused.threshold.to_bits());
        assert_eq!(plain.outliers, reused.outliers);
        assert!(
            reused.outliers.capacity() >= cap,
            "recycled buffer must keep its capacity"
        );
    }

    #[test]
    fn sign_symmetry() {
        let data: Vec<f32> = (-50..=50).map(|i| i as f32 / 25.0).collect();
        let q = EasyQuant::fit(6, &data);
        let back_pos = q.dequantize(q.quantize(0.8));
        let back_neg = q.dequantize(q.quantize(-0.8));
        assert!((back_pos + back_neg).abs() < 1e-6);
    }
}
