//! PowerQuant (Yvinec et al., ICLR 2023 [39]) — non-uniform quantization via
//! a power automorphism, used by the PQ-SL baseline and the Fig. 4 row-2
//! ablation.
//!
//! The idea: instead of quantizing `x` on a uniform grid, quantize
//! `sign(x)·|x/s|^a` (a power re-mapping of the normalized magnitude) on a
//! uniform grid and invert with the `1/a` power on dequantization. The
//! exponent `a` is found by a data-free automorphism search; here we do the
//! search directly on the tensor being compressed (a strictly stronger
//! variant — it can only flatter the baseline) by grid-searching `a` to
//! minimize reconstruction MSE.

/// A fitted PowerQuant transform.
#[derive(Debug, Clone, Copy)]
pub struct PowerQuant {
    /// Bit width (one sign-carrying grid over [-1, 1]).
    pub bits: u32,
    /// Scale `s = max|x|`.
    pub scale: f32,
    /// Exponent `a` of the automorphism.
    pub exponent: f32,
}

impl PowerQuant {
    /// Candidate exponents searched (log-spaced around 1.0, as in the paper's
    /// automorphism family `x ↦ x^a`).
    pub const EXPONENT_GRID: [f32; 9] = [0.25, 0.35, 0.5, 0.7, 1.0, 1.4, 2.0, 2.8, 4.0];

    /// Fit scale + exponent on the data by minimizing reconstruction MSE.
    pub fn fit(bits: u32, data: &[f32]) -> Self {
        let scale = data.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        let mut best = (f64::INFINITY, 1.0f32);
        // Subsample for the search: error is a smooth function of `a`, and
        // the grid search is O(|grid|·n).
        let stride = (data.len() / 4096).max(1);
        for &a in &Self::EXPONENT_GRID {
            let q = PowerQuant {
                bits,
                scale,
                exponent: a,
            };
            let mut err = 0.0f64;
            let mut i = 0;
            while i < data.len() {
                let x = data[i];
                let back = q.dequantize(q.quantize(x));
                err += ((back - x) as f64).powi(2);
                i += stride;
            }
            if err < best.0 {
                best = (err, a);
            }
        }
        PowerQuant {
            bits,
            scale,
            exponent: best.1,
        }
    }

    /// Number of positive levels (`2^(b-1) - 1`; one bit carries the sign).
    #[inline]
    fn qmax(&self) -> u32 {
        (1u32 << (self.bits.max(2) - 1)) - 1
    }

    /// Quantize into a signed level encoded as `sign bit | magnitude`.
    #[inline]
    pub fn quantize(&self, x: f32) -> u32 {
        let t = (x.abs() / self.scale).clamp(0.0, 1.0).powf(self.exponent);
        let mag = (t * self.qmax() as f32 + 0.5) as u32;
        let sign = if x < 0.0 { 1u32 } else { 0 };
        (sign << (self.bits.max(2) - 1)) | mag.min(self.qmax())
    }

    /// Invert [`Self::quantize`].
    #[inline]
    pub fn dequantize(&self, level: u32) -> f32 {
        let b = self.bits.max(2);
        let sign = if level >> (b - 1) != 0 { -1.0f32 } else { 1.0 };
        let mag = level & self.qmax();
        let t = mag as f32 / self.qmax() as f32;
        sign * t.powf(1.0 / self.exponent) * self.scale
    }

    /// Quantize a slice.
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize a slice of levels.
    pub fn dequantize_all(&self, levels: &[u32]) -> Vec<f32> {
        levels.iter().map(|&l| self.dequantize(l)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn roundtrip_error_small_at_8_bits() {
        let mut rng = Pcg32::seeded(31);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
        let q = PowerQuant::fit(8, &data);
        let mse: f64 = data
            .iter()
            .map(|&x| ((q.dequantize(q.quantize(x)) - x) as f64).powi(2))
            .sum::<f64>()
            / data.len() as f64;
        assert!(mse < 1e-3, "mse={mse}");
    }

    #[test]
    fn sign_preserved() {
        let q = PowerQuant {
            bits: 4,
            scale: 1.0,
            exponent: 0.5,
        };
        assert!(q.dequantize(q.quantize(-0.7)) < 0.0);
        assert!(q.dequantize(q.quantize(0.7)) > 0.0);
    }

    #[test]
    fn zero_maps_to_zero() {
        let q = PowerQuant {
            bits: 6,
            scale: 2.0,
            exponent: 2.0,
        };
        assert_eq!(q.dequantize(q.quantize(0.0)), 0.0);
    }

    #[test]
    fn laplacian_data_prefers_sub_unit_exponent() {
        // Heavy-tailed (Laplace-like) data is PowerQuant's motivating case:
        // the fitted exponent should deviate from the uniform a=1.
        let mut rng = Pcg32::seeded(33);
        let data: Vec<f32> = (0..4000)
            .map(|_| {
                // Laplace via difference of exponentials
                let u = rng.uniform_f64().max(1e-9);
                let v = rng.uniform_f64().max(1e-9);
                ((-u.ln()) - (-v.ln())) as f32
            })
            .collect();
        let q = PowerQuant::fit(3, &data);
        assert!(
            q.exponent != 1.0,
            "expected non-uniform exponent, got {}",
            q.exponent
        );
    }

    #[test]
    fn fit_beats_or_matches_plain_uniform() {
        let mut rng = Pcg32::seeded(34);
        let data: Vec<f32> = (0..3000).map(|_| rng.normal() * 2.0).collect();
        let fitted = PowerQuant::fit(4, &data);
        let uniform = PowerQuant {
            exponent: 1.0,
            ..fitted
        };
        let mse = |q: &PowerQuant| -> f64 {
            data.iter()
                .map(|&x| ((q.dequantize(q.quantize(x)) - x) as f64).powi(2))
                .sum()
        };
        assert!(mse(&fitted) <= mse(&uniform) * 1.0001);
    }
}
