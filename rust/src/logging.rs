//! Minimal leveled stderr logger.
//!
//! The offline build environment has no `log`/`env_logger` wiring on the
//! request path, so the coordinator uses this tiny logger: global level set
//! once (from the CLI or `SLFAC_LOG`), macro-free call sites, timestamps in
//! seconds since process start so runs are diffable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Fine-grained wire-path tracing (per message).
    Trace = 0,
    /// Per-step diagnostics.
    Debug = 1,
    /// Per-round progress (default).
    Info = 2,
    /// Recoverable anomalies.
    Warn = 3,
    /// Failures.
    Error = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }

    /// Parse a level name (case-insensitive). Unknown names yield `None`.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    start(); // pin t=0 at init
}

/// Initialise from the `SLFAC_LOG` environment variable if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SLFAC_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
    start();
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

/// True if `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l >= level()
}

/// Emit a log line at level `l`. Prefer the [`crate::info!`]-style macros.
pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {msg}", l.tag());
}

// The macros check `enabled` *before* formatting: a suppressed log line
// costs one atomic load and zero heap (the `format!` never runs), which
// is what lets quiet steady-state training rounds stay allocation-free.

/// Log at INFO.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::logging::enabled($crate::logging::Level::Info) {
            $crate::logging::log($crate::logging::Level::Info, module_path!(), &format!($($arg)*))
        }
    };
}
/// Log at DEBUG.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::logging::enabled($crate::logging::Level::Debug) {
            $crate::logging::log($crate::logging::Level::Debug, module_path!(), &format!($($arg)*))
        }
    };
}
/// Log at TRACE.
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        if $crate::logging::enabled($crate::logging::Level::Trace) {
            $crate::logging::log($crate::logging::Level::Trace, module_path!(), &format!($($arg)*))
        }
    };
}
/// Log at WARN.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        if $crate::logging::enabled($crate::logging::Level::Warn) {
            $crate::logging::log($crate::logging::Level::Warn, module_path!(), &format!($($arg)*))
        }
    };
}
/// Log at ERROR.
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        if $crate::logging::enabled($crate::logging::Level::Error) {
            $crate::logging::log($crate::logging::Level::Error, module_path!(), &format!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Trace < Level::Error);
        assert!(Level::Info < Level::Warn);
    }
}
