//! SL-ACC — adaptive channel-wise compression (arXiv:2508.12984).
//!
//! SL-ACC scores each channel of the smashed data by its mean energy and
//! allocates quantization bit widths per channel from those scores, so
//! informative (high-energy) channels travel at high precision while
//! near-silent channels are squeezed to `b_min` bits. It is the spatial,
//! channel-granular sibling of SL-FAC's frequency-group allocation: both
//! route through the same Eq. 6/7 machinery
//! ([`crate::quant::log_energy`] / [`crate::quant::group_bits`]), with
//! SL-ACC's groups being the `C` channels of a sample instead of the two
//! frequency bands of a channel.
//!
//! Per sample:
//!
//! 1. mean energy per channel `Ē_c = ‖x_c‖² / (M·N)` (f64 accumulation);
//! 2. `E*_c = ln(Ē_c + 1)`, `τ = max_c E*_c`,
//!    `b_c = round(b_min + (b_max − b_min)·tanh(π/2 · E*_c/τ))`;
//! 3. min-max linear quantization of each channel at `b_c` bits.
//!
//! Wire layout (body, after the standard payload header), frozen by the
//! golden vectors in `tests/golden/codec_wire.json`:
//!
//! ```text
//! per sample, per channel (both ascending):
//!   u8   b_c                    allocated bit width
//!   f32  min                    channel range minimum
//!   f32  max                    channel range maximum
//!   ⌈M·N·b_c/8⌉ bytes           packed levels, row-major, MSB-first
//! ```
//!
//! Like SL-FAC, the codec has a **fused** single-pass kernel (energy and
//! min/max folded in one sweep per channel) and a multi-pass **reference**
//! kernel (separate [`LinearQuantizer::fit`]), selected by `fast_path`.
//! Both produce bit-identical wire bytes: the fused min/max fold replicates
//! [`crate::tensor::min_max`]'s NaN-skipping convention exactly, and the
//! energy fold order (ascending, f64) matches the reference's `sum()`
//! (pinned by `tests/codec_differential.rs`).

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{
    group_bits, log_energy, pack_levels_into, unpack_levels_lut, AllocationConfig,
    LinearQuantizer,
};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// SL-ACC parameters.
#[derive(Debug, Clone, Copy)]
pub struct SlAccConfig {
    /// Per-channel bit-width bounds (shared with FQC's Eq. 7).
    pub alloc: AllocationConfig,
    /// Fused single-pass kernel (default) vs the multi-pass reference —
    /// bit-identical on the wire either way.
    pub fast_path: bool,
}

impl Default for SlAccConfig {
    fn default() -> Self {
        SlAccConfig {
            alloc: AllocationConfig::default(),
            fast_path: true,
        }
    }
}

/// SL-ACC codec. Spatial domain, deterministic.
#[derive(Debug, Clone)]
pub struct SlAccCodec {
    cfg: SlAccConfig,
}

impl SlAccCodec {
    /// Build from config (bounds validated).
    pub fn new(cfg: SlAccConfig) -> Self {
        cfg.alloc.validate().expect("SL-ACC bit bounds");
        SlAccCodec { cfg }
    }

    fn compress_impl(
        &self,
        x: &Tensor,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let plane = m * n;
        let worst_plane_bytes = (plane * self.cfg.alloc.b_max as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(body, b * c * (9 + worst_plane_bytes));
        let energies = &mut scratch.energies;
        let minmax = &mut scratch.vals; // fused kernel's (lo, hi) staging
        for bi in 0..b {
            energies.clear();
            minmax.clear();
            if self.cfg.fast_path {
                // fused: one sweep per channel folds energy AND range.
                // The min/max fold mirrors tensor::min_max (skip NaN,
                // empty/all-NaN => (0, 0)) so the reference's
                // LinearQuantizer::fit sees identical bytes.
                for ci in 0..c {
                    let ch = x.channel(bi, ci);
                    let mut e = 0.0f64;
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for &v in ch {
                        e += (v as f64) * (v as f64);
                        if !v.is_nan() {
                            lo = lo.min(v);
                            hi = hi.max(v);
                        }
                    }
                    if lo > hi {
                        (lo, hi) = (0.0, 0.0);
                    }
                    energies.push(e / plane as f64);
                    minmax.push(lo);
                    minmax.push(hi);
                }
            } else {
                // reference: energy pass only; ranges come from a second
                // pass inside LinearQuantizer::fit below
                for ci in 0..c {
                    let ch = x.channel(bi, ci);
                    let e: f64 = ch.iter().map(|&v| (v as f64) * (v as f64)).sum();
                    energies.push(e / plane as f64);
                }
            }
            // τ is per sample: the channel bit budget adapts to each
            // sample's own energy profile (the "adaptive" in SL-ACC)
            let tau = energies.iter().fold(0.0f64, |t, &e| t.max(log_energy(e)));
            for ci in 0..c {
                let ch = x.channel(bi, ci);
                let bits = group_bits(&self.cfg.alloc, log_energy(energies[ci]), tau);
                let q = if self.cfg.fast_path {
                    LinearQuantizer {
                        bits,
                        min: minmax[2 * ci],
                        max: minmax[2 * ci + 1],
                    }
                } else {
                    LinearQuantizer::fit(bits, ch)
                };
                w.u8(bits as u8);
                w.f32(q.min);
                w.f32(q.max);
                pack_levels_into(ch, &q, &mut w);
            }
        }
        Ok(Payload {
            kind: CodecKind::SlAcc as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }
}

impl ActivationCodec for SlAccCodec {
    fn name(&self) -> &'static str {
        "sl-acc"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::SlAcc
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, scratch, body)?;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        out.reset_dense(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        for bi in 0..b {
            for ci in 0..c {
                let bits = r.u8()? as u32;
                ensure!(
                    (1..=16).contains(&bits),
                    "corrupt SL-ACC bit width {bits}"
                );
                let min = r.f32()?;
                let max = r.f32()?;
                let q = LinearQuantizer { bits, min, max };
                unpack_levels_lut(
                    &mut r,
                    &q,
                    plane,
                    &mut scratch.lut,
                    out.channel_mut(bi, ci),
                )?;
            }
        }
        ensure!(
            r.remaining() == 0,
            "trailing bytes in SL-ACC payload: {}",
            r.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    fn mk(fast: bool) -> SlAccCodec {
        SlAccCodec::new(SlAccConfig {
            fast_path: fast,
            ..Default::default()
        })
    }

    #[test]
    fn roundtrip_bounded_by_quantizer_step() {
        let x = smooth_activations(&[2, 4, 10, 10], 41);
        let c = mk(true);
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        // every channel got >= b_min bits of min-max quantization, so the
        // worst-case element error is half a step of the coarsest channel
        let err = back.rel_l2_error(&x);
        assert!(err < 0.2, "rel err {err}");
    }

    #[test]
    fn high_energy_channels_get_more_bits() {
        let mut x = Tensor::zeros(&[1, 3, 6, 6]);
        for (i, v) in x.channel_mut(0, 0).iter_mut().enumerate() {
            *v = if i % 2 == 0 { 40.0 } else { -40.0 };
        }
        for v in x.channel_mut(0, 1).iter_mut() {
            *v = 0.01;
        }
        // channel 2 stays all-zero
        let c = mk(true);
        let p = c.compress(&x).unwrap();
        let mut r = BodyReader::new(&p.body);
        let mut bits = Vec::new();
        for _ in 0..3 {
            let b = r.u8().unwrap() as u32;
            bits.push(b);
            let _ = r.f32().unwrap();
            let _ = r.f32().unwrap();
            r.bytes((36 * b as usize + 7) / 8).unwrap();
        }
        assert_eq!(r.remaining(), 0);
        assert_eq!(bits[0], 8, "dominant channel takes b_max-ish bits");
        assert!(bits[1] < bits[0], "weak channel gets fewer: {bits:?}");
        assert_eq!(bits[2], 2, "silent channel pinned at b_min");
    }

    #[test]
    fn fused_matches_reference_bitwise() {
        for seed in [1u64, 2, 3] {
            let x = smooth_activations(&[2, 3, 7, 9], seed);
            let pf = mk(true).compress(&x).unwrap();
            let pr = mk(false).compress(&x).unwrap();
            assert_eq!(pf.to_bytes(), pr.to_bytes(), "seed {seed}");
        }
        // degenerate inputs hit the lo>hi => (0,0) branch
        for x in [
            Tensor::zeros(&[1, 2, 4, 4]),
            Tensor::full(&[2, 1, 3, 3], -2.5),
            Tensor::full(&[1, 1, 1, 1], f32::NAN),
        ] {
            let pf = mk(true).compress(&x).unwrap();
            let pr = mk(false).compress(&x).unwrap();
            assert_eq!(pf.to_bytes(), pr.to_bytes());
        }
    }

    #[test]
    fn all_zero_sample_is_exact_and_minimal() {
        let x = Tensor::zeros(&[1, 2, 5, 5]);
        let c = mk(true);
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.data(), x.data());
        // every channel at b_min: 9-byte header + ceil(25·2/8) payload each
        assert_eq!(p.body.len(), 2 * (9 + 7));
    }

    #[test]
    fn corrupt_bit_width_rejected() {
        let x = smooth_activations(&[1, 2, 4, 4], 43);
        let c = mk(true);
        let mut p = c.compress(&x).unwrap();
        p.body[0] = 0; // bits = 0 is never written
        assert!(c.decompress(&p).is_err());
        let mut p2 = c.compress(&x).unwrap();
        p2.body.push(0xAB); // trailing garbage
        assert!(c.decompress(&p2).is_err());
    }
}
