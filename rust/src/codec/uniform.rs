//! Uniform-strategy codecs: PQ-SL (PowerQuant), EasyQuant, plain linear
//! quantization, and the FP32 identity reference.
//!
//! These are the "uniform compression strategy" family the paper contrasts
//! with (§I): every element of the smashed data receives the same bit
//! width, regardless of informativeness.

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{BitReader, EasyQuant, LinearQuantizer, PowerQuant};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// PQ-SL: PowerQuant [39] applied to the whole tensor at a fixed bit width.
#[derive(Debug, Clone, Copy)]
pub struct PowerQuantCodec {
    /// Bit width (sign + magnitude grid).
    pub bits: u32,
}

impl PowerQuantCodec {
    /// Build with the given bit width (2..=16).
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits));
        PowerQuantCodec { bits }
    }
}

impl ActivationCodec for PowerQuantCodec {
    fn name(&self) -> &'static str {
        "pq-sl"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::PowerQuant
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let (b, c, m, n) = x.as_bchw();
        let q = PowerQuant::fit(self.bits, x.data());
        let cap = 8 + (x.numel() * self.bits as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(std::mem::take(&mut out.body), cap);
        w.f32(q.scale);
        w.f32(q.exponent);
        let mut bits = w.packer();
        for &v in x.data() {
            bits.put(q.quantize(v), self.bits);
        }
        bits.finish();
        *out = Payload {
            kind: CodecKind::PowerQuant as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        };
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let count = b * c * m * n;
        let mut r = BodyReader::new(&p.body);
        let scale = r.f32()?;
        let exponent = r.f32()?;
        ensure!(
            exponent > 0.0 && scale >= 0.0,
            "corrupt PowerQuant header (scale={scale}, a={exponent})"
        );
        let q = PowerQuant {
            bits: self.bits,
            scale,
            exponent,
        };
        // §Perf L3 iteration 2: dequantization calls powf per element; with
        // ≤ 2^bits distinct levels a lookup table removes it from the loop
        // (≈4× decompress speedup at 4 bits, see EXPERIMENTS.md §Perf).
        // The table lives in the scratch arena (rebuilt in place, no alloc
        // after warm-up). usize shift: safe for any bits <= 16 invariant
        // and does not overflow even if a hand-built codec widens it.
        let levels = 1usize << self.bits;
        scratch.lut.clear();
        scratch.lut.extend((0..levels as u32).map(|l| q.dequantize(l)));
        let packed = r.bytes((count * self.bits as usize + 7) / 8)?;
        let mut bits = BitReader::new(packed);
        out.reset_dense(&[b, c, m, n]); // dense: every element written below
        for o in out.data_mut() {
            *o = scratch.lut[bits.get(self.bits) as usize];
        }
        Ok(())
    }
}

/// EasyQuant [40]: outlier isolation + optimized clip range, fixed bit width.
#[derive(Debug, Clone, Copy)]
pub struct EasyQuantCodec {
    /// Bit width for the inlier grid.
    pub bits: u32,
}

impl EasyQuantCodec {
    /// Build with the given bit width (2..=16).
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits));
        EasyQuantCodec { bits }
    }
}

impl ActivationCodec for EasyQuantCodec {
    fn name(&self) -> &'static str {
        "easyquant"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::EasyQuant
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    /// Body-reusing compression; the fit's outlier list recycles through
    /// the scratch arena (`EasyQuant::fit_with`), so the whole encode is
    /// allocation-free in steady state like the other baselines
    /// (`tests/codec_zero_alloc.rs`).
    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let (b, c, m, n) = x.as_bchw();
        let q = EasyQuant::fit_with(self.bits, x.data(), std::mem::take(&mut scratch.outliers));
        let cap = 8 + q.outliers.len() * 8 + (x.numel() * self.bits as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(std::mem::take(&mut out.body), cap);
        w.f32(q.clip);
        w.u32(q.outliers.len() as u32);
        for &(i, v) in &q.outliers {
            w.u32(i);
            w.f32(v);
        }
        let mut bits = w.packer();
        for &v in x.data() {
            bits.put(q.quantize(v), self.bits);
        }
        bits.finish();
        scratch.outliers = q.outliers; // return the capacity to the arena
        *out = Payload {
            kind: CodecKind::EasyQuant as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        };
        Ok(())
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    /// Streaming decode into the reusable output tensor: dequantize the
    /// inlier grid straight into `out`, then patch the sparse outliers
    /// from the body slice — no level vector, no outlier vector.
    fn decompress_into(
        &self,
        p: &Payload,
        _scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let count = b * c * m * n;
        let mut r = BodyReader::new(&p.body);
        let clip = r.f32()?;
        let n_out = r.u32()? as usize;
        ensure!(n_out <= count, "corrupt EasyQuant outlier count {n_out}");
        let outlier_bytes = r.bytes(n_out * 8)?;
        // validate indices before touching `out`, so a corrupt payload
        // fails without a half-written tensor
        for pair in outlier_bytes.chunks_exact(8) {
            let i = u32::from_le_bytes(pair[0..4].try_into().unwrap());
            ensure!((i as usize) < count, "corrupt outlier index {i}");
        }
        let q = EasyQuant {
            bits: self.bits,
            clip,
            threshold: 0.0,
            outliers: Vec::new(),
        };
        let packed = r.bytes((count * self.bits as usize + 7) / 8)?;
        let mut bits = BitReader::new(packed);
        out.reset_dense(&[b, c, m, n]); // dense: every element written below
        for o in out.data_mut() {
            *o = q.dequantize(bits.get(self.bits));
        }
        let data = out.data_mut();
        for pair in outlier_bytes.chunks_exact(8) {
            let i = u32::from_le_bytes(pair[0..4].try_into().unwrap()) as usize;
            data[i] = f32::from_le_bytes(pair[4..8].try_into().unwrap());
        }
        Ok(())
    }
}

/// Plain per-tensor min-max linear quantization at a fixed bit width — the
/// simplest uniform baseline, and the Fig. 4 "EasyQuant/PowerQuant vs FQC"
/// control.
#[derive(Debug, Clone, Copy)]
pub struct UniformLinearCodec {
    /// Bit width.
    pub bits: u32,
}

impl UniformLinearCodec {
    /// Build with the given bit width (1..=16).
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits));
        UniformLinearCodec { bits }
    }
}

impl ActivationCodec for UniformLinearCodec {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::UniformLinear
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let (b, c, m, n) = x.as_bchw();
        let q = LinearQuantizer::fit(self.bits, x.data());
        let cap = 8 + (x.numel() * self.bits as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(std::mem::take(&mut out.body), cap);
        w.f32(q.min);
        w.f32(q.max);
        let mut bits = w.packer();
        for &v in x.data() {
            bits.put(q.quantize(v), self.bits);
        }
        bits.finish();
        *out = Payload {
            kind: CodecKind::UniformLinear as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        };
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let count = b * c * m * n;
        let mut r = BodyReader::new(&p.body);
        let q = LinearQuantizer {
            bits: self.bits,
            min: r.f32()?,
            max: r.f32()?,
        };
        out.reset_dense(&[b, c, m, n]); // dense: every element written below
        crate::quant::unpack_levels_lut(&mut r, &q, count, &mut scratch.lut, out.data_mut())
    }
}

/// FP32 passthrough — the no-compression reference for ratio accounting.
#[derive(Debug, Clone, Copy)]
pub struct IdentityCodec;

impl ActivationCodec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::Identity
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let (b, c, m, n) = x.as_bchw();
        let mut body = std::mem::take(&mut out.body);
        body.clear();
        body.reserve(x.numel() * 4);
        for &v in x.data() {
            body.extend_from_slice(&v.to_le_bytes());
        }
        *out = Payload {
            kind: CodecKind::Identity as u8,
            shape: [b, c, m, n],
            body,
        };
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        _scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let count = b * c * m * n;
        ensure!(
            p.body.len() == count * 4,
            "identity payload length mismatch"
        );
        out.reset_dense(&[b, c, m, n]); // dense: every element written below
        for (o, ch) in out.data_mut().iter_mut().zip(p.body.chunks_exact(4)) {
            *o = f32::from_le_bytes(ch.try_into().unwrap());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    #[test]
    fn powerquant_roundtrip() {
        let x = smooth_activations(&[2, 4, 8, 8], 31);
        let codec = PowerQuantCodec::new(6);
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert!(back.rel_l2_error(&x) < 0.1);
    }

    #[test]
    fn easyquant_roundtrip_with_outliers() {
        let mut x = smooth_activations(&[1, 4, 8, 8], 32);
        x.data_mut()[5] = 100.0; // hard outlier
        let codec = EasyQuantCodec::new(6);
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert_eq!(back.data()[5], 100.0, "outlier must be exact");
        assert!(back.rel_l2_error(&x) < 0.1);
    }

    #[test]
    fn uniform_linear_roundtrip_err_bounded_by_step() {
        let x = smooth_activations(&[2, 2, 6, 6], 33);
        let codec = UniformLinearCodec::new(8);
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        let (lo, hi) = x.min_max();
        let step = (hi - lo) / 255.0;
        assert!(back.max_abs_diff(&x) <= step / 2.0 + 1e-6);
    }

    #[test]
    fn identity_is_exact() {
        let x = smooth_activations(&[2, 3, 5, 5], 34);
        let codec = IdentityCodec;
        let p = codec.compress(&x).unwrap();
        let back = codec.decompress(&p).unwrap();
        assert_eq!(back.data(), x.data());
        // wire cost = raw cost + header
        assert_eq!(p.body.len(), x.numel() * 4);
    }

    #[test]
    fn wire_sizes_ordered_by_bits() {
        let x = smooth_activations(&[2, 4, 10, 10], 35);
        let b4 = UniformLinearCodec::new(4).compress(&x).unwrap().wire_bytes();
        let b8 = UniformLinearCodec::new(8).compress(&x).unwrap().wire_bytes();
        assert!(b4 < b8);
    }

    #[test]
    fn corrupt_headers_rejected() {
        let x = smooth_activations(&[1, 2, 4, 4], 36);
        let pq = PowerQuantCodec::new(4);
        let mut p = pq.compress(&x).unwrap();
        // exponent ← -1
        p.body[4..8].copy_from_slice(&(-1.0f32).to_le_bytes());
        assert!(pq.decompress(&p).is_err());

        let eq = EasyQuantCodec::new(4);
        let mut p = eq.compress(&x).unwrap();
        p.body[4..8].copy_from_slice(&u32::MAX.to_le_bytes()); // outlier count
        assert!(eq.decompress(&p).is_err());
    }
}
