//! TK-SL — randomized top-k sparsification (Zheng et al., IJCAI 2023 [25]).
//!
//! Retains the `keep_fraction` largest-magnitude elements of each sample's
//! smashed data plus a small random subset (`random_fraction`) of the rest
//! (the "randomized" part, which de-biases the estimator and was shown to
//! help convergence vs plain top-k). Retained values travel as f16 with u32
//! flat indices; everything else reconstructs as zero.
//!
//! The paper's Fig. 2 shows this baseline degrading most under non-IID —
//! magnitude selection keeps high-magnitude noise and drops low-magnitude
//! informative features (§III-B).

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};
use std::sync::Mutex;

/// TK-SL parameters.
#[derive(Debug, Clone, Copy)]
pub struct TopKConfig {
    /// Fraction of elements kept by magnitude (the paper's top-k).
    pub keep_fraction: f64,
    /// Additional fraction kept uniformly at random from the remainder.
    pub random_fraction: f64,
    /// Seed for the random subset.
    pub seed: u64,
}

impl Default for TopKConfig {
    fn default() -> Self {
        TopKConfig {
            keep_fraction: 0.25,
            random_fraction: 0.05,
            seed: 7,
        }
    }
}

/// Randomized top-k codec. Spatial domain.
#[derive(Debug)]
pub struct TopKCodec {
    cfg: TopKConfig,
    // RNG state advances per compression so successive batches sample
    // different random subsets (as in the reference implementation).
    rng: Mutex<Pcg32>,
}

impl TopKCodec {
    /// Build from config.
    pub fn new(cfg: TopKConfig) -> Self {
        assert!(
            cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0,
            "keep_fraction out of range"
        );
        assert!((0.0..=1.0).contains(&cfg.random_fraction));
        TopKCodec {
            cfg,
            rng: Mutex::new(Pcg32::seeded(cfg.seed)),
        }
    }

    /// Shared compression body; `rng` supplies the random-extra draws,
    /// `scratch` the index work buffers and the recycled body. The byte
    /// stream and RNG consumption are independent of scratch reuse
    /// (identical partial-sort inputs, identical draws).
    fn compress_impl(
        &self,
        x: &Tensor,
        rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let per_sample = c * m * n;
        let k_top = ((per_sample as f64 * self.cfg.keep_fraction).ceil() as usize)
            .clamp(1, per_sample);
        let k_rand = (per_sample as f64 * self.cfg.random_fraction).floor() as usize;

        let mut w = BodyWriter::from_vec(body, b * (4 + (k_top + k_rand) * 6));
        let idx = &mut scratch.idx;
        let kept = &mut scratch.kept;
        for bi in 0..b {
            let sample = &x.data()[bi * per_sample..(bi + 1) * per_sample];
            // top-k by |x| via partial sort of indices
            idx.clear();
            idx.extend(0..per_sample as u32);
            idx.select_nth_unstable_by(k_top - 1, |&a, &b| {
                sample[b as usize]
                    .abs()
                    .partial_cmp(&sample[a as usize].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            kept.clear();
            kept.extend_from_slice(&idx[..k_top]);
            // random extras from the remainder
            if k_rand > 0 && k_top < per_sample {
                let rest = &idx[k_top..];
                for _ in 0..k_rand {
                    kept.push(rest[rng.below(rest.len() as u32) as usize]);
                }
                kept.sort_unstable();
                kept.dedup();
            } else {
                kept.sort_unstable();
            }
            w.u32(kept.len() as u32);
            for &i in kept.iter() {
                w.u32(i);
                w.f16(sample[i as usize]);
            }
        }
        Ok(Payload {
            kind: CodecKind::TopK as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }
}

impl ActivationCodec for TopKCodec {
    fn name(&self) -> &'static str {
        "tk-sl"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::TopK
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        // Standalone path: draws from the codec's own advancing stream.
        // NOT schedule-independent when one codec instance is shared by
        // concurrent devices — the coordinator uses `compress_with_rng`
        // with per-device streams instead.
        let mut rng = self.rng.lock().unwrap();
        self.compress_impl(x, &mut rng, &mut CodecScratch::new(), Vec::new())
    }

    fn compress_with_rng(&self, x: &Tensor, rng: &mut Pcg32) -> Result<Payload> {
        self.compress_impl(x, rng, &mut CodecScratch::new(), Vec::new())
    }

    fn compress_into(
        &self,
        x: &Tensor,
        rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, rng, scratch, body)?;
        Ok(())
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn decompress_into(
        &self,
        p: &Payload,
        _scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let per_sample = c * m * n;
        out.reset(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        for bi in 0..b {
            let count = r.u32()? as usize;
            ensure!(count <= per_sample, "corrupt top-k count {count}");
            let dst =
                &mut out.data_mut()[bi * per_sample..(bi + 1) * per_sample];
            for _ in 0..count {
                let i = r.u32()? as usize;
                ensure!(i < per_sample, "corrupt top-k index {i}");
                dst[i] = r.f16()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    #[test]
    fn keeps_largest_magnitudes_exactly() {
        let mut x = Tensor::zeros(&[1, 1, 4, 4]);
        x.data_mut()[3] = 10.0;
        x.data_mut()[9] = -8.0;
        x.data_mut()[12] = 0.01;
        let codec = TopKCodec::new(TopKConfig {
            keep_fraction: 2.0 / 16.0,
            random_fraction: 0.0,
            seed: 1,
        });
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert!((back.data()[3] - 10.0).abs() < 0.01);
        assert!((back.data()[9] + 8.0).abs() < 0.01);
        assert_eq!(back.data()[12], 0.0, "small value dropped");
    }

    #[test]
    fn wire_size_scales_with_keep_fraction() {
        let x = smooth_activations(&[2, 4, 8, 8], 11);
        let small = TopKCodec::new(TopKConfig {
            keep_fraction: 0.1,
            random_fraction: 0.0,
            seed: 1,
        });
        let large = TopKCodec::new(TopKConfig {
            keep_fraction: 0.5,
            random_fraction: 0.0,
            seed: 1,
        });
        let ps = small.compress(&x).unwrap();
        let pl = large.compress(&x).unwrap();
        assert!(pl.wire_bytes() > 3 * ps.wire_bytes() / 2);
    }

    #[test]
    fn randomized_extras_add_coverage() {
        let x = smooth_activations(&[1, 2, 8, 8], 12);
        let plain = TopKCodec::new(TopKConfig {
            keep_fraction: 0.2,
            random_fraction: 0.0,
            seed: 3,
        });
        let rand = TopKCodec::new(TopKConfig {
            keep_fraction: 0.2,
            random_fraction: 0.2,
            seed: 3,
        });
        let nz = |t: &Tensor| t.data().iter().filter(|&&v| v != 0.0).count();
        let b_plain = plain.decompress(&plain.compress(&x).unwrap()).unwrap();
        let b_rand = rand.decompress(&rand.compress(&x).unwrap()).unwrap();
        assert!(nz(&b_rand) > nz(&b_plain));
    }

    #[test]
    fn error_decreases_with_keep_fraction() {
        let x = smooth_activations(&[2, 4, 10, 10], 13);
        let mut last = f64::INFINITY;
        for f in [0.1, 0.3, 0.6, 1.0] {
            let c = TopKCodec::new(TopKConfig {
                keep_fraction: f,
                random_fraction: 0.0,
                seed: 5,
            });
            let back = c.decompress(&c.compress(&x).unwrap()).unwrap();
            let err = back.rel_l2_error(&x);
            assert!(err <= last + 1e-9, "f={f}");
            last = err;
        }
        assert!(last < 0.01, "full keep should be ~f16-exact, err={last}");
    }

    #[test]
    fn compress_with_rng_is_schedule_independent() {
        // same per-device stream ⇒ same payload, no matter how many other
        // compressions happened on the shared codec in between
        let x = smooth_activations(&[2, 4, 8, 8], 15);
        let codec = TopKCodec::new(TopKConfig::default());
        let mut stream_a = crate::rng::Pcg32::derived(99, crate::rng::stream::CODEC, 0);
        let p1 = codec.compress_with_rng(&x, &mut stream_a).unwrap();
        // interleave unrelated work on the codec's internal stream
        for _ in 0..5 {
            let _ = codec.compress(&x).unwrap();
        }
        let mut stream_b = crate::rng::Pcg32::derived(99, crate::rng::stream::CODEC, 0);
        let p2 = codec.compress_with_rng(&x, &mut stream_b).unwrap();
        assert_eq!(p1.to_bytes(), p2.to_bytes());
        // and a different device stream samples different extras
        let mut stream_c = crate::rng::Pcg32::derived(99, crate::rng::stream::CODEC, 1);
        let p3 = codec.compress_with_rng(&x, &mut stream_c).unwrap();
        assert_ne!(p1.to_bytes(), p3.to_bytes());
    }

    #[test]
    fn corrupt_index_rejected() {
        let x = smooth_activations(&[1, 1, 4, 4], 14);
        let codec = TopKCodec::new(TopKConfig::default());
        let mut p = codec.compress(&x).unwrap();
        // overwrite first index with an out-of-range value
        p.body[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(codec.decompress(&p).is_err());
    }
}
