//! Mask-encoded top-k sparsification (arXiv:2408.13787).
//!
//! The direct literature comparison for TK-SL ([`crate::codec::TopKCodec`]):
//! the same largest-magnitude selection, but the kept-position set travels
//! as a **1-bit-per-element mask** instead of u32 indices, kept values are
//! bit-packed through a shared min-max quantizer instead of f16, and a
//! per-sample norm-compensation factor `γ = ‖x‖ / ‖x_kept‖` rescales the
//! survivors at decode so the reconstruction preserves the sample's L2
//! energy instead of systematically understating it ("unbiased
//! dequantize"). At the default
//! operating point (keep 25%, 4 bits) the mask encoding costs `0.125·P`
//! bytes against TK-SL's `6·k` — a ~4× smaller wire for the same k.
//!
//! Selection is fully deterministic: magnitude order with an ascending
//! flat-index tie-break, so equal-magnitude ties always resolve the same
//! way regardless of the partial sort's internal permutation.
//!
//! Wire layout (body, after the standard payload header), frozen by the
//! golden vectors in `tests/golden/codec_wire.json`:
//!
//! ```text
//! per sample (P = C·M·N elements, k = clamp(⌈P·keep_fraction⌉, 1, P)):
//!   f32  γ                      energy compensation (1.0 when degenerate)
//!   f32  min                    kept-value range minimum
//!   f32  max                    kept-value range maximum
//!   ⌈P/8⌉ bytes                 kept-position bitmap (bit j ⇒ element j)
//!   ⌈k·bits/8⌉ bytes            packed kept levels, ascending flat index
//! ```

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, BitReader, LinearQuantizer};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Mask-encoded top-k parameters.
#[derive(Debug, Clone, Copy)]
pub struct MaskTopKConfig {
    /// Fraction of elements kept by magnitude.
    pub keep_fraction: f64,
    /// Bit width of the kept-value quantizer.
    pub bits: u32,
}

impl Default for MaskTopKConfig {
    fn default() -> Self {
        MaskTopKConfig {
            keep_fraction: 0.25,
            bits: 4,
        }
    }
}

/// Mask-encoded top-k codec. Spatial domain, deterministic, fixed-rate
/// (payload size depends only on the shape).
#[derive(Debug, Clone)]
pub struct MaskTopKCodec {
    cfg: MaskTopKConfig,
}

impl MaskTopKCodec {
    /// Build from config.
    pub fn new(cfg: MaskTopKConfig) -> Self {
        assert!(
            cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0,
            "keep_fraction out of range"
        );
        assert!((1..=16).contains(&cfg.bits));
        MaskTopKCodec { cfg }
    }

    fn compress_impl(
        &self,
        x: &Tensor,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let per = c * m * n;
        let k = ((per as f64 * self.cfg.keep_fraction).ceil() as usize).clamp(1, per);
        let mask_bytes = (per + 7) / 8;
        let packed_bytes = (k * self.cfg.bits as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(body, b * (12 + mask_bytes + packed_bytes));
        let idx = &mut scratch.idx;
        let bitmap = &mut scratch.bitmap;
        let vals = &mut scratch.vals;
        for bi in 0..b {
            let sample = &x.data()[bi * per..(bi + 1) * per];
            bitmap.clear();
            bitmap.resize(mask_bytes, 0);
            if k == per {
                for byte in bitmap[..per / 8].iter_mut() {
                    *byte = 0xFF;
                }
                for j in (per / 8) * 8..per {
                    bitmap[j / 8] |= 1 << (j % 8);
                }
            } else {
                idx.clear();
                idx.extend(0..per as u32);
                // descending |x| with ascending-index tie-break: the kept
                // SET is deterministic even though the partial sort's
                // internal order is not
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    sample[b as usize]
                        .abs()
                        .partial_cmp(&sample[a as usize].abs())
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                for &j in idx[..k].iter() {
                    bitmap[j as usize / 8] |= 1 << (j % 8);
                }
            }
            // gather survivors in ascending flat order (a bitmap scan, not
            // a sort) while folding the energy ratio
            vals.clear();
            let mut total_e = 0.0f64;
            let mut kept_e = 0.0f64;
            for (j, &v) in sample.iter().enumerate() {
                let e = (v as f64) * (v as f64);
                total_e += e;
                if bitmap[j / 8] & (1 << (j % 8)) != 0 {
                    kept_e += e;
                    vals.push(v);
                }
            }
            let gamma = if kept_e > 0.0 {
                let g = (total_e / kept_e).sqrt() as f32;
                if g.is_finite() {
                    g
                } else {
                    1.0
                }
            } else {
                1.0
            };
            let q = LinearQuantizer::fit(self.cfg.bits, vals);
            w.f32(gamma);
            w.f32(q.min);
            w.f32(q.max);
            w.bytes(bitmap);
            pack_levels_into(vals, &q, &mut w);
        }
        Ok(Payload {
            kind: CodecKind::MaskTopK as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }
}

impl ActivationCodec for MaskTopKCodec {
    fn name(&self) -> &'static str {
        "mask-topk"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::MaskTopK
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, scratch, body)?;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let per = c * m * n;
        let mask_bytes = (per + 7) / 8;
        out.reset(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let bitmap = &mut scratch.bitmap;
        for bi in 0..b {
            let gamma = r.f32()?;
            ensure!(
                gamma.is_finite() && gamma > 0.0,
                "corrupt mask-topk gamma {gamma}"
            );
            let min = r.f32()?;
            let max = r.f32()?;
            bitmap.clear();
            bitmap.extend_from_slice(r.bytes(mask_bytes)?);
            // count survivors, ignoring padding bits past P
            let mut k = 0usize;
            for (i, &byte) in bitmap.iter().enumerate() {
                let pad = if i == per / 8 && per % 8 != 0 {
                    !((1u8 << (per % 8)) - 1)
                } else {
                    0
                };
                k += (byte & !pad).count_ones() as usize;
            }
            ensure!(k >= 1, "corrupt mask-topk bitmap: nothing kept");
            let q = LinearQuantizer {
                bits: self.cfg.bits,
                min,
                max,
            };
            let packed = r.bytes((k * self.cfg.bits as usize + 7) / 8)?;
            let mut br = BitReader::new(packed);
            let dst = &mut out.data_mut()[bi * per..(bi + 1) * per];
            for (j, d) in dst.iter_mut().enumerate() {
                if bitmap[j / 8] & (1 << (j % 8)) != 0 {
                    *d = gamma * q.dequantize(br.get(self.cfg.bits));
                }
            }
        }
        ensure!(
            r.remaining() == 0,
            "trailing bytes in mask-topk payload: {}",
            r.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    fn mk(keep: f64, bits: u32) -> MaskTopKCodec {
        MaskTopKCodec::new(MaskTopKConfig {
            keep_fraction: keep,
            bits,
        })
    }

    #[test]
    fn bit_layout_oracle() {
        // x = [0.5, -3.0, 2.0, 0.1], keep 0.5 ⇒ k=2, kept {1, 2};
        // γ = √(13.26/13); quantizer over [-3, 2] at 4 bits ⇒ levels 0, 15
        let x = Tensor::new(&[1, 1, 2, 2], vec![0.5, -3.0, 2.0, 0.1]);
        let p = mk(0.5, 4).compress(&x).unwrap();
        let mut r = BodyReader::new(&p.body);
        let gamma = r.f32().unwrap();
        assert!((gamma - (13.26f32 / 13.0).sqrt()).abs() < 1e-6);
        assert_eq!(r.f32().unwrap(), -3.0);
        assert_eq!(r.f32().unwrap(), 2.0);
        assert_eq!(r.bytes(1).unwrap(), &[0b0000_0110]);
        assert_eq!(r.bytes(1).unwrap(), &[0x0F]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn payload_size_is_shape_determined() {
        // fixed-rate: two very different tensors of one shape, same size
        let a = smooth_activations(&[2, 3, 8, 8], 61);
        let b = Tensor::zeros(&[2, 3, 8, 8]);
        let c = mk(0.25, 4);
        assert_eq!(
            c.compress(&a).unwrap().wire_bytes(),
            c.compress(&b).unwrap().wire_bytes()
        );
    }

    #[test]
    fn equal_magnitude_ties_keep_lowest_indices() {
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let p = mk(0.5, 4).compress(&x).unwrap();
        // bitmap bytes sit after γ/min/max
        assert_eq!(&p.body[12..14], &[0xFF, 0x00]);
    }

    #[test]
    fn error_decreases_with_keep_fraction() {
        let x = smooth_activations(&[2, 4, 10, 10], 62);
        let mut last = f64::INFINITY;
        for f in [0.1, 0.3, 0.6, 1.0] {
            let back = mk(f, 8).decompress(&mk(f, 8).compress(&x).unwrap()).unwrap();
            let err = back.rel_l2_error(&x);
            assert!(err <= last + 0.02, "f={f}: {err} vs {last}");
            last = err;
        }
        assert!(last < 0.02, "full keep at 8 bits, err={last}");
    }

    #[test]
    fn beats_index_coding_on_the_wire() {
        // at the shared default operating point the mask encoding must be
        // strictly smaller than TK-SL's 6-bytes-per-survivor
        let x = smooth_activations(&[2, 4, 14, 14], 63);
        let mask = mk(0.25, 4).compress(&x).unwrap().wire_bytes();
        let tk = crate::codec::TopKCodec::new(crate::codec::TopKConfig {
            keep_fraction: 0.25,
            random_fraction: 0.0,
            seed: 1,
        })
        .compress(&x)
        .unwrap()
        .wire_bytes();
        assert!(mask * 2 < tk, "mask {mask} vs index {tk}");
    }

    #[test]
    fn all_zero_and_single_element_degenerate_inputs() {
        let z = Tensor::zeros(&[1, 2, 3, 3]);
        let c = mk(0.25, 4);
        let back = c.decompress(&c.compress(&z).unwrap()).unwrap();
        assert_eq!(back.data(), z.data());
        let one = Tensor::new(&[1, 1, 1, 1], vec![-7.5]);
        let back1 = c.decompress(&c.compress(&one).unwrap()).unwrap();
        assert!((back1.data()[0] + 7.5).abs() < 1e-3);
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let x = smooth_activations(&[1, 2, 4, 4], 64);
        let c = mk(0.25, 4);
        // zeroed bitmap ⇒ k = 0
        let mut p = c.compress(&x).unwrap();
        for byte in p.body[12..16].iter_mut() {
            *byte = 0;
        }
        assert!(c.decompress(&p).is_err());
        // non-finite gamma
        let mut p2 = c.compress(&x).unwrap();
        p2.body[..4].copy_from_slice(&f32::INFINITY.to_le_bytes());
        assert!(c.decompress(&p2).is_err());
        // truncation and trailing garbage
        let mut p3 = c.compress(&x).unwrap();
        p3.body.pop();
        assert!(c.decompress(&p3).is_err());
        let mut p4 = c.compress(&x).unwrap();
        p4.body.push(0);
        assert!(c.decompress(&p4).is_err());
    }
}
