//! Wire format for compressed smashed data.
//!
//! A [`Payload`] is what travels over the (simulated) network: a small
//! self-describing header plus the codec-specific body. `to_bytes` /
//! `from_bytes` define the exact octet layout so the network simulator
//! charges true byte counts, and so corrupted payloads fail loudly.
//!
//! Layout (little-endian):
//!
//! ```text
//! 0    4  magic "SLFC"
//! 4    1  version (1)
//! 5    1  codec kind tag
//! 6    2  reserved
//! 8   16  shape (4 × u32: B, C, M, N)
//! 24   4  body length (u32)
//! 28   n  codec-specific body
//! ```

use anyhow::{bail, Result};

/// Magic prefix of every payload.
pub const MAGIC: &[u8; 4] = b"SLFC";
/// Current wire version.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 28;
/// Upper bound on the element count a wire header may claim (2^28 f32
/// elements = 1 GiB decoded). Parsing rejects anything larger so a
/// corrupted shape field can never drive an OOM-sized allocation in a
/// decoder.
pub const MAX_WIRE_ELEMS: usize = 1 << 28;

/// A compressed tensor en route between device and server.
#[derive(Debug, Clone)]
pub struct Payload {
    /// Codec tag (see `CodecKind as u8`).
    pub kind: u8,
    /// Original tensor shape (B, C, M, N).
    pub shape: [usize; 4],
    /// Codec-specific body.
    pub body: Vec<u8>,
}

impl Payload {
    /// Placeholder payload for the buffer-reuse API
    /// ([`crate::codec::ActivationCodec::compress_into`] overwrites every
    /// field; the body's capacity is what gets recycled).
    pub fn empty() -> Payload {
        Payload {
            kind: 0,
            shape: [0; 4],
            body: Vec::new(),
        }
    }

    /// Total wire size in bytes (header + body).
    pub fn wire_bytes(&self) -> usize {
        HEADER_BYTES + self.body.len()
    }

    /// Uncompressed f32 size of the carried tensor.
    pub fn raw_bytes(&self) -> usize {
        self.shape.iter().product::<usize>() * 4
    }

    /// Compression ratio `raw / wire` (>1 means smaller on the wire).
    pub fn compression_ratio(&self) -> f64 {
        self.raw_bytes() as f64 / self.wire_bytes() as f64
    }

    /// Serialize to the octet layout above.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_BYTES + self.body.len());
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        out.push(self.kind);
        out.extend_from_slice(&[0u8; 2]);
        for d in self.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.body.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.body);
        out
    }

    /// Parse from octets, validating magic/version/length.
    pub fn from_bytes(bytes: &[u8]) -> Result<Payload> {
        if bytes.len() < HEADER_BYTES {
            bail!("payload too short: {} bytes", bytes.len());
        }
        if &bytes[0..4] != MAGIC {
            bail!("bad payload magic");
        }
        if bytes[4] != VERSION {
            bail!("unsupported payload version {}", bytes[4]);
        }
        let kind = bytes[5];
        let mut shape = [0usize; 4];
        for (i, d) in shape.iter_mut().enumerate() {
            let off = 8 + i * 4;
            *d = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        }
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&n| n <= MAX_WIRE_ELEMS);
        if numel.is_none() {
            bail!("implausible payload shape {shape:?}");
        }
        let body_len =
            u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
        if bytes.len() != HEADER_BYTES + body_len {
            bail!(
                "payload length mismatch: header says {body_len}, have {}",
                bytes.len() - HEADER_BYTES
            );
        }
        Ok(Payload {
            kind,
            shape,
            body: bytes[HEADER_BYTES..].to_vec(),
        })
    }
}

/// Little-endian body writer.
#[derive(Debug, Default)]
pub struct BodyWriter {
    buf: Vec<u8>,
}

impl BodyWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// With reserved capacity.
    pub fn with_capacity(n: usize) -> Self {
        BodyWriter {
            buf: Vec::with_capacity(n),
        }
    }

    /// Writer over a recycled buffer: contents are cleared, capacity (plus
    /// at least `reserve` bytes) is kept — the zero-allocation steady-state
    /// path (`CodecScratch::take_body` supplies the buffer).
    pub fn from_vec(mut buf: Vec<u8>, reserve: usize) -> Self {
        buf.clear();
        buf.reserve(reserve);
        BodyWriter { buf }
    }

    /// Bit-level packer appending MSB-first levels directly to this body —
    /// no intermediate buffer, no copy. Call
    /// [`crate::quant::BitPacker::finish`] before writing further bytes.
    pub fn packer(&mut self) -> crate::quant::BitPacker<'_> {
        crate::quant::BitPacker::new(&mut self.buf)
    }

    /// Append a u8.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    /// Append a u16.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append a u32.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an f32.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an f16 (IEEE half, see [`f32_to_f16`]).
    pub fn f16(&mut self, v: f32) {
        self.buf.extend_from_slice(&f32_to_f16(v).to_le_bytes());
    }
    /// Append raw bytes.
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// Current length.
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    /// Finish, returning the buffer.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian body reader with bounds checking.
#[derive(Debug)]
pub struct BodyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BodyReader<'a> {
    /// Reader over a body slice.
    pub fn new(buf: &'a [u8]) -> Self {
        BodyReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated payload body: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a u8.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    /// Read a u16.
    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    /// Read a u32.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    /// Read an f32.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    /// Read an f16 into f32.
    pub fn f16(&mut self) -> Result<f32> {
        Ok(f16_to_f32(u16::from_le_bytes(
            self.take(2)?.try_into().unwrap(),
        )))
    }
    /// Read `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }
    /// Remaining unread bytes.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// f32 → IEEE 754 binary16 bits (round-to-nearest-even, with overflow→inf).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → inf
    }
    if unbiased >= -14 {
        // normal half
        let half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        // round to nearest even
        let round_bits = mant & 0x1FFF;
        if round_bits > 0x1000 || (round_bits == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        let out = (half_exp << 10) + half_mant; // mantissa carry rolls into exp
        return sign | out as u16;
    }
    if unbiased >= -24 {
        // subnormal half: half_mant = round(x / 2^-24) = full >> (-unbiased-1)
        let shift = (-unbiased - 1) as u32;
        let full = mant | 0x80_0000;
        let mut half_mant = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    sign // underflow → ±0
}

/// IEEE 754 binary16 bits → f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize (value = mant × 2^-24 = 1.f × 2^(-14-s))
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((112 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_roundtrip() {
        let p = Payload {
            kind: 3,
            shape: [2, 16, 14, 14],
            body: vec![1, 2, 3, 4, 5],
        };
        let bytes = p.to_bytes();
        assert_eq!(bytes.len(), p.wire_bytes());
        let q = Payload::from_bytes(&bytes).unwrap();
        assert_eq!(q.kind, 3);
        assert_eq!(q.shape, [2, 16, 14, 14]);
        assert_eq!(q.body, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn payload_rejects_corruption() {
        let p = Payload {
            kind: 1,
            shape: [1, 1, 2, 2],
            body: vec![0; 8],
        };
        let mut bytes = p.to_bytes();
        bytes[0] = b'X';
        assert!(Payload::from_bytes(&bytes).is_err());
        let mut bytes = p.to_bytes();
        bytes.truncate(bytes.len() - 1);
        assert!(Payload::from_bytes(&bytes).is_err());
        let mut bytes = p.to_bytes();
        bytes[4] = 99; // version
        assert!(Payload::from_bytes(&bytes).is_err());
    }

    #[test]
    fn body_writer_reader_roundtrip() {
        let mut w = BodyWriter::new();
        w.u8(7);
        w.u16(65535);
        w.u32(123456789);
        w.f32(-2.5);
        w.f16(1.5);
        w.bytes(&[9, 9]);
        let buf = w.finish();
        let mut r = BodyReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 65535);
        assert_eq!(r.u32().unwrap(), 123456789);
        assert_eq!(r.f32().unwrap(), -2.5);
        assert_eq!(r.f16().unwrap(), 1.5);
        assert_eq!(r.bytes(2).unwrap(), &[9, 9]);
        assert_eq!(r.remaining(), 0);
        assert!(r.u8().is_err());
    }

    #[test]
    fn f16_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 1.5, 0.25] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "v={v}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut rng = crate::rng::Pcg32::seeded(55);
        for _ in 0..2000 {
            let v = rng.normal() * 100.0;
            let back = f16_to_f32(f32_to_f16(v));
            let rel = ((back - v) / v.abs().max(1e-3)).abs();
            assert!(rel < 1e-3, "v={v} back={back}");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_to_f32(f32_to_f16(1e-12)), 0.0); // underflow
        // subnormal half survives approximately
        let v = 3.0e-6f32;
        let back = f16_to_f32(f32_to_f16(v));
        assert!((back - v).abs() / v < 0.05, "v={v} back={back}");
    }

    #[test]
    fn compression_ratio_math() {
        let p = Payload {
            kind: 0,
            shape: [1, 1, 10, 10],
            body: vec![0; 72],
        };
        // raw = 400, wire = 100 ⇒ 4×
        assert!((p.compression_ratio() - 4.0).abs() < 1e-9);
    }
}
