//! Fig. 4 row-1 ablation codecs: spatial-domain element selection by
//! magnitude or by deviation-from-mean, with the *same* downstream
//! quantization as SL-FAC's kept set.
//!
//! These isolate AFD's contribution: identical bit budget machinery, but
//! the "informative subset" is chosen in the spatial domain — the selection
//! strategy the paper argues retains high-magnitude noise and discards
//! low-magnitude informative features (§III-D.1).

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, unpack_levels, LinearQuantizer};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Selection ablation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SelectConfig {
    /// Fraction of elements kept per channel.
    pub keep_fraction: f64,
    /// Bit width for kept elements.
    pub bits: u32,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig {
            keep_fraction: 0.25,
            bits: 6,
        }
    }
}

/// Scoring strategy for selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Score {
    Magnitude,
    StdDeviation,
}

/// Shared implementation: keep the top-scoring fraction of each channel,
/// transmit a bitmap + quantized kept values.
#[derive(Debug, Clone)]
struct SelectCodec {
    cfg: SelectConfig,
    score: Score,
}

impl SelectCodec {
    fn compress_impl(
        &self,
        x: &Tensor,
        kind: CodecKind,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let plane = m * n;
        let keep = ((plane as f64 * self.cfg.keep_fraction).ceil() as usize).clamp(1, plane);
        let mut w = BodyWriter::from_vec(body, 0);
        let idx = &mut scratch.idx;
        let kept = &mut scratch.kept;
        let bitmap = &mut scratch.bitmap;
        let vals = &mut scratch.vals;
        for bi in 0..b {
            for ci in 0..c {
                let ch = x.channel(bi, ci);
                let mean = ch.iter().sum::<f32>() / plane as f32;
                let score = |v: f32| match self.score {
                    Score::Magnitude => v.abs(),
                    Score::StdDeviation => (v - mean).abs(),
                };
                idx.clear();
                idx.extend(0..plane as u32);
                idx.select_nth_unstable_by(keep - 1, |&a, &b| {
                    score(ch[b as usize])
                        .partial_cmp(&score(ch[a as usize]))
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                kept.clear();
                kept.extend_from_slice(&idx[..keep]);
                kept.sort_unstable();
                // bitmap of kept positions
                bitmap.clear();
                bitmap.resize((plane + 7) / 8, 0);
                for &i in kept.iter() {
                    bitmap[i as usize / 8] |= 1 << (i % 8);
                }
                w.bytes(bitmap);
                // quantize kept values with their own min/max
                vals.clear();
                vals.extend(kept.iter().map(|&i| ch[i as usize]));
                let q = LinearQuantizer::fit(self.cfg.bits, vals);
                w.f32(q.min);
                w.f32(q.max);
                pack_levels_into(vals, &q, &mut w);
            }
        }
        Ok(Payload {
            kind: kind as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }

    fn decompress_impl(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        out.reset(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let bitmap = &mut scratch.bitmap;
        let kept = &mut scratch.kept;
        let vals = &mut scratch.vals;
        for bi in 0..b {
            for ci in 0..c {
                bitmap.clear();
                bitmap.extend_from_slice(r.bytes((plane + 7) / 8)?);
                kept.clear();
                kept.extend((0..plane as u32).filter(|&i| {
                    bitmap[i as usize / 8] & (1 << (i % 8)) != 0
                }));
                ensure!(!kept.is_empty(), "corrupt selection bitmap");
                let q = LinearQuantizer {
                    bits: self.cfg.bits,
                    min: r.f32()?,
                    max: r.f32()?,
                };
                vals.clear();
                vals.resize(kept.len(), 0.0);
                unpack_levels(&mut r, &q, kept.len(), vals)?;
                let ch = out.channel_mut(bi, ci);
                for (&i, &v) in kept.iter().zip(vals.iter()) {
                    ch[i as usize] = v;
                }
            }
        }
        Ok(())
    }
}

/// Magnitude-based selection ablation ("Magnitude" curve in Fig. 4 row 1).
#[derive(Debug, Clone)]
pub struct MagnitudeSelectCodec(SelectCodec);

impl MagnitudeSelectCodec {
    /// Build from config.
    pub fn new(cfg: SelectConfig) -> Self {
        assert!(cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0);
        MagnitudeSelectCodec(SelectCodec {
            cfg,
            score: Score::Magnitude,
        })
    }
}

impl ActivationCodec for MagnitudeSelectCodec {
    fn name(&self) -> &'static str {
        "magnitude"
    }
    fn kind(&self) -> CodecKind {
        CodecKind::MagnitudeSelect
    }
    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }
    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }
    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.0.compress_impl(x, CodecKind::MagnitudeSelect, scratch, body)?;
        Ok(())
    }
    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        self.0.decompress_impl(p, scratch, out)
    }
}

/// STD-based selection ablation ("STD" curve in Fig. 4 row 1).
#[derive(Debug, Clone)]
pub struct StdSelectCodec(SelectCodec);

impl StdSelectCodec {
    /// Build from config.
    pub fn new(cfg: SelectConfig) -> Self {
        assert!(cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0);
        StdSelectCodec(SelectCodec {
            cfg,
            score: Score::StdDeviation,
        })
    }
}

impl ActivationCodec for StdSelectCodec {
    fn name(&self) -> &'static str {
        "std"
    }
    fn kind(&self) -> CodecKind {
        CodecKind::StdSelect
    }
    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }
    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }
    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.0.compress_impl(x, CodecKind::StdSelect, scratch, body)?;
        Ok(())
    }
    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        self.0.decompress_impl(p, scratch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    #[test]
    fn magnitude_keeps_largest() {
        let mut x = Tensor::zeros(&[1, 1, 4, 4]);
        x.data_mut()[7] = 9.0;
        x.data_mut()[2] = -6.0;
        let c = MagnitudeSelectCodec::new(SelectConfig {
            keep_fraction: 2.0 / 16.0,
            bits: 8,
        });
        let back = c.decompress(&c.compress(&x).unwrap()).unwrap();
        assert!((back.data()[7] - 9.0).abs() < 0.1);
        assert!((back.data()[2] + 6.0).abs() < 0.1);
        assert_eq!(back.data()[0], 0.0);
    }

    #[test]
    fn std_select_prefers_deviation_not_magnitude() {
        // Channel with large mean: magnitude keeps everything near the mean,
        // STD-based keeps the deviants.
        let mut x = Tensor::full(&[1, 1, 4, 4], 10.0);
        x.data_mut()[5] = 10.5; // biggest |x - mean|
        x.data_mut()[11] = 9.4;
        let c = StdSelectCodec::new(SelectConfig {
            keep_fraction: 2.0 / 16.0,
            bits: 8,
        });
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert!((back.data()[5] - 10.5).abs() < 0.05);
        assert!((back.data()[11] - 9.4).abs() < 0.05);
    }

    #[test]
    fn roundtrip_bounded_error_full_keep() {
        let x = smooth_activations(&[2, 3, 8, 8], 41);
        for codec in [
            Box::new(MagnitudeSelectCodec::new(SelectConfig {
                keep_fraction: 1.0,
                bits: 8,
            })) as Box<dyn ActivationCodec>,
            Box::new(StdSelectCodec::new(SelectConfig {
                keep_fraction: 1.0,
                bits: 8,
            })),
        ] {
            let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
            assert!(back.rel_l2_error(&x) < 0.02, "{}", codec.name());
        }
    }

    #[test]
    fn corrupt_bitmap_rejected() {
        let x = smooth_activations(&[1, 1, 4, 4], 42);
        let c = MagnitudeSelectCodec::new(SelectConfig::default());
        let mut p = c.compress(&x).unwrap();
        // zero the bitmap → "nothing kept" must error
        for b in p.body.iter_mut().take(2) {
            *b = 0;
        }
        assert!(c.decompress(&p).is_err());
    }
}
