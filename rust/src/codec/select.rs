//! Fig. 4 row-1 ablation codecs: spatial-domain element selection by
//! magnitude or by deviation-from-mean, with the *same* downstream
//! quantization as SL-FAC's kept set.
//!
//! These isolate AFD's contribution: identical bit budget machinery, but
//! the "informative subset" is chosen in the spatial domain — the selection
//! strategy the paper argues retains high-magnitude noise and discards
//! low-magnitude informative features (§III-D.1).

use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, unpack_levels, LinearQuantizer};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Selection ablation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SelectConfig {
    /// Fraction of elements kept per channel.
    pub keep_fraction: f64,
    /// Bit width for kept elements.
    pub bits: u32,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig {
            keep_fraction: 0.25,
            bits: 6,
        }
    }
}

/// Scoring strategy for selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Score {
    Magnitude,
    StdDeviation,
}

/// Shared implementation: keep the top-scoring fraction of each channel,
/// transmit a bitmap + quantized kept values.
#[derive(Debug, Clone)]
struct SelectCodec {
    cfg: SelectConfig,
    score: Score,
}

impl SelectCodec {
    fn compress_impl(&self, x: &Tensor, kind: CodecKind) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let plane = m * n;
        let keep = ((plane as f64 * self.cfg.keep_fraction).ceil() as usize).clamp(1, plane);
        let mut w = BodyWriter::new();
        for bi in 0..b {
            for ci in 0..c {
                let ch = x.channel(bi, ci);
                let mean = ch.iter().sum::<f32>() / plane as f32;
                let score = |v: f32| match self.score {
                    Score::Magnitude => v.abs(),
                    Score::StdDeviation => (v - mean).abs(),
                };
                let mut idx: Vec<u32> = (0..plane as u32).collect();
                idx.select_nth_unstable_by(keep - 1, |&a, &b| {
                    score(ch[b as usize])
                        .partial_cmp(&score(ch[a as usize]))
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                let mut kept = idx[..keep].to_vec();
                kept.sort_unstable();
                // bitmap of kept positions
                let mut bitmap = vec![0u8; (plane + 7) / 8];
                for &i in &kept {
                    bitmap[i as usize / 8] |= 1 << (i % 8);
                }
                w.bytes(&bitmap);
                // quantize kept values with their own min/max
                let vals: Vec<f32> = kept.iter().map(|&i| ch[i as usize]).collect();
                let q = LinearQuantizer::fit(self.cfg.bits, &vals);
                w.f32(q.min);
                w.f32(q.max);
                pack_levels_into(&vals, &q, &mut w);
            }
        }
        Ok(Payload {
            kind: kind as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }

    fn decompress_impl(&self, p: &Payload) -> Result<Tensor> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        let mut out = Tensor::zeros(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        for bi in 0..b {
            for ci in 0..c {
                let bitmap = r.bytes((plane + 7) / 8)?.to_vec();
                let kept: Vec<usize> = (0..plane)
                    .filter(|i| bitmap[i / 8] & (1 << (i % 8)) != 0)
                    .collect();
                ensure!(!kept.is_empty(), "corrupt selection bitmap");
                let q = LinearQuantizer {
                    bits: self.cfg.bits,
                    min: r.f32()?,
                    max: r.f32()?,
                };
                let mut vals = vec![0.0f32; kept.len()];
                unpack_levels(&mut r, &q, kept.len(), &mut vals)?;
                let ch = out.channel_mut(bi, ci);
                for (&i, &v) in kept.iter().zip(&vals) {
                    ch[i] = v;
                }
            }
        }
        Ok(out)
    }
}

/// Magnitude-based selection ablation ("Magnitude" curve in Fig. 4 row 1).
#[derive(Debug, Clone)]
pub struct MagnitudeSelectCodec(SelectCodec);

impl MagnitudeSelectCodec {
    /// Build from config.
    pub fn new(cfg: SelectConfig) -> Self {
        assert!(cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0);
        MagnitudeSelectCodec(SelectCodec {
            cfg,
            score: Score::Magnitude,
        })
    }
}

impl ActivationCodec for MagnitudeSelectCodec {
    fn name(&self) -> &'static str {
        "magnitude"
    }
    fn kind(&self) -> CodecKind {
        CodecKind::MagnitudeSelect
    }
    fn compress(&self, x: &Tensor) -> Result<Payload> {
        self.0.compress_impl(x, CodecKind::MagnitudeSelect)
    }
    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        self.0.decompress_impl(p)
    }
}

/// STD-based selection ablation ("STD" curve in Fig. 4 row 1).
#[derive(Debug, Clone)]
pub struct StdSelectCodec(SelectCodec);

impl StdSelectCodec {
    /// Build from config.
    pub fn new(cfg: SelectConfig) -> Self {
        assert!(cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0);
        StdSelectCodec(SelectCodec {
            cfg,
            score: Score::StdDeviation,
        })
    }
}

impl ActivationCodec for StdSelectCodec {
    fn name(&self) -> &'static str {
        "std"
    }
    fn kind(&self) -> CodecKind {
        CodecKind::StdSelect
    }
    fn compress(&self, x: &Tensor) -> Result<Payload> {
        self.0.compress_impl(x, CodecKind::StdSelect)
    }
    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        self.0.decompress_impl(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    #[test]
    fn magnitude_keeps_largest() {
        let mut x = Tensor::zeros(&[1, 1, 4, 4]);
        x.data_mut()[7] = 9.0;
        x.data_mut()[2] = -6.0;
        let c = MagnitudeSelectCodec::new(SelectConfig {
            keep_fraction: 2.0 / 16.0,
            bits: 8,
        });
        let back = c.decompress(&c.compress(&x).unwrap()).unwrap();
        assert!((back.data()[7] - 9.0).abs() < 0.1);
        assert!((back.data()[2] + 6.0).abs() < 0.1);
        assert_eq!(back.data()[0], 0.0);
    }

    #[test]
    fn std_select_prefers_deviation_not_magnitude() {
        // Channel with large mean: magnitude keeps everything near the mean,
        // STD-based keeps the deviants.
        let mut x = Tensor::full(&[1, 1, 4, 4], 10.0);
        x.data_mut()[5] = 10.5; // biggest |x - mean|
        x.data_mut()[11] = 9.4;
        let c = StdSelectCodec::new(SelectConfig {
            keep_fraction: 2.0 / 16.0,
            bits: 8,
        });
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert!((back.data()[5] - 10.5).abs() < 0.05);
        assert!((back.data()[11] - 9.4).abs() < 0.05);
    }

    #[test]
    fn roundtrip_bounded_error_full_keep() {
        let x = smooth_activations(&[2, 3, 8, 8], 41);
        for codec in [
            Box::new(MagnitudeSelectCodec::new(SelectConfig {
                keep_fraction: 1.0,
                bits: 8,
            })) as Box<dyn ActivationCodec>,
            Box::new(StdSelectCodec::new(SelectConfig {
                keep_fraction: 1.0,
                bits: 8,
            })),
        ] {
            let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
            assert!(back.rel_l2_error(&x) < 0.02, "{}", codec.name());
        }
    }

    #[test]
    fn corrupt_bitmap_rejected() {
        let x = smooth_activations(&[1, 1, 4, 4], 42);
        let c = MagnitudeSelectCodec::new(SelectConfig::default());
        let mut p = c.compress(&x).unwrap();
        // zero the bitmap → "nothing kept" must error
        for b in p.body.iter_mut().take(2) {
            *b = 0;
        }
        assert!(c.decompress(&p).is_err());
    }
}
