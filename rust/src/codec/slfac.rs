//! The SL-FAC codec — Algorithm 1 of the paper (AFD + FQC).
//!
//! Input: per-channel DCT coefficient planes of the smashed data (produced
//! by the L1 Pallas kernel inside the HLO graph on the wire path, or by the
//! Rust [`crate::dct`] module in standalone mode).
//!
//! Per channel `(b, c)`:
//! 1. **AFD** — zig-zag scan; spectral energy `E = X²` (Eq. 3); cumulative
//!    energy ratio (Eq. 4); split at the smallest `k*` with ratio ≥ θ.
//! 2. **FQC** — group mean energies (Eq. 5), log map (Eq. 6), bit widths
//!    via `tanh` scaling (Eq. 7), then min-max linear quantization of each
//!    group with its own range (Eq. 8), bit-packed.
//!
//! Decompression inverts Eq. 9, inverse zig-zag, and (on the wire path)
//! hands the coefficient planes to the `idct` HLO artifact.
//!
//! ### Two compression kernels, one byte stream
//!
//! The per-channel compressor exists twice (selected by
//! [`SlFacConfig::fast_path`], config key `codec_fast_path`):
//!
//! * **fused** (default) — one sweep computes the zig-zag scatter and total
//!   energy, a second sweep finds `k*`, both groups' energies *and* both
//!   min/max ranges, then a final sweep quantizes and word-packs straight
//!   into the payload body. Zero heap allocations in steady state (scratch
//!   arena + recycled body).
//! * **reference** — the historical multi-pass path
//!   ([`crate::freq::afd_channel_into`] + separate quantizer fits +
//!   intermediate bit buffer), kept for debugging and cross-validation.
//!
//! The fused kernel folds every f64 sum and every min/max in exactly the
//! reference's element order, so both kernels are **bit-identical on the
//! wire** — enforced by `tests/codec_differential.rs` over randomized
//! shapes, seeds, θ, and bit bounds. Decompression has a single
//! (scratch-based) implementation. See ARCHITECTURE.md "Codec hot path &
//! memory discipline".
//!
//! ### Wire body layout (after the common payload header)
//!
//! ```text
//! per channel (B·C times, in NCHW order):
//!   u16  k*          (low-frequency count)
//!   u8   b_low       u8 b_high
//!   f32  min_low     f32 max_low
//!   f32  min_high    f32 max_high    (present only if k* < M·N)
//!   then ⌈(k*·b_low + (MN−k*)·b_high) / 8⌉ packed bytes
//! ```
//!
//! The 12–20 byte per-channel header is the "metadata overhead" the paper's
//! communication accounting includes; with MNIST-scale planes (14×14) and
//! the default bounds it is ≈6% of the payload. This layout is **frozen**
//! (wire version 1); any change requires a payload version bump and a
//! golden-vector re-bless.

use super::plan::{CodecPlan, CodecScratch};
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::freq::ZigZag;
use crate::quant::{allocate_bits, AllocationConfig, BitReader, BitWriter, LinearQuantizer};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// SL-FAC hyper-parameters (paper §III-A.4: θ=0.9, bits ∈ [2, 8]).
#[derive(Debug, Clone, Copy)]
pub struct SlFacConfig {
    /// Energy threshold θ for the AFD split.
    pub theta: f64,
    /// FQC bit-width bounds.
    pub alloc: AllocationConfig,
    /// Fused single-pass kernel (default) vs multi-pass reference kernel.
    /// Bit-identical wire bytes either way; see the module docs.
    pub fast_path: bool,
}

impl Default for SlFacConfig {
    fn default() -> Self {
        SlFacConfig {
            theta: 0.9,
            alloc: AllocationConfig::default(),
            fast_path: true,
        }
    }
}

/// The paper's codec. See module docs.
#[derive(Debug, Clone)]
pub struct SlFacCodec {
    cfg: SlFacConfig,
}

impl SlFacCodec {
    /// Build with the given config (validated).
    pub fn new(cfg: SlFacConfig) -> Self {
        cfg.alloc.validate().expect("invalid FQC bit bounds");
        assert!(
            cfg.theta > 0.0 && cfg.theta <= 1.0,
            "theta must be in (0, 1], got {}",
            cfg.theta
        );
        SlFacCodec { cfg }
    }

    /// Access the config.
    pub fn config(&self) -> &SlFacConfig {
        &self.cfg
    }

    /// Fused per-channel kernel: AFD split, FQC allocation, quantizer
    /// ranges, and word-level packing in three sweeps over the zig-zag
    /// sequence, allocation-free and bit-identical to
    /// [`Self::compress_channel_reference`] (same f64 fold order, same
    /// min/max fold, same quantize arithmetic, same byte layout).
    fn compress_channel_fused(
        &self,
        zz: &ZigZag,
        plane: &[f32],
        scratch: &mut CodecScratch,
        w: &mut BodyWriter,
    ) {
        let len = plane.len();
        debug_assert_eq!(len, zz.scan.len());
        let seq = &mut scratch.seq;
        seq.resize(len, 0.0);

        // sweep 1 — zig-zag scatter + total spectral energy (Eq. 3),
        // folded in scan order exactly like the reference.
        let mut total = 0.0f64;
        for (pos, &rm) in zz.scan.iter().enumerate() {
            let c = plane[rm as usize];
            seq[pos] = c;
            total += (c as f64) * (c as f64);
        }

        // sweep 2 — k* (Eq. 4) plus both groups' energies (Eq. 5) and
        // min/max ranges, found online in one pass.
        let k: usize;
        let e_low: f64;
        let lo_low: f32;
        let hi_low: f32;
        let (mut e_high, mut lo_high, mut hi_high) = (0.0f64, f32::INFINITY, f32::NEG_INFINITY);
        if total <= 0.0 {
            // degenerate all-zero channel: DC alone (Algorithm 1 edge case)
            k = 1;
            e_low = (seq[0] as f64) * (seq[0] as f64);
            let (a, b) = crate::tensor::min_max(&seq[..1]);
            lo_low = a;
            hi_low = b;
            for &c in &seq[1..] {
                e_high += (c as f64) * (c as f64);
            }
            let (a, b) = crate::tensor::min_max(&seq[1..]);
            lo_high = a;
            hi_high = b;
        } else {
            let target = self.cfg.theta * total;
            let mut acc = 0.0f64;
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            let mut split = len; // theta > 1 (or NaN energies) ⇒ all low
            for (i, &c) in seq.iter().enumerate() {
                acc += (c as f64) * (c as f64);
                if !c.is_nan() {
                    lo = lo.min(c);
                    hi = hi.max(c);
                }
                if acc >= target {
                    split = i + 1;
                    break;
                }
            }
            k = split;
            // acc folded seq[..k] in ascending order from 0.0 — the exact
            // addend sequence of the reference's separate Eq. 5 sum
            e_low = acc;
            if lo > hi {
                // all-NaN group: min_max's empty convention
                lo_low = 0.0;
                hi_low = 0.0;
            } else {
                lo_low = lo;
                hi_low = hi;
            }
            for &c in &seq[k..] {
                e_high += (c as f64) * (c as f64);
                if !c.is_nan() {
                    lo_high = lo_high.min(c);
                    hi_high = hi_high.max(c);
                }
            }
        }
        if lo_high > hi_high {
            lo_high = 0.0;
            hi_high = 0.0;
        }
        let n_high = len - k;
        let mean_low = e_low / k as f64;
        let mean_high = if n_high == 0 {
            0.0
        } else {
            e_high / n_high as f64
        };
        let (b_low, b_high) = allocate_bits(&self.cfg.alloc, mean_low, mean_high);

        // header (frozen layout — see module docs)
        let q_low = LinearQuantizer {
            bits: b_low,
            min: lo_low,
            max: hi_low,
        };
        w.u16(k as u16);
        w.u8(b_low as u8);
        w.u8(b_high as u8);
        w.f32(q_low.min);
        w.f32(q_low.max);
        let q_high = if k < len {
            let q = LinearQuantizer {
                bits: b_high,
                min: lo_high,
                max: hi_high,
            };
            w.f32(q.min);
            w.f32(q.max);
            Some(q)
        } else {
            None
        };

        // sweep 3 — quantize + word-pack straight into the payload body
        let mut p = w.packer();
        for &x in &seq[..k] {
            p.put(q_low.quantize(x), b_low);
        }
        if let Some(q) = &q_high {
            for &x in &seq[k..] {
                p.put(q.quantize(x), b_high);
            }
        }
        p.finish();
    }

    /// Reference per-channel kernel: the historical multi-pass path —
    /// [`crate::freq::afd_channel_into`], separate quantizer fits, and an
    /// intermediate bit buffer. Kept reachable (`codec_fast_path = false`)
    /// for debugging and as the differential-test oracle.
    fn compress_channel_reference(
        &self,
        zz: &ZigZag,
        plane: &[f32],
        scratch: &mut CodecScratch,
        w: &mut BodyWriter,
    ) {
        let split = crate::freq::afd_channel_into(zz, plane, self.cfg.theta, &mut scratch.seq);
        let k = split.k;
        let len = plane.len();
        let (b_low, b_high) =
            allocate_bits(&self.cfg.alloc, split.mean_energy_low, split.mean_energy_high);

        let low = &scratch.seq[..k];
        let high = &scratch.seq[k..];
        let q_low = LinearQuantizer::fit(b_low, low);
        w.u16(k as u16);
        w.u8(b_low as u8);
        w.u8(b_high as u8);
        w.f32(q_low.min);
        w.f32(q_low.max);
        let q_high = if k < len {
            let q = LinearQuantizer::fit(b_high, high);
            w.f32(q.min);
            w.f32(q.max);
            Some(q)
        } else {
            None
        };

        let mut bits = BitWriter::with_capacity((len * b_low as usize + 7) / 8);
        for &x in low {
            bits.put(q_low.quantize(x), b_low);
        }
        if let Some(q) = &q_high {
            for &x in high {
                bits.put(q.quantize(x), b_high);
            }
        }
        w.bytes(&bits.finish());
    }

    /// Shared compression body over a (possibly recycled) body buffer.
    fn compress_impl(
        &self,
        x: &Tensor,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let plan = CodecPlan::for_shape(m, n);
        // rough capacity guess: headers + ~mid bits per coefficient
        let mid_bits = (self.cfg.alloc.b_min + self.cfg.alloc.b_max) as usize / 2;
        let cap = b * c * (20 + (m * n * mid_bits + 7) / 8);
        let mut w = BodyWriter::from_vec(body, cap);
        for bi in 0..b {
            for ci in 0..c {
                let plane = x.channel(bi, ci);
                if self.cfg.fast_path {
                    self.compress_channel_fused(&plan.zz, plane, scratch, &mut w);
                } else {
                    self.compress_channel_reference(&plan.zz, plane, scratch, &mut w);
                }
            }
        }
        Ok(Payload {
            kind: CodecKind::SlFac as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }

    /// Per-channel decoder (single implementation for both kernel modes):
    /// header parse, word-level unpack + dequantize into the scratch
    /// sequence, inverse zig-zag into the output plane.
    fn decompress_channel(
        zz: &ZigZag,
        r: &mut BodyReader,
        seq: &mut Vec<f32>,
        out_plane: &mut [f32],
    ) -> Result<()> {
        let len = out_plane.len();
        let k = r.u16()? as usize;
        ensure!(k >= 1 && k <= len, "corrupt k*={k} for plane of {len}");
        let b_low = r.u8()? as u32;
        let b_high = r.u8()? as u32;
        ensure!(
            (1..=16).contains(&b_low) && b_high <= 16,
            "corrupt bit widths ({b_low}, {b_high})"
        );
        let min_low = r.f32()?;
        let max_low = r.f32()?;
        let q_low = LinearQuantizer {
            bits: b_low,
            min: min_low,
            max: max_low,
        };
        let q_high = if k < len {
            let min_high = r.f32()?;
            let max_high = r.f32()?;
            Some(LinearQuantizer {
                bits: b_high.max(1),
                min: min_high,
                max: max_high,
            })
        } else {
            None
        };
        let packed_bits = k * b_low as usize + (len - k) * b_high as usize;
        let packed_bytes = (packed_bits + 7) / 8;
        let packed = r.bytes(packed_bytes)?;
        let mut bits = BitReader::new(packed);
        // zig-zag sequence reconstruction into the reusable scratch
        seq.resize(len, 0.0);
        for s in seq.iter_mut().take(k) {
            *s = q_low.dequantize(bits.get(b_low));
        }
        if let Some(q) = &q_high {
            for s in seq.iter_mut().skip(k) {
                *s = q.dequantize(bits.get(b_high));
            }
        }
        zz.invert(seq, out_plane);
        Ok(())
    }

    /// Shared decompression body into a caller-owned tensor.
    fn decompress_impl(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plan = CodecPlan::for_shape(m, n);
        // dense decode: zz.invert overwrites every element of every plane
        out.reset_dense(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        for bi in 0..b {
            for ci in 0..c {
                Self::decompress_channel(
                    &plan.zz,
                    &mut r,
                    &mut scratch.seq,
                    out.channel_mut(bi, ci),
                )?;
            }
        }
        ensure!(r.remaining() == 0, "trailing bytes in SL-FAC payload");
        Ok(())
    }
}

impl ActivationCodec for SlFacCodec {
    fn name(&self) -> &'static str {
        "slfac"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::SlFac
    }

    fn frequency_domain(&self) -> bool {
        true
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, scratch, body)?;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        self.decompress_impl(p, scratch, out)
    }
}

/// Ablation codec: AFD split retained, but both groups get the same mid bit
/// width — isolates FQC's contribution ("SL-FAC w/o FQC", Fig. 4 row 2).
#[derive(Debug, Clone)]
pub struct AfdUniformCodec {
    inner: SlFacCodec,
}

impl AfdUniformCodec {
    /// θ for the split; `bits` for both groups.
    pub fn new(theta: f64, bits: u32) -> Self {
        Self::with_fast_path(theta, bits, true)
    }

    /// As [`AfdUniformCodec::new`] with an explicit kernel-mode choice.
    pub fn with_fast_path(theta: f64, bits: u32, fast_path: bool) -> Self {
        AfdUniformCodec {
            inner: SlFacCodec::new(SlFacConfig {
                theta,
                alloc: AllocationConfig {
                    b_min: bits,
                    b_max: bits,
                },
                fast_path,
            }),
        }
    }
}

impl ActivationCodec for AfdUniformCodec {
    fn name(&self) -> &'static str {
        "afd-uniform"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::AfdUniform
    }

    fn frequency_domain(&self) -> bool {
        true
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        // routes through our compress_into, which restamps the kind tag
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        self.inner.compress_into(x, rng, scratch, out)?;
        out.kind = CodecKind::AfdUniform as u8;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        self.inner.decompress_into(p, scratch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;
    use crate::dct::Dct2d;

    fn coeffs_of(shape: &[usize], seed: u64) -> Tensor {
        Dct2d::forward_tensor(&smooth_activations(shape, seed))
    }

    #[test]
    fn roundtrip_preserves_shape_and_low_error() {
        let x = coeffs_of(&[2, 6, 14, 14], 1);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        let back = codec.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        // θ=0.9 bounds the *retained* energy: reconstruction error is at
        // most ~sqrt(1-θ) of the signal (F_h is coarsely quantized).
        let err = back.rel_l2_error(&x);
        assert!(err < (1.0f64 - 0.9).sqrt() + 0.05, "rel err {err}");
    }

    #[test]
    fn fused_and_reference_kernels_are_bit_identical() {
        // the tentpole invariant, at unit-test granularity (the randomized
        // campaign lives in tests/codec_differential.rs)
        for (shape, seed, theta) in [
            (&[2usize, 4, 14, 14][..], 11u64, 0.9f64),
            (&[1, 1, 6, 6][..], 12, 0.5),
            (&[3, 2, 8, 8][..], 13, 1.0),
            (&[1, 3, 7, 9][..], 14, 0.95),
        ] {
            let x = coeffs_of(shape, seed);
            let fast = SlFacCodec::new(SlFacConfig {
                theta,
                fast_path: true,
                ..Default::default()
            });
            let reference = SlFacCodec::new(SlFacConfig {
                theta,
                fast_path: false,
                ..Default::default()
            });
            let pf = fast.compress(&x).unwrap();
            let pr = reference.compress(&x).unwrap();
            assert_eq!(pf.to_bytes(), pr.to_bytes(), "shape {shape:?} θ={theta}");
            assert_eq!(
                fast.decompress(&pf).unwrap().data(),
                reference.decompress(&pr).unwrap().data()
            );
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_transparent() {
        // one arena reused for growing/shrinking planes must not change
        // bytes vs fresh arenas
        let codec = SlFacCodec::new(SlFacConfig::default());
        let mut scratch = CodecScratch::new();
        let mut rng = crate::rng::Pcg32::seeded(0);
        let mut out = Payload::empty();
        for (shape, seed) in [
            (&[1usize, 2, 14, 14][..], 21u64),
            (&[1, 2, 4, 4][..], 22),
            (&[2, 3, 9, 11][..], 23),
        ] {
            let x = coeffs_of(shape, seed);
            codec
                .compress_into(&x, &mut rng, &mut scratch, &mut out)
                .unwrap();
            let fresh = codec.compress(&x).unwrap();
            assert_eq!(out.to_bytes(), fresh.to_bytes(), "{shape:?}");
            let mut t = Tensor::zeros(&[1]);
            codec.decompress_into(&out, &mut scratch, &mut t).unwrap();
            assert_eq!(t.data(), codec.decompress(&fresh).unwrap().data());
        }
    }

    #[test]
    fn compresses_smooth_data_well() {
        let x = coeffs_of(&[4, 8, 14, 14], 2);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        assert!(
            p.compression_ratio() > 3.0,
            "ratio {}",
            p.compression_ratio()
        );
    }

    #[test]
    fn higher_theta_higher_fidelity() {
        // Fig. 3's mechanism at codec level: raising θ moves more energy
        // into the finely-quantized F_l, so fidelity at the endpoints must
        // improve markedly (local non-monotonicity between neighboring θ is
        // possible because the F_h range shifts with the split point).
        let x = coeffs_of(&[2, 4, 14, 14], 3);
        let err_at = |theta: f64| {
            let codec = SlFacCodec::new(SlFacConfig {
                theta,
                ..Default::default()
            });
            codec
                .decompress(&codec.compress(&x).unwrap())
                .unwrap()
                .rel_l2_error(&x)
        };
        let lo = err_at(0.5);
        let hi = err_at(0.99);
        assert!(hi < lo, "err(0.99)={hi} should beat err(0.5)={lo}");
        assert!(hi < 0.12, "err at theta=0.99 is {hi}");
    }

    #[test]
    fn low_group_gets_more_bits_than_high() {
        // Parse the wire body of a single-channel payload and check Eq. 7's
        // intent: the informative group is quantized more finely.
        let x = coeffs_of(&[1, 1, 14, 14], 4);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        let mut r = BodyReader::new(&p.body);
        let _k = r.u16().unwrap();
        let b_low = r.u8().unwrap();
        let b_high = r.u8().unwrap();
        assert!(b_low > b_high, "b_low={b_low} b_high={b_high}");
        assert!(b_low <= 8 && b_high >= 2);
    }

    #[test]
    fn all_low_group_when_theta_one() {
        let x = coeffs_of(&[1, 2, 8, 8], 5);
        let codec = SlFacCodec::new(SlFacConfig {
            theta: 1.0,
            ..Default::default()
        });
        let p = codec.compress(&x).unwrap();
        let back = codec.decompress(&p).unwrap();
        // With everything in F_l at b_max the reconstruction is very tight.
        assert!(back.rel_l2_error(&x) < 0.01);
    }

    #[test]
    fn zero_tensor_roundtrips() {
        let x = Tensor::zeros(&[1, 3, 7, 9]);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert_eq!(back.data(), x.data());
    }

    #[test]
    fn corrupt_payload_is_rejected_not_panicking() {
        let x = coeffs_of(&[1, 2, 6, 6], 6);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let mut p = codec.compress(&x).unwrap();
        p.body.truncate(p.body.len() / 2);
        assert!(codec.decompress(&p).is_err());
        // corrupt k*
        let mut p2 = codec.compress(&x).unwrap();
        p2.body[0] = 0xFF;
        p2.body[1] = 0xFF;
        assert!(codec.decompress(&p2).is_err());
    }

    #[test]
    fn afd_uniform_is_worse_or_equal_at_same_budget() {
        // FQC's adaptive allocation should not lose to flat allocation when
        // both use the same mean bit count on energy-skewed data.
        let x = coeffs_of(&[4, 6, 14, 14], 7);
        let slfac = SlFacCodec::new(SlFacConfig::default());
        let p_s = slfac.compress(&x).unwrap();
        let err_s = slfac.decompress(&p_s).unwrap().rel_l2_error(&x);

        // flat codec sized to at least slfac's bytes
        let mut err_flat = f64::INFINITY;
        for bits in 2..=8 {
            let flat = AfdUniformCodec::new(0.9, bits);
            let p_f = flat.compress(&x).unwrap();
            if p_f.wire_bytes() >= p_s.wire_bytes() {
                err_flat = flat.decompress(&p_f).unwrap().rel_l2_error(&x);
                break;
            }
        }
        assert!(
            err_s <= err_flat * 1.05,
            "slfac {err_s} vs flat {err_flat}"
        );
    }

    #[test]
    fn property_roundtrip_arbitrary_shapes_and_thetas() {
        crate::testing::prop("slfac roundtrip", 60, |g| {
            let shape = g.bchw_shape();
            let theta = *g.choose(&[0.5f64, 0.7, 0.8, 0.9, 0.95, 1.0]);
            let x = g.tensor(&shape, 2.0);
            let coeffs = Dct2d::forward_tensor(&x);
            let codec = SlFacCodec::new(SlFacConfig {
                theta,
                ..Default::default()
            });
            let p = codec.compress(&coeffs).unwrap();
            let back = codec.decompress(&p).unwrap();
            assert_eq!(back.shape(), coeffs.shape());
            for v in back.data() {
                assert!(v.is_finite());
            }
            // wire-format determinism
            let p2 = codec.compress(&coeffs).unwrap();
            assert_eq!(p.body, p2.body, "compression must be deterministic");
        });
    }

    #[test]
    fn metadata_overhead_is_modest() {
        let x = coeffs_of(&[1, 16, 14, 14], 8);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        // per-channel header is 20 bytes; body must be dominated by packed bits
        let header_bytes = 16 * 20;
        assert!(
            (header_bytes as f64) < 0.3 * p.body.len() as f64,
            "headers {header_bytes} vs body {}",
            p.body.len()
        );
    }
}
