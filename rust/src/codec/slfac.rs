//! The SL-FAC codec — Algorithm 1 of the paper (AFD + FQC).
//!
//! Input: per-channel DCT coefficient planes of the smashed data (produced
//! by the L1 Pallas kernel inside the HLO graph on the wire path, or by the
//! Rust [`crate::dct`] module in standalone mode).
//!
//! Per channel `(b, c)`:
//! 1. **AFD** — zig-zag scan; spectral energy `E = X²` (Eq. 3); cumulative
//!    energy ratio (Eq. 4); split at the smallest `k*` with ratio ≥ θ.
//! 2. **FQC** — group mean energies (Eq. 5), log map (Eq. 6), bit widths
//!    via `tanh` scaling (Eq. 7), then min-max linear quantization of each
//!    group with its own range (Eq. 8), bit-packed.
//!
//! Decompression inverts Eq. 9, inverse zig-zag, and (on the wire path)
//! hands the coefficient planes to the `idct` HLO artifact.
//!
//! ### Wire body layout (after the common payload header)
//!
//! ```text
//! per channel (B·C times, in NCHW order):
//!   u16  k*          (low-frequency count)
//!   u8   b_low       u8 b_high
//!   f32  min_low     f32 max_low
//!   f32  min_high    f32 max_high    (present only if k* < M·N)
//!   then ⌈(k*·b_low + (MN−k*)·b_high) / 8⌉ packed bytes
//! ```
//!
//! The 12–20 byte per-channel header is the "metadata overhead" the paper's
//! communication accounting includes; with MNIST-scale planes (14×14) and
//! the default bounds it is ≈6% of the payload.

use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::freq::zigzag;
use crate::quant::{allocate_bits, AllocationConfig, BitReader, BitWriter, LinearQuantizer};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// SL-FAC hyper-parameters (paper §III-A.4: θ=0.9, bits ∈ [2, 8]).
#[derive(Debug, Clone, Copy)]
pub struct SlFacConfig {
    /// Energy threshold θ for the AFD split.
    pub theta: f64,
    /// FQC bit-width bounds.
    pub alloc: AllocationConfig,
}

impl Default for SlFacConfig {
    fn default() -> Self {
        SlFacConfig {
            theta: 0.9,
            alloc: AllocationConfig::default(),
        }
    }
}

/// The paper's codec. See module docs.
#[derive(Debug, Clone)]
pub struct SlFacCodec {
    cfg: SlFacConfig,
}

impl SlFacCodec {
    /// Build with the given config (validated).
    pub fn new(cfg: SlFacConfig) -> Self {
        cfg.alloc.validate().expect("invalid FQC bit bounds");
        assert!(
            cfg.theta > 0.0 && cfg.theta <= 1.0,
            "theta must be in (0, 1], got {}",
            cfg.theta
        );
        SlFacCodec { cfg }
    }

    /// Access the config.
    pub fn config(&self) -> &SlFacConfig {
        &self.cfg
    }

    /// Compress one channel plane into the body writer, reusing `scratch`
    /// for the zig-zag sequence (zero per-channel allocations on the hot
    /// path — §Perf L3 iteration 1). Returns `(k*, b_low, b_high)`.
    fn compress_channel(
        &self,
        zz: &crate::freq::ZigZag,
        plane: &[f32],
        scratch: &mut Vec<f32>,
        w: &mut BodyWriter,
    ) -> (usize, u32, u32) {
        let split = crate::freq::afd_channel_into(zz, plane, self.cfg.theta, scratch);
        let k = split.k;
        let len = plane.len();
        let (b_low, b_high) =
            allocate_bits(&self.cfg.alloc, split.mean_energy_low, split.mean_energy_high);

        let low = &scratch[..k];
        let high = &scratch[k..];
        let q_low = LinearQuantizer::fit(b_low, low);
        w.u16(k as u16);
        w.u8(b_low as u8);
        w.u8(b_high as u8);
        w.f32(q_low.min);
        w.f32(q_low.max);
        let q_high = if k < len {
            let q = LinearQuantizer::fit(b_high, high);
            w.f32(q.min);
            w.f32(q.max);
            Some(q)
        } else {
            None
        };

        let mut bits = BitWriter::with_capacity((len * b_low as usize + 7) / 8);
        for &x in low {
            bits.put(q_low.quantize(x), b_low);
        }
        if let Some(q) = &q_high {
            for &x in high {
                bits.put(q.quantize(x), b_high);
            }
        }
        w.bytes(&bits.finish());
        (k, b_low, b_high)
    }

    fn decompress_channel(
        zz: &crate::freq::ZigZag,
        r: &mut BodyReader,
        seq: &mut Vec<f32>,
        out_plane: &mut [f32],
    ) -> Result<()> {
        let len = out_plane.len();
        let k = r.u16()? as usize;
        ensure!(k >= 1 && k <= len, "corrupt k*={k} for plane of {len}");
        let b_low = r.u8()? as u32;
        let b_high = r.u8()? as u32;
        ensure!(
            (1..=16).contains(&b_low) && b_high <= 16,
            "corrupt bit widths ({b_low}, {b_high})"
        );
        let min_low = r.f32()?;
        let max_low = r.f32()?;
        let q_low = LinearQuantizer {
            bits: b_low,
            min: min_low,
            max: max_low,
        };
        let q_high = if k < len {
            let min_high = r.f32()?;
            let max_high = r.f32()?;
            Some(LinearQuantizer {
                bits: b_high.max(1),
                min: min_high,
                max: max_high,
            })
        } else {
            None
        };
        let packed_bits = k * b_low as usize + (len - k) * b_high as usize;
        let packed_bytes = (packed_bits + 7) / 8;
        let packed = r.bytes(packed_bytes)?;
        let mut bits = BitReader::new(packed);
        // zig-zag sequence reconstruction into the reusable scratch
        seq.resize(len, 0.0);
        for s in seq.iter_mut().take(k) {
            *s = q_low.dequantize(bits.get(b_low));
        }
        if let Some(q) = &q_high {
            for s in seq.iter_mut().skip(k) {
                *s = q.dequantize(bits.get(b_high));
            }
        }
        zz.invert(seq, out_plane);
        Ok(())
    }
}

impl ActivationCodec for SlFacCodec {
    fn name(&self) -> &'static str {
        "slfac"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::SlFac
    }

    fn frequency_domain(&self) -> bool {
        true
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let zz = zigzag(m, n);
        // rough capacity guess: headers + ~mid bits per coefficient
        let mid_bits = (self.cfg.alloc.b_min + self.cfg.alloc.b_max) as usize / 2;
        let mut w =
            BodyWriter::with_capacity(b * c * (20 + (m * n * mid_bits + 7) / 8));
        let mut scratch = Vec::with_capacity(m * n);
        for bi in 0..b {
            for ci in 0..c {
                self.compress_channel(&zz, x.channel(bi, ci), &mut scratch, &mut w);
            }
        }
        Ok(Payload {
            kind: CodecKind::SlFac as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        let [b, c, m, n] = p.shape;
        let zz = zigzag(m, n);
        let mut out = Tensor::zeros(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let mut seq = Vec::with_capacity(m * n);
        for bi in 0..b {
            for ci in 0..c {
                Self::decompress_channel(&zz, &mut r, &mut seq, out.channel_mut(bi, ci))?;
            }
        }
        ensure!(r.remaining() == 0, "trailing bytes in SL-FAC payload");
        Ok(out)
    }
}

/// Ablation codec: AFD split retained, but both groups get the same mid bit
/// width — isolates FQC's contribution ("SL-FAC w/o FQC", Fig. 4 row 2).
#[derive(Debug, Clone)]
pub struct AfdUniformCodec {
    inner: SlFacCodec,
}

impl AfdUniformCodec {
    /// θ for the split; `bits` for both groups.
    pub fn new(theta: f64, bits: u32) -> Self {
        AfdUniformCodec {
            inner: SlFacCodec::new(SlFacConfig {
                theta,
                alloc: AllocationConfig {
                    b_min: bits,
                    b_max: bits,
                },
            }),
        }
    }
}

impl ActivationCodec for AfdUniformCodec {
    fn name(&self) -> &'static str {
        "afd-uniform"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::AfdUniform
    }

    fn frequency_domain(&self) -> bool {
        true
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        let mut p = self.inner.compress(x)?;
        p.kind = CodecKind::AfdUniform as u8;
        Ok(p)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        self.inner.decompress(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;
    use crate::dct::Dct2d;

    fn coeffs_of(shape: &[usize], seed: u64) -> Tensor {
        Dct2d::forward_tensor(&smooth_activations(shape, seed))
    }

    #[test]
    fn roundtrip_preserves_shape_and_low_error() {
        let x = coeffs_of(&[2, 6, 14, 14], 1);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        let back = codec.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        // θ=0.9 bounds the *retained* energy: reconstruction error is at
        // most ~sqrt(1-θ) of the signal (F_h is coarsely quantized).
        let err = back.rel_l2_error(&x);
        assert!(err < (1.0f64 - 0.9).sqrt() + 0.05, "rel err {err}");
    }

    #[test]
    fn compresses_smooth_data_well() {
        let x = coeffs_of(&[4, 8, 14, 14], 2);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        assert!(
            p.compression_ratio() > 3.0,
            "ratio {}",
            p.compression_ratio()
        );
    }

    #[test]
    fn higher_theta_higher_fidelity() {
        // Fig. 3's mechanism at codec level: raising θ moves more energy
        // into the finely-quantized F_l, so fidelity at the endpoints must
        // improve markedly (local non-monotonicity between neighboring θ is
        // possible because the F_h range shifts with the split point).
        let x = coeffs_of(&[2, 4, 14, 14], 3);
        let err_at = |theta: f64| {
            let codec = SlFacCodec::new(SlFacConfig {
                theta,
                ..Default::default()
            });
            codec
                .decompress(&codec.compress(&x).unwrap())
                .unwrap()
                .rel_l2_error(&x)
        };
        let lo = err_at(0.5);
        let hi = err_at(0.99);
        assert!(hi < lo, "err(0.99)={hi} should beat err(0.5)={lo}");
        assert!(hi < 0.12, "err at theta=0.99 is {hi}");
    }

    #[test]
    fn low_group_gets_more_bits_than_high() {
        // Parse the wire body of a single-channel payload and check Eq. 7's
        // intent: the informative group is quantized more finely.
        let x = coeffs_of(&[1, 1, 14, 14], 4);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        let mut r = BodyReader::new(&p.body);
        let _k = r.u16().unwrap();
        let b_low = r.u8().unwrap();
        let b_high = r.u8().unwrap();
        assert!(b_low > b_high, "b_low={b_low} b_high={b_high}");
        assert!(b_low <= 8 && b_high >= 2);
    }

    #[test]
    fn all_low_group_when_theta_one() {
        let x = coeffs_of(&[1, 2, 8, 8], 5);
        let codec = SlFacCodec::new(SlFacConfig {
            theta: 1.0,
            ..Default::default()
        });
        let p = codec.compress(&x).unwrap();
        let back = codec.decompress(&p).unwrap();
        // With everything in F_l at b_max the reconstruction is very tight.
        assert!(back.rel_l2_error(&x) < 0.01);
    }

    #[test]
    fn zero_tensor_roundtrips() {
        let x = Tensor::zeros(&[1, 3, 7, 9]);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert_eq!(back.data(), x.data());
    }

    #[test]
    fn corrupt_payload_is_rejected_not_panicking() {
        let x = coeffs_of(&[1, 2, 6, 6], 6);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let mut p = codec.compress(&x).unwrap();
        p.body.truncate(p.body.len() / 2);
        assert!(codec.decompress(&p).is_err());
        // corrupt k*
        let mut p2 = codec.compress(&x).unwrap();
        p2.body[0] = 0xFF;
        p2.body[1] = 0xFF;
        assert!(codec.decompress(&p2).is_err());
    }

    #[test]
    fn afd_uniform_is_worse_or_equal_at_same_budget() {
        // FQC's adaptive allocation should not lose to flat allocation when
        // both use the same mean bit count on energy-skewed data.
        let x = coeffs_of(&[4, 6, 14, 14], 7);
        let slfac = SlFacCodec::new(SlFacConfig::default());
        let p_s = slfac.compress(&x).unwrap();
        let err_s = slfac.decompress(&p_s).unwrap().rel_l2_error(&x);

        // flat codec sized to at least slfac's bytes
        let mut err_flat = f64::INFINITY;
        for bits in 2..=8 {
            let flat = AfdUniformCodec::new(0.9, bits);
            let p_f = flat.compress(&x).unwrap();
            if p_f.wire_bytes() >= p_s.wire_bytes() {
                err_flat = flat.decompress(&p_f).unwrap().rel_l2_error(&x);
                break;
            }
        }
        assert!(
            err_s <= err_flat * 1.05,
            "slfac {err_s} vs flat {err_flat}"
        );
    }

    #[test]
    fn property_roundtrip_arbitrary_shapes_and_thetas() {
        crate::testing::prop("slfac roundtrip", 60, |g| {
            let shape = g.bchw_shape();
            let theta = *g.choose(&[0.5f64, 0.7, 0.8, 0.9, 0.95, 1.0]);
            let x = g.tensor(&shape, 2.0);
            let coeffs = Dct2d::forward_tensor(&x);
            let codec = SlFacCodec::new(SlFacConfig {
                theta,
                ..Default::default()
            });
            let p = codec.compress(&coeffs).unwrap();
            let back = codec.decompress(&p).unwrap();
            assert_eq!(back.shape(), coeffs.shape());
            for v in back.data() {
                assert!(v.is_finite());
            }
            // wire-format determinism
            let p2 = codec.compress(&coeffs).unwrap();
            assert_eq!(p.body, p2.body, "compression must be deterministic");
        });
    }

    #[test]
    fn metadata_overhead_is_modest() {
        let x = coeffs_of(&[1, 16, 14, 14], 8);
        let codec = SlFacCodec::new(SlFacConfig::default());
        let p = codec.compress(&x).unwrap();
        // per-channel header is 20 bytes; body must be dominated by packed bits
        let header_bytes = 16 * 20;
        assert!(
            (header_bytes as f64) < 0.3 * p.body.len() as f64,
            "headers {header_bytes} vs body {}",
            p.body.len()
        );
    }
}
