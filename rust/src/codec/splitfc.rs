//! FC-SL — the SplitFC baseline (Oh et al., IEEE TNNLS 2025 [27]).
//!
//! SplitFC compresses smashed data feature-wise: features (channels) with
//! low dispersion carry little task information and are dropped; the
//! remaining features are quantized. Our implementation per sample:
//!
//! 1. rank channels by their standard deviation;
//! 2. keep the top `keep_fraction`, drop the rest (each dropped channel is
//!    summarized by its mean — one f16 — so the server reconstructs a DC
//!    approximation rather than zeros, matching the reference's
//!    mean-preserving dropout);
//! 3. min-max linear quantization of each kept channel at `bits` with a
//!    per-channel range (SplitFC's "adaptive feature-wise quantization").

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, unpack_levels_lut, LinearQuantizer};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// FC-SL parameters.
#[derive(Debug, Clone, Copy)]
pub struct SplitFcConfig {
    /// Fraction of channels kept (by std rank).
    pub keep_fraction: f64,
    /// Bit width for kept channels.
    pub bits: u32,
}

impl Default for SplitFcConfig {
    fn default() -> Self {
        SplitFcConfig {
            keep_fraction: 0.25,
            bits: 4,
        }
    }
}

/// SplitFC codec. Spatial domain.
#[derive(Debug, Clone)]
pub struct SplitFcCodec {
    cfg: SplitFcConfig,
}

impl SplitFcCodec {
    /// Build from config.
    pub fn new(cfg: SplitFcConfig) -> Self {
        assert!(cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0);
        assert!((1..=16).contains(&cfg.bits));
        SplitFcCodec { cfg }
    }
}

impl ActivationCodec for SplitFcCodec {
    fn name(&self) -> &'static str {
        "fc-sl"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::SplitFc
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let (b, c, m, n) = x.as_bchw();
        let keep = ((c as f64 * self.cfg.keep_fraction).ceil() as usize).clamp(1, c);
        let mut w = BodyWriter::from_vec(std::mem::take(&mut out.body), 0);
        let ranks = &mut scratch.ranks;
        let kept = &mut scratch.kept;
        let bitmap = &mut scratch.bitmap;
        for bi in 0..b {
            // rank channels by std
            ranks.clear();
            ranks.extend((0..c).map(|ci| (ci, crate::tensor::std_dev(x.channel(bi, ci)))));
            ranks.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            kept.clear();
            kept.extend(ranks[..keep].iter().map(|&(i, _)| i as u32));
            kept.sort_unstable();

            // channel bitmap: 1 bit per channel
            bitmap.clear();
            bitmap.resize((c + 7) / 8, 0);
            for &ci in kept.iter() {
                bitmap[ci as usize / 8] |= 1 << (ci % 8);
            }
            w.bytes(bitmap);
            // dropped channel means (bitmap test ≡ the historical
            // `kept.contains`, same bytes)
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) == 0 {
                    let ch = x.channel(bi, ci);
                    let mean = ch.iter().sum::<f32>() / ch.len() as f32;
                    w.f16(mean);
                }
            }
            // kept channels: per-channel min/max + packed levels
            for &ci in kept.iter() {
                let ch = x.channel(bi, ci as usize);
                let q = LinearQuantizer::fit(self.cfg.bits, ch);
                w.f32(q.min);
                w.f32(q.max);
                pack_levels_into(ch, &q, &mut w);
            }
        }
        *out = Payload {
            kind: CodecKind::SplitFc as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        };
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        // dense decode: every channel is either mean-filled or unpacked
        out.reset_dense(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let bitmap = &mut scratch.bitmap;
        let kept = &mut scratch.kept;
        for bi in 0..b {
            bitmap.clear();
            bitmap.extend_from_slice(r.bytes((c + 7) / 8)?);
            kept.clear();
            kept.extend(
                (0..c as u32).filter(|&ci| {
                    bitmap[ci as usize / 8] & (1 << (ci % 8)) != 0
                }),
            );
            ensure!(!kept.is_empty(), "corrupt SplitFC bitmap: nothing kept");
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) == 0 {
                    let mean = r.f16()?;
                    out.channel_mut(bi, ci).fill(mean);
                }
            }
            for &ci in kept.iter() {
                let ci = ci as usize;
                let min = r.f32()?;
                let max = r.f32()?;
                let q = LinearQuantizer {
                    bits: self.cfg.bits,
                    min,
                    max,
                };
                unpack_levels_lut(
                    &mut r,
                    &q,
                    plane,
                    &mut scratch.lut,
                    out.channel_mut(bi, ci),
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;
    use crate::rng::Pcg32;

    #[test]
    fn high_variance_channels_survive() {
        let mut rng = Pcg32::seeded(21);
        let mut x = Tensor::zeros(&[1, 4, 6, 6]);
        // channel 2 has high variance, others near-constant
        for (i, v) in x.channel_mut(0, 2).iter_mut().enumerate() {
            *v = if i % 2 == 0 { 5.0 } else { -5.0 } + rng.normal() * 0.1;
        }
        for ci in [0usize, 1, 3] {
            x.channel_mut(0, ci).fill(1.0);
        }
        let codec = SplitFcCodec::new(SplitFcConfig {
            keep_fraction: 0.25,
            bits: 8,
        });
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        // kept channel reconstructs well
        let err2 = crate::tensor::Tensor::new(&[36], back.channel(0, 2).to_vec())
            .rel_l2_error(&Tensor::new(&[36], x.channel(0, 2).to_vec()));
        assert!(err2 < 0.05, "kept channel err {err2}");
        // dropped channels reconstruct as their mean (exactly 1.0 here)
        for ci in [0usize, 1, 3] {
            for &v in back.channel(0, ci) {
                assert!((v - 1.0).abs() < 0.01);
            }
        }
    }

    #[test]
    fn roundtrip_all_channels_kept() {
        let x = smooth_activations(&[2, 3, 8, 8], 22);
        let codec = SplitFcCodec::new(SplitFcConfig {
            keep_fraction: 1.0,
            bits: 8,
        });
        let back = codec.decompress(&codec.compress(&x).unwrap()).unwrap();
        assert!(back.rel_l2_error(&x) < 0.02);
    }

    #[test]
    fn wire_size_tracks_keep_fraction() {
        let x = smooth_activations(&[2, 8, 10, 10], 23);
        let sizes: Vec<usize> = [0.25, 0.5, 1.0]
            .iter()
            .map(|&f| {
                let c = SplitFcCodec::new(SplitFcConfig {
                    keep_fraction: f,
                    bits: 4,
                });
                c.compress(&x).unwrap().wire_bytes()
            })
            .collect();
        assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2]);
    }

    #[test]
    fn truncated_payload_rejected() {
        let x = smooth_activations(&[1, 4, 6, 6], 24);
        let codec = SplitFcCodec::new(SplitFcConfig::default());
        let mut p = codec.compress(&x).unwrap();
        p.body.truncate(p.body.len() - 3);
        assert!(codec.decompress(&p).is_err());
    }
}
