//! Per-shape codec plans and per-worker scratch arenas — the memory
//! discipline layer of the codec hot path (see ARCHITECTURE.md "Codec hot
//! path & memory discipline").
//!
//! The compress/decompress kernels run `B·C` times per device per round;
//! at fleet scale (64–256 simulated devices) anything per-call shows up.
//! Two mechanisms keep the steady state allocation- and lock-free:
//!
//! * **Plans** ([`CodecPlan`], one per `(M, N)` plane shape) bundle every
//!   immutable precomputed table a kernel needs — the zig-zag scan and the
//!   DCT plan (basis matrices, transposes, fast power-of-two twiddles).
//!   Plans resolve through a [`SnapshotCache`]: readers do one atomic load
//!   and a `HashMap` lookup — **no lock** — instead of the historical
//!   `Mutex<HashMap>` acquired on every call.
//! * **Scratch** ([`CodecScratch`]) owns every mutable work buffer a kernel
//!   needs (zig-zag sequence, level tables, index/bitmap work, recycled
//!   payload bodies). One arena lives per device context; the round
//!   engine's shard ownership (one worker owns a device per phase —
//!   [`crate::coordinator::engine`]) makes it data-race free without any
//!   synchronization, and scratch contents never influence results (every
//!   buffer is fully overwritten before use), so bit-transparency across
//!   worker counts is preserved.

use crate::freq::ZigZag;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

/// A lock-free read-mostly cache: readers load an immutable snapshot map
/// with one `Acquire` atomic load; writers (cache misses only) serialize on
/// a build mutex, clone the map, insert, and publish the new snapshot.
///
/// Superseded snapshots are intentionally **leaked**: a reader may still
/// hold a reference to the old map, and the key universe (distinct tensor
/// shapes / transform sizes seen by a process) is tiny and bounded, so the
/// leak is a few hundred bytes per distinct key ever inserted — the price
/// of a zero-synchronization steady-state read path without an `ArcSwap`
/// dependency.
pub struct SnapshotCache<K, V> {
    map: AtomicPtr<HashMap<K, Arc<V>>>,
    build: Mutex<()>,
}

impl<K: Eq + Hash + Clone, V> SnapshotCache<K, V> {
    /// Empty cache.
    pub fn new() -> Self {
        SnapshotCache {
            map: AtomicPtr::new(Box::into_raw(Box::new(HashMap::new()))),
            build: Mutex::new(()),
        }
    }

    /// Current published snapshot. Safe because snapshots are never freed.
    fn snapshot(&self) -> &HashMap<K, Arc<V>> {
        // SAFETY: the pointer always comes from Box::into_raw of a live
        // map, and superseded maps are leaked (never dropped), so the
        // reference cannot dangle.
        unsafe { &*self.map.load(Ordering::Acquire) }
    }

    /// Fetch the value for `key`, building (and publishing) it on first use.
    /// The hot path — key present — is a single atomic load plus a map
    /// lookup and an `Arc` clone.
    pub fn get_or_build(&self, key: K, build: impl FnOnce() -> V) -> Arc<V> {
        if let Some(v) = self.snapshot().get(&key) {
            return v.clone();
        }
        let _guard = self.build.lock().unwrap();
        // another thread may have built it while we waited
        if let Some(v) = self.snapshot().get(&key) {
            return v.clone();
        }
        let v = Arc::new(build());
        let mut next = self.snapshot().clone();
        next.insert(key, v.clone());
        // publish; the previous snapshot leaks by design (see type docs)
        self.map.store(Box::into_raw(Box::new(next)), Ordering::Release);
        v
    }

    /// Number of cached entries (diagnostics).
    pub fn len(&self) -> usize {
        self.snapshot().len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.snapshot().is_empty()
    }
}

impl<K: Eq + Hash + Clone, V> Default for SnapshotCache<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything immutable a codec kernel needs for one `(M, N)` plane shape.
#[derive(Debug)]
pub struct CodecPlan {
    /// Plane height.
    pub m: usize,
    /// Plane width.
    pub n: usize,
    /// Zig-zag scan tables (shared with [`crate::freq::zigzag`]).
    pub zz: Arc<ZigZag>,
}

fn plan_cache() -> &'static SnapshotCache<(usize, usize), CodecPlan> {
    static CACHE: std::sync::OnceLock<SnapshotCache<(usize, usize), CodecPlan>> =
        std::sync::OnceLock::new();
    CACHE.get_or_init(SnapshotCache::new)
}

impl CodecPlan {
    /// Resolve (building on first use) the plan for an `M×N` plane.
    pub fn for_shape(m: usize, n: usize) -> Arc<CodecPlan> {
        plan_cache().get_or_build((m, n), || CodecPlan {
            m,
            n,
            zz: crate::freq::zigzag(m, n),
        })
    }

    /// The matching DCT plan (basis matrices, pre-transposed variants,
    /// fast power-of-two twiddles), fetched **lazily** from the shared
    /// [`crate::dct::plan`] cache. Lazy because codec kernels themselves
    /// never transform: on the real wire path the DCT runs inside the HLO
    /// graph, so building basis tables per codec shape would be pure
    /// waste. Standalone-mode consumers ([`crate::dct::Dct2d`]) hit the
    /// same cache, so there is never a duplicate build.
    pub fn dct(&self) -> Arc<crate::dct::DctPlan> {
        crate::dct::plan(self.m, self.n)
    }
}

/// Reusable mutable work buffers for the codec kernels — one arena per
/// device context (per worker), threaded through
/// [`crate::codec::ActivationCodec::compress_into`] /
/// [`crate::codec::ActivationCodec::decompress_into`].
///
/// Every buffer is fully overwritten by its user before being read, so
/// carrying an arena across calls/rounds can never change results — only
/// allocation counts. After one warm-up call per shape, the steady state
/// performs zero heap allocations (pinned by `tests/codec_zero_alloc.rs`).
#[derive(Debug, Default)]
pub struct CodecScratch {
    /// Per-channel f32 sequence work (zig-zag scan order, channel values).
    pub seq: Vec<f32>,
    /// Secondary f32 work (kept values, dequantized channel staging).
    pub vals: Vec<f32>,
    /// Index work (top-k partial sort, kept-position lists).
    pub idx: Vec<u32>,
    /// Kept-index list (sorted subsets).
    pub kept: Vec<u32>,
    /// Channel-ranking work `(index, score)` (FC-SL std ranking).
    pub ranks: Vec<(usize, f32)>,
    /// Bitmap work (kept-position bitmaps).
    pub bitmap: Vec<u8>,
    /// Dequantization lookup table (≤ 2^bits entries, bits ≤ 8 paths).
    pub lut: Vec<f32>,
    /// EasyQuant sparse outlier work `(flat index, value)` — recycled
    /// through `EasyQuant::fit_with` so the fit stops allocating on the
    /// hot path.
    pub outliers: Vec<(u32, f32)>,
    /// Per-channel f64 accumulators (SL-ACC mean spectral energies).
    pub energies: Vec<f64>,
    /// Recycled payload bodies: `take_body` pops one (retaining its
    /// capacity), `recycle_body` returns one after its payload is decoded.
    pool: Vec<Vec<u8>>,
}

impl CodecScratch {
    /// Fresh, empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// A body buffer for a new payload: recycled (capacity retained,
    /// cleared) when available, freshly empty otherwise.
    pub fn take_body(&mut self) -> Vec<u8> {
        let mut b = self.pool.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a spent payload body to the pool for reuse.
    pub fn recycle_body(&mut self, body: Vec<u8>) {
        // bound the pool: the trainer keeps at most two payloads in
        // flight per device (uplink + gradient)
        if self.pool.len() < 4 {
            self.pool.push(body);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_cache_builds_once_and_shares() {
        use std::sync::atomic::AtomicUsize;
        let cache: SnapshotCache<usize, u64> = SnapshotCache::new();
        let built = AtomicUsize::new(0);
        let a = cache.get_or_build(7, || {
            built.fetch_add(1, Ordering::Relaxed);
            42
        });
        let b = cache.get_or_build(7, || {
            built.fetch_add(1, Ordering::Relaxed);
            99
        });
        assert_eq!(*a, 42);
        assert!(Arc::ptr_eq(&a, &b), "second get must hit the snapshot");
        assert_eq!(built.load(Ordering::Relaxed), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn snapshot_cache_is_threadsafe() {
        let cache: Arc<SnapshotCache<usize, usize>> = Arc::new(SnapshotCache::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    for i in 0..64 {
                        let key = (t + i) % 16;
                        let v = cache.get_or_build(key, || key * 10);
                        assert_eq!(*v, key * 10);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 16);
    }

    #[test]
    fn codec_plan_resolves_and_dedups() {
        let p1 = CodecPlan::for_shape(14, 14);
        let p2 = CodecPlan::for_shape(14, 14);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(p1.zz.scan.len(), 196);
        assert_eq!((p1.m, p1.n), (14, 14));
        // plan tables agree with the module-level caches
        assert!(Arc::ptr_eq(&p1.zz, &crate::freq::zigzag(14, 14)));
        assert!(Arc::ptr_eq(&p1.dct(), &crate::dct::plan(14, 14)));
    }

    #[test]
    fn scratch_body_pool_recycles_capacity() {
        let mut s = CodecScratch::new();
        let mut b = s.take_body();
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        s.recycle_body(b);
        let b2 = s.take_body();
        assert!(b2.is_empty());
        assert!(b2.capacity() >= cap, "recycled body must keep its capacity");
    }
}
