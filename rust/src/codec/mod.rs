//! Smashed-data codecs: SL-FAC (AFD + FQC) and every baseline/ablation the
//! paper evaluates against.
//!
//! A codec turns a cut-layer tensor into a [`Payload`] and back. Codecs
//! declare their working domain:
//!
//! * **frequency-domain** codecs ([`SlFacCodec`], the AFD ablations) consume
//!   per-channel DCT coefficient planes. On the real wire path those planes
//!   come out of the HLO graph (the L1 Pallas kernel inside `client_fwd` /
//!   `server_step`), and the decompressed planes go back through the `idct`
//!   artifact — Rust never recomputes the transform there.
//! * **spatial-domain** codecs (TK-SL, FC-SL, PQ-SL, EasyQuant, identity,
//!   and the literature-cluster family SL-ACC / feature-wise / mask-topk /
//!   NSC-SL) consume the activations directly.
//!
//! [`roundtrip_spatial`] wraps either kind into a spatial-in/spatial-out
//! round trip (using the Rust DCT for frequency codecs) so fidelity and
//! ratio comparisons are apples-to-apples; the DCT being orthonormal means
//! coefficient-domain L2 error equals spatial L2 error.
//!
//! The hot path is planned and allocation-free: per-shape immutable tables
//! resolve through the lock-free caches in [`plan`], and the coordinator
//! threads a per-device [`CodecScratch`] arena through
//! [`ActivationCodec::compress_into`] /
//! [`ActivationCodec::decompress_into`]. Both are contractually
//! **bit-transparent** — identical wire bytes and decoded tensors vs the
//! allocating reference paths (see ARCHITECTURE.md "Codec hot path &
//! memory discipline" and `tests/codec_differential.rs`).

pub mod featurewise;
pub mod maskenc;
pub mod nscsl;
pub mod plan;
pub mod select;
pub mod slacc;
pub mod slfac;
pub mod splitfc;
pub mod topk;
pub mod uniform;
pub mod wire;

pub use featurewise::{FeatureWiseCodec, FeatureWiseConfig};
pub use maskenc::{MaskTopKCodec, MaskTopKConfig};
pub use nscsl::{NscSlCodec, NscSlConfig};
pub use plan::{CodecPlan, CodecScratch};
pub use select::{MagnitudeSelectCodec, SelectConfig, StdSelectCodec};
pub use slacc::{SlAccCodec, SlAccConfig};
pub use slfac::{AfdUniformCodec, SlFacCodec, SlFacConfig};
pub use splitfc::{SplitFcCodec, SplitFcConfig};
pub use topk::{TopKCodec, TopKConfig};
pub use uniform::{EasyQuantCodec, IdentityCodec, PowerQuantCodec, UniformLinearCodec};
pub use wire::Payload;

use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::Result;

/// Numeric tags used in payload headers (stable wire identifiers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CodecKind {
    /// FP32 passthrough (no compression).
    Identity = 0,
    /// SL-FAC: AFD + FQC (the paper's method).
    SlFac = 1,
    /// TK-SL: randomized top-k sparsification [25].
    TopK = 2,
    /// FC-SL: SplitFC std-based feature dropout + quantization [27].
    SplitFc = 3,
    /// PQ-SL: PowerQuant uniform-bit non-uniform quantization [39].
    PowerQuant = 4,
    /// EasyQuant outlier-isolating quantization [40] (Fig. 4 ablation).
    EasyQuant = 5,
    /// Magnitude-based spatial selection (Fig. 4 ablation).
    MagnitudeSelect = 6,
    /// STD-based spatial selection (Fig. 4 ablation).
    StdSelect = 7,
    /// AFD split + uniform mid bit width ("SL-FAC w/o FQC" ablation).
    AfdUniform = 8,
    /// Plain per-tensor min-max linear quantization.
    UniformLinear = 9,
    /// SL-ACC: channel-wise energy-adaptive bit allocation (arXiv:2508.12984).
    SlAcc = 10,
    /// Adaptive feature-wise drop + quantize (Oh et al., arXiv:2307.10805).
    FeatureWise = 11,
    /// Mask-encoded top-k sparsification (arXiv:2408.13787).
    MaskTopK = 12,
    /// NSC-SL: seeded-subspace projection compression (arXiv:2602.02696).
    NscSl = 13,
}

/// The codec interface used by the coordinator and benches.
pub trait ActivationCodec: Send + Sync {
    /// Stable display name (used in configs, CSV column headers).
    fn name(&self) -> &'static str;

    /// Wire tag.
    fn kind(&self) -> CodecKind;

    /// Whether `compress` expects per-channel DCT coefficient planes
    /// (true for AFD-family codecs) rather than spatial activations.
    fn frequency_domain(&self) -> bool {
        false
    }

    /// Compress a (B,C,M,N) tensor into a payload.
    fn compress(&self, x: &Tensor) -> Result<Payload>;

    /// Compress drawing any randomized decisions from the **caller's** RNG
    /// stream instead of codec-internal state.
    ///
    /// The parallel round engine calls this with a per-device stream
    /// derived from the root seed ([`crate::rng::derive_seed`]), so
    /// compression results are a function of `(seed, device, call index)`
    /// alone — never of thread scheduling across devices. Deterministic
    /// codecs ignore the stream (this default just forwards to
    /// [`Self::compress`]); randomized codecs (TK-SL) must override it.
    fn compress_with_rng(&self, x: &Tensor, _rng: &mut Pcg32) -> Result<Payload> {
        self.compress(x)
    }

    /// Reconstruct the tensor (same domain as `compress` input).
    fn decompress(&self, p: &Payload) -> Result<Tensor>;

    /// Buffer-reusing compression: write the payload into `out` (its body
    /// capacity is recycled) drawing work buffers from `scratch`. The
    /// coordinator threads one [`CodecScratch`] per device context through
    /// this, so the steady-state hot path allocates nothing (see
    /// ARCHITECTURE.md "Codec hot path & memory discipline").
    ///
    /// **Contract:** the produced payload is byte-identical to
    /// [`Self::compress_with_rng`] on the same inputs — scratch reuse is a
    /// memory optimization, never a semantic one (pinned by
    /// `tests/codec_differential.rs`). The default forwards to the
    /// allocating path; hot codecs override it.
    fn compress_into(
        &self,
        x: &Tensor,
        rng: &mut Pcg32,
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        *out = self.compress_with_rng(x, rng)?;
        Ok(())
    }

    /// Buffer-reusing decompression into `out` (reset in place, allocation
    /// reused) with work buffers from `scratch`. Same bit-identity contract
    /// as [`Self::compress_into`]; the default forwards to the allocating
    /// path.
    fn decompress_into(
        &self,
        p: &Payload,
        _scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        *out = self.decompress(p)?;
        Ok(())
    }
}

/// Allocating `compress` expressed through the scratch API with fresh
/// temporaries. **Only** for codecs that override
/// [`ActivationCodec::compress_into`] (the default `compress_into` calls
/// back into `compress`, which would recurse); the RNG argument is a dummy,
/// so randomized codecs must not route their draws through this.
pub(crate) fn compress_fresh<C: ActivationCodec + ?Sized>(c: &C, x: &Tensor) -> Result<Payload> {
    let mut out = Payload::empty();
    c.compress_into(x, &mut Pcg32::seeded(0), &mut CodecScratch::new(), &mut out)?;
    Ok(out)
}

/// Allocating `decompress` expressed through the scratch API with fresh
/// temporaries. **Only** for codecs that override
/// [`ActivationCodec::decompress_into`] (same recursion caveat as
/// [`compress_fresh`]).
pub(crate) fn decompress_fresh<C: ActivationCodec + ?Sized>(c: &C, p: &Payload) -> Result<Tensor> {
    let mut out = Tensor::zeros(&[1]);
    c.decompress_into(p, &mut CodecScratch::new(), &mut out)?;
    Ok(out)
}

/// Construct a codec by config name. Accepted names (paper labels):
/// `slfac`, `pq-sl`/`powerquant`, `tk-sl`/`topk`, `fc-sl`/`splitfc`,
/// `easyquant`, `magnitude`, `std`, `afd-uniform`, `uniform`,
/// `identity`/`fp32`, and the literature-cluster family
/// `sl-acc`/`slacc`, `featurewise`/`feature-wise`,
/// `mask-topk`/`maskenc`/`mask-encoded`, `nsc-sl`/`nscsl`.
pub fn by_name(name: &str, params: &CodecParams) -> Result<Box<dyn ActivationCodec>> {
    let c: Box<dyn ActivationCodec> = match name.to_ascii_lowercase().as_str() {
        "slfac" | "sl-fac" => Box::new(SlFacCodec::new(SlFacConfig {
            theta: params.theta,
            alloc: crate::quant::AllocationConfig {
                b_min: params.b_min,
                b_max: params.b_max,
            },
            fast_path: params.fast_path,
        })),
        "pq-sl" | "powerquant" => Box::new(PowerQuantCodec::new(params.uniform_bits)),
        "tk-sl" | "topk" => Box::new(TopKCodec::new(TopKConfig {
            keep_fraction: params.keep_fraction,
            random_fraction: params.random_fraction,
            seed: params.seed,
        })),
        "fc-sl" | "splitfc" => Box::new(SplitFcCodec::new(SplitFcConfig {
            keep_fraction: params.keep_fraction,
            bits: params.uniform_bits,
        })),
        "easyquant" => Box::new(EasyQuantCodec::new(params.uniform_bits)),
        "magnitude" => Box::new(MagnitudeSelectCodec::new(SelectConfig {
            keep_fraction: params.keep_fraction,
            bits: params.uniform_bits,
        })),
        "std" => Box::new(StdSelectCodec::new(SelectConfig {
            keep_fraction: params.keep_fraction,
            bits: params.uniform_bits,
        })),
        "afd-uniform" => Box::new(AfdUniformCodec::with_fast_path(
            params.theta,
            (params.b_min + params.b_max) / 2,
            params.fast_path,
        )),
        "uniform" => Box::new(UniformLinearCodec::new(params.uniform_bits)),
        "sl-acc" | "slacc" => Box::new(SlAccCodec::new(SlAccConfig {
            alloc: crate::quant::AllocationConfig {
                b_min: params.b_min,
                b_max: params.b_max,
            },
            fast_path: params.fast_path,
        })),
        "featurewise" | "feature-wise" => Box::new(FeatureWiseCodec::new(FeatureWiseConfig {
            drop_threshold: params.drop_threshold,
            alloc: crate::quant::AllocationConfig {
                b_min: params.b_min,
                b_max: params.b_max,
            },
        })),
        "mask-topk" | "maskenc" | "mask-encoded" => Box::new(MaskTopKCodec::new(MaskTopKConfig {
            keep_fraction: params.keep_fraction,
            bits: params.uniform_bits,
        })),
        "nsc-sl" | "nscsl" => Box::new(NscSlCodec::new(NscSlConfig {
            subspace_fraction: params.subspace_fraction,
            bits: params.uniform_bits,
            seed: params.seed,
        })),
        "identity" | "fp32" | "none" => Box::new(IdentityCodec),
        other => anyhow::bail!("unknown codec '{other}'"),
    };
    Ok(c)
}

/// Codec hyper-parameters shared by the factory (config-file friendly).
#[derive(Debug, Clone)]
pub struct CodecParams {
    /// AFD energy threshold θ (paper: 0.9).
    pub theta: f64,
    /// FQC minimum bit width (paper: 2).
    pub b_min: u32,
    /// FQC maximum bit width (paper: 8).
    pub b_max: u32,
    /// Bit width for uniform-bit baselines (PQ-SL, EasyQuant, FC-SL…).
    pub uniform_bits: u32,
    /// Keep fraction for selection baselines (TK-SL top-k, FC-SL, ablations).
    pub keep_fraction: f64,
    /// Extra random-keep fraction for randomized top-k (TK-SL).
    pub random_fraction: f64,
    /// Seed for randomized codecs.
    pub seed: u64,
    /// Relative dispersion threshold for the feature-wise codec: a channel
    /// is dropped when `std_c < drop_threshold · std_max`.
    pub drop_threshold: f64,
    /// Subspace rank fraction for NSC-SL: `r = ⌈f · M·N⌉` coefficients
    /// travel per channel.
    pub subspace_fraction: f64,
    /// Use the fused single-pass kernels (default). `false` routes the
    /// AFD-family codecs through the multi-pass reference kernels — wire
    /// bytes are bit-identical either way (enforced by
    /// `tests/codec_differential.rs`); the toggle exists so the reference
    /// stays reachable for debugging (`codec_fast_path` config key).
    pub fast_path: bool,
}

impl Default for CodecParams {
    fn default() -> Self {
        CodecParams {
            theta: 0.9,
            b_min: 2,
            b_max: 8,
            uniform_bits: 4,
            keep_fraction: 0.25,
            random_fraction: 0.05,
            seed: 7,
            drop_threshold: 0.2,
            subspace_fraction: 0.5,
            fast_path: true,
        }
    }
}

/// All codec names the experiment drivers iterate over.
pub const ALL_CODECS: &[&str] = &[
    "slfac",
    "pq-sl",
    "tk-sl",
    "fc-sl",
    "easyquant",
    "magnitude",
    "std",
    "afd-uniform",
    "uniform",
    "identity",
    "sl-acc",
    "featurewise",
    "mask-topk",
    "nsc-sl",
];

/// Spatial-domain round trip through any codec: frequency-domain codecs get
/// a Rust DCT in front and IDCT behind; spatial codecs pass straight through.
/// Returns (reconstructed tensor, payload).
pub fn roundtrip_spatial(
    codec: &dyn ActivationCodec,
    x: &Tensor,
) -> Result<(Tensor, Payload)> {
    if codec.frequency_domain() {
        let coeffs = crate::dct::Dct2d::forward_tensor(x);
        let payload = codec.compress(&coeffs)?;
        let coeffs_back = codec.decompress(&payload)?;
        Ok((crate::dct::Dct2d::inverse_tensor(&coeffs_back), payload))
    } else {
        let payload = codec.compress(x)?;
        let back = codec.decompress(&payload)?;
        Ok((back, payload))
    }
}

/// Generate activation-like tensors (shared by tests and benches): sums of
/// low-frequency sinusoids + mild noise, with per-channel amplitudes drawn
/// log-uniform over ~1.5 decades. Post-conv feature maps look like this —
/// spatially smooth with widely varying channel scales — which is exactly
/// the "feature-space entanglement" structure the paper argues uniform
/// strategies handle poorly and AFD exploits.
pub fn smooth_activations(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = crate::rng::Pcg32::seeded(seed);
    let (b, c, m, n) = Tensor::zeros(shape).as_bchw();
    let mut t = Tensor::zeros(shape);
    for bi in 0..b {
        for ci in 0..c {
            let fx = 1.0 + rng.uniform() * 2.0;
            let fy = 1.0 + rng.uniform() * 2.0;
            let phase = rng.uniform() * 6.28;
            // log-uniform channel scale in [e^-2, e^1.2] ≈ [0.14, 3.3]
            let amp = rng.uniform_in(-2.0, 1.2).exp();
            let ch = t.channel_mut(bi, ci);
            for r in 0..m {
                for cc in 0..n {
                    let v = amp
                        * ((fx * r as f32 / m as f32 * 6.28 + phase).sin()
                            + (fy * cc as f32 / n as f32 * 6.28).cos()
                            + 0.02 * rng.normal());
                    ch[r * n + cc] = v;
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_codec() {
        let params = CodecParams::default();
        for name in ALL_CODECS {
            let c = by_name(name, &params).unwrap();
            assert!(!c.name().is_empty());
        }
        assert!(by_name("bogus", &params).is_err());
    }

    #[test]
    fn every_codec_roundtrips_shape_and_bounded_error() {
        let params = CodecParams::default();
        let x = smooth_activations(&[2, 4, 14, 14], 77);
        for name in ALL_CODECS {
            let c = by_name(name, &params).unwrap();
            let (back, payload) = roundtrip_spatial(c.as_ref(), &x).unwrap();
            assert_eq!(back.shape(), x.shape(), "{name}");
            let err = back.rel_l2_error(&x);
            // identity must be (near-)exact; everything else bounded
            let cap = if *name == "identity" { 1e-5 } else { 0.9 };
            assert!(err < cap, "{name}: rel err {err}");
            assert!(payload.wire_bytes() > 0);
        }
    }

    #[test]
    fn compressing_codecs_beat_fp32_on_the_wire() {
        let params = CodecParams::default();
        let x = smooth_activations(&[2, 8, 14, 14], 78);
        for name in &["slfac", "pq-sl", "tk-sl", "fc-sl", "uniform"] {
            let c = by_name(name, &params).unwrap();
            let (_, payload) = roundtrip_spatial(c.as_ref(), &x).unwrap();
            assert!(
                payload.compression_ratio() > 2.0,
                "{name}: ratio {}",
                payload.compression_ratio()
            );
        }
    }

    #[test]
    fn slfac_beats_uniform_at_similar_rate() {
        // The paper's core claim, in miniature: at comparable wire size,
        // frequency-aware bit allocation yields lower reconstruction error
        // than uniform quantization on smooth feature maps.
        let x = smooth_activations(&[4, 8, 14, 14], 79);
        let params = CodecParams::default();
        let slfac = by_name("slfac", &params).unwrap();
        let (back_s, pay_s) = roundtrip_spatial(slfac.as_ref(), &x).unwrap();

        // pick uniform bits to be at least as generous (≥ bytes) as slfac
        let mut uni_err = f64::INFINITY;
        for bits in 2..=8u32 {
            let uni = UniformLinearCodec::new(bits);
            let (back_u, pay_u) = roundtrip_spatial(&uni, &x).unwrap();
            if pay_u.wire_bytes() >= pay_s.wire_bytes() {
                uni_err = back_u.rel_l2_error(&x);
                break;
            }
        }
        let s_err = back_s.rel_l2_error(&x);
        assert!(
            s_err < uni_err,
            "slfac err {s_err} should beat uniform err {uni_err} \
             (slfac bytes {})",
            pay_s.wire_bytes()
        );
    }

    #[test]
    fn property_all_codecs_roundtrip_random_shapes() {
        crate::testing::prop("codec roundtrip any shape", 40, |g| {
            let shape = g.bchw_shape();
            let x = g.tensor(&shape, 1.0);
            let params = CodecParams::default();
            let name = *g.choose(ALL_CODECS);
            let c = by_name(name, &params).unwrap();
            let (back, _) = roundtrip_spatial(c.as_ref(), &x).unwrap();
            assert_eq!(back.shape(), x.shape());
            for v in back.data() {
                assert!(v.is_finite(), "{name} produced non-finite output");
            }
        });
    }

    #[test]
    fn payload_bytes_roundtrip_through_wire_serialization() {
        let params = CodecParams::default();
        let x = smooth_activations(&[1, 4, 8, 8], 80);
        for name in ALL_CODECS {
            let c = by_name(name, &params).unwrap();
            let input = if c.frequency_domain() {
                crate::dct::Dct2d::forward_tensor(&x)
            } else {
                x.clone()
            };
            let p = c.compress(&input).unwrap();
            let bytes = p.to_bytes();
            let p2 = Payload::from_bytes(&bytes).unwrap();
            let a = c.decompress(&p).unwrap();
            let b = c.decompress(&p2).unwrap();
            assert!(a.max_abs_diff(&b) == 0.0, "{name}");
        }
    }
}
