//! NSC-SL — neural subspace compression for split learning
//! (arXiv:2602.02696).
//!
//! Projects each channel's `P = M·N` plane onto an `r`-dimensional
//! subspace (`r = ⌈subspace_fraction · P⌉`), transmits the `r` projection
//! coefficients quantized at `bits`, and reconstructs by the transposed
//! projection. Where the reference learns its subspace end-to-end, this
//! implementation uses a **seeded random orthonormal basis** — Gaussian
//! rows orthonormalized by modified Gram-Schmidt — which makes the scheme
//! bandwidth-parameterized, training-free, and exactly reproducible: the
//! basis is a pure function of `(seed, P, r)`, so client and server derive
//! identical matrices from configuration alone and the wire never carries
//! the basis. Orthonormality makes decode an orthogonal projection
//! (`B^T B`), so reconstruction error is exactly the energy outside the
//! subspace plus quantization noise — no amplification.
//!
//! Bases are derived from the dedicated [`crate::rng::stream::BASIS`]
//! stream (geometry-indexed, device-independent) and cached in a
//! process-wide [`SnapshotCache`] — built once per distinct `(P, r, seed)`,
//! then a lock-free lookup on the hot path.
//!
//! Wire layout (body, after the standard payload header), frozen by the
//! golden vectors in `tests/golden/codec_wire.json`:
//!
//! ```text
//! u16  r                        subspace rank (payload self-describing)
//! per sample, per channel (both ascending):
//!   f32  min                    coefficient range minimum
//!   f32  max                    coefficient range maximum
//!   ⌈r·bits/8⌉ bytes            packed coefficient levels, MSB-first
//! ```

use super::plan::{CodecScratch, SnapshotCache};
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, unpack_levels_lut, LinearQuantizer};
use crate::rng::{stream, Pcg32};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// NSC-SL parameters.
#[derive(Debug, Clone, Copy)]
pub struct NscSlConfig {
    /// Subspace rank as a fraction of the plane size: `r = ⌈f · M·N⌉`,
    /// clamped to `[1, M·N]`. Directly parameterizes the bandwidth.
    pub subspace_fraction: f64,
    /// Bit width of the coefficient quantizer.
    pub bits: u32,
    /// Basis seed — must agree between client and server (it is part of
    /// the run config, so the config fingerprint pins it).
    pub seed: u64,
}

impl Default for NscSlConfig {
    fn default() -> Self {
        NscSlConfig {
            subspace_fraction: 0.5,
            bits: 4,
            seed: 7,
        }
    }
}

fn basis_cache() -> &'static SnapshotCache<(usize, usize, u64), Vec<f32>> {
    static CACHE: std::sync::OnceLock<SnapshotCache<(usize, usize, u64), Vec<f32>>> =
        std::sync::OnceLock::new();
    CACHE.get_or_init(SnapshotCache::new)
}

/// The `r × p` row-major orthonormal basis for `(p, r, seed)` — built once,
/// then shared process-wide.
fn basis(p: usize, r: usize, seed: u64) -> Arc<Vec<f32>> {
    basis_cache().get_or_build((p, r, seed), || build_basis(p, r, seed))
}

/// Gaussian rows + modified Gram-Schmidt. Deterministic: the draw order and
/// the (f64) orthogonalization arithmetic are fixed, so every process
/// derives bit-identical bases.
fn build_basis(p: usize, r: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::derived(seed, stream::BASIS, ((p as u64) << 24) ^ r as u64);
    let mut b = vec![0.0f32; r * p];
    for i in 0..r {
        let (done, rest) = b.split_at_mut(i * p);
        let row = &mut rest[..p];
        // a fresh Gaussian row is dependent on the span of `done` with
        // probability zero; the redraw loop is a numerical safety net, and
        // the unit-vector fallback keeps the basis well-defined even then
        let mut ok = false;
        for _attempt in 0..8 {
            for v in row.iter_mut() {
                *v = rng.normal();
            }
            orthogonalize(row, done, p, i);
            if normalize(row) {
                ok = true;
                break;
            }
        }
        if !ok {
            row.fill(0.0);
            row[i % p] = 1.0;
            orthogonalize(row, done, p, i);
            if !normalize(row) {
                row.fill(0.0);
                row[i % p] = 1.0;
            }
        }
    }
    b
}

/// Subtract `row`'s components along each of the `k` earlier rows (modified
/// Gram-Schmidt step, f64 accumulators).
fn orthogonalize(row: &mut [f32], done: &[f32], p: usize, k: usize) {
    for e in 0..k {
        let earlier = &done[e * p..(e + 1) * p];
        let dot: f64 = row
            .iter()
            .zip(earlier)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        for (v, &w) in row.iter_mut().zip(earlier) {
            *v -= (dot * w as f64) as f32;
        }
    }
}

/// Scale `row` to unit norm; false when the row is numerically degenerate.
fn normalize(row: &mut [f32]) -> bool {
    let norm = row
        .iter()
        .map(|&v| v as f64 * v as f64)
        .sum::<f64>()
        .sqrt();
    if norm <= 1e-6 {
        return false;
    }
    let inv = (1.0 / norm) as f32;
    for v in row.iter_mut() {
        *v *= inv;
    }
    true
}

/// NSC-SL codec. Spatial domain, deterministic, fixed-rate.
#[derive(Debug, Clone)]
pub struct NscSlCodec {
    cfg: NscSlConfig,
}

impl NscSlCodec {
    /// Build from config.
    pub fn new(cfg: NscSlConfig) -> Self {
        assert!(
            cfg.subspace_fraction > 0.0 && cfg.subspace_fraction <= 1.0,
            "subspace_fraction out of range"
        );
        assert!((1..=16).contains(&cfg.bits));
        NscSlCodec { cfg }
    }

    fn rank(&self, p: usize) -> usize {
        ((p as f64 * self.cfg.subspace_fraction).ceil() as usize).clamp(1, p)
    }

    fn compress_impl(
        &self,
        x: &Tensor,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let p = m * n;
        let r_dim = self.rank(p);
        ensure!(
            r_dim <= u16::MAX as usize,
            "NSC-SL rank {r_dim} exceeds the u16 wire field"
        );
        let bmat = basis(p, r_dim, self.cfg.seed);
        let packed = (r_dim * self.cfg.bits as usize + 7) / 8;
        let mut w = BodyWriter::from_vec(body, 2 + b * c * (8 + packed));
        w.u16(r_dim as u16);
        let coeffs = &mut scratch.vals;
        for bi in 0..b {
            for ci in 0..c {
                let ch = x.channel(bi, ci);
                coeffs.clear();
                for i in 0..r_dim {
                    let row = &bmat[i * p..(i + 1) * p];
                    let mut y = 0.0f32;
                    for (&w_ij, &v) in row.iter().zip(ch) {
                        y += w_ij * v;
                    }
                    coeffs.push(y);
                }
                let q = LinearQuantizer::fit(self.cfg.bits, coeffs);
                w.f32(q.min);
                w.f32(q.max);
                pack_levels_into(coeffs, &q, &mut w);
            }
        }
        Ok(Payload {
            kind: CodecKind::NscSl as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }
}

impl ActivationCodec for NscSlCodec {
    fn name(&self) -> &'static str {
        "nsc-sl"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::NscSl
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, scratch, body)?;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        out.reset_dense(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let r_dim = r.u16()? as usize;
        ensure!(
            r_dim >= 1 && r_dim <= plane,
            "corrupt NSC-SL rank {r_dim} for plane {plane}"
        );
        // the payload self-describes its rank: decode works even when the
        // local subspace_fraction differs from the encoder's
        let bmat = basis(plane, r_dim, self.cfg.seed);
        let coeffs = &mut scratch.vals;
        let lut = &mut scratch.lut;
        for bi in 0..b {
            for ci in 0..c {
                let min = r.f32()?;
                let max = r.f32()?;
                let q = LinearQuantizer {
                    bits: self.cfg.bits,
                    min,
                    max,
                };
                coeffs.clear();
                coeffs.resize(r_dim, 0.0);
                unpack_levels_lut(&mut r, &q, r_dim, lut, coeffs)?;
                let ch = out.channel_mut(bi, ci);
                ch.fill(0.0);
                for i in 0..r_dim {
                    let row = &bmat[i * plane..(i + 1) * plane];
                    let y = coeffs[i];
                    for (d, &w_ij) in ch.iter_mut().zip(row) {
                        *d += y * w_ij;
                    }
                }
            }
        }
        ensure!(
            r.remaining() == 0,
            "trailing bytes in NSC-SL payload: {}",
            r.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;

    fn mk(frac: f64, bits: u32) -> NscSlCodec {
        NscSlCodec::new(NscSlConfig {
            subspace_fraction: frac,
            bits,
            seed: 7,
        })
    }

    #[test]
    fn basis_is_orthonormal() {
        let (p, r) = (16usize, 8usize);
        let b = basis(p, r, 7);
        for i in 0..r {
            for j in 0..r {
                let dot: f64 = (0..p)
                    .map(|t| b[i * p + t] as f64 * b[j * p + t] as f64)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-4,
                    "⟨b{i}, b{j}⟩ = {dot}, want {want}"
                );
            }
        }
    }

    #[test]
    fn basis_is_cached_and_deterministic() {
        let a = basis(25, 5, 7);
        let b = basis(25, 5, 7);
        assert!(Arc::ptr_eq(&a, &b), "second fetch must hit the cache");
        assert_ne!(*basis(25, 5, 8), *a, "different seed, different basis");
        assert_eq!(build_basis(25, 5, 7), *a, "rebuild is bit-identical");
    }

    #[test]
    fn full_rank_roundtrips_near_exact() {
        let x = smooth_activations(&[1, 2, 4, 4], 71);
        let c = mk(1.0, 16);
        let back = c.decompress(&c.compress(&x).unwrap()).unwrap();
        // r = P with an orthonormal basis ⇒ B^T B = I up to fp noise, and
        // 16-bit coefficients add almost nothing
        assert!(back.rel_l2_error(&x) < 0.02);
    }

    #[test]
    fn error_decreases_with_rank() {
        let x = smooth_activations(&[2, 3, 6, 6], 72);
        let errs: Vec<f64> = [0.25, 0.5, 1.0]
            .iter()
            .map(|&f| {
                let c = mk(f, 8);
                c.decompress(&c.compress(&x).unwrap())
                    .unwrap()
                    .rel_l2_error(&x)
            })
            .collect();
        assert!(
            errs[0] > errs[1] && errs[1] > errs[2],
            "errors {errs:?} must fall with rank"
        );
        assert!(errs[0] < 0.95, "quarter-rank projection keeps some signal");
    }

    #[test]
    fn wire_size_tracks_rank_and_bits() {
        let x = smooth_activations(&[2, 4, 8, 8], 73);
        let by_rank: Vec<usize> = [0.25, 0.5, 1.0]
            .iter()
            .map(|&f| mk(f, 4).compress(&x).unwrap().wire_bytes())
            .collect();
        assert!(by_rank[0] < by_rank[1] && by_rank[1] < by_rank[2]);
        let by_bits: Vec<usize> = [2, 4, 8]
            .iter()
            .map(|&bits| mk(0.5, bits).compress(&x).unwrap().wire_bytes())
            .collect();
        assert!(by_bits[0] < by_bits[1] && by_bits[1] < by_bits[2]);
    }

    #[test]
    fn decoder_rank_comes_from_the_wire() {
        // a decoder configured at a different fraction still decodes
        // correctly: r travels in the payload
        let x = smooth_activations(&[1, 2, 5, 5], 74);
        let enc = mk(0.5, 8);
        let p = enc.compress(&x).unwrap();
        let a = enc.decompress(&p).unwrap();
        let b = mk(0.25, 8).decompress(&p).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn degenerate_inputs_roundtrip() {
        let c = mk(0.5, 4);
        let z = Tensor::zeros(&[1, 2, 3, 3]);
        let back = c.decompress(&c.compress(&z).unwrap()).unwrap();
        // all coefficients are exactly 0 ⇒ exact reconstruction
        assert_eq!(back.data(), z.data());
        let one = Tensor::new(&[1, 1, 1, 1], vec![3.25]);
        let b1 = c.decompress(&c.compress(&one).unwrap()).unwrap();
        // P = 1 ⇒ r = 1 and the basis row is ±1
        assert!((b1.data()[0] - 3.25).abs() < 1e-2);
    }

    #[test]
    fn corrupt_rank_and_trailing_bytes_rejected() {
        let x = smooth_activations(&[1, 2, 4, 4], 75);
        let c = mk(0.5, 4);
        let mut p = c.compress(&x).unwrap();
        p.body[..2].copy_from_slice(&0u16.to_le_bytes());
        assert!(c.decompress(&p).is_err(), "rank 0 rejected");
        let mut p2 = c.compress(&x).unwrap();
        p2.body[..2].copy_from_slice(&1000u16.to_le_bytes());
        assert!(c.decompress(&p2).is_err(), "rank > P rejected");
        let mut p3 = c.compress(&x).unwrap();
        p3.body.push(0);
        assert!(c.decompress(&p3).is_err(), "trailing bytes rejected");
    }
}
