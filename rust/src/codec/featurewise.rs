//! Adaptive feature-wise drop + quantize (Oh et al. 2023, arXiv:2307.10805).
//!
//! Where FC-SL ([`crate::codec::SplitFcCodec`]) keeps a *fixed fraction* of
//! channels by std rank, this codec is fully adaptive: a channel survives
//! when its dispersion clears a **relative threshold**, and each surviving
//! channel is quantized at its own bit width proportional to how much of
//! the sample's dispersion it carries. Per sample:
//!
//! 1. `s_c = std(x_c)` for every channel, `s_max = max_c s_c`;
//! 2. drop channel `c` iff `s_c < drop_threshold · s_max` (each dropped
//!    channel is summarized by its f16 mean, as in FC-SL); an all-constant
//!    sample (`s_max = 0`) legitimately drops **every** channel;
//! 3. kept channels get `b_c = round(b_min + (b_max − b_min) · s_c/s_max)`
//!    bits of per-channel min-max quantization.
//!
//! Unlike FC-SL there is no ranking sort — only max folds — so the kernel
//! is allocation-free and covered by `tests/codec_zero_alloc.rs`.
//!
//! Wire layout (body, after the standard payload header), frozen by the
//! golden vectors in `tests/golden/codec_wire.json`:
//!
//! ```text
//! per sample:
//!   ⌈C/8⌉ bytes                 channel bitmap (bit set ⇒ channel kept)
//!   f16 × (#dropped)            dropped channel means, channel-ascending
//!   per kept channel (ascending):
//!     u8   b_c                  allocated bit width
//!     f32  min                  channel range minimum
//!     f32  max                  channel range maximum
//!     ⌈M·N·b_c/8⌉ bytes         packed levels, row-major, MSB-first
//! ```

use super::plan::CodecScratch;
use super::wire::{BodyReader, BodyWriter, Payload};
use super::{ActivationCodec, CodecKind};
use crate::quant::{pack_levels_into, unpack_levels_lut, AllocationConfig, LinearQuantizer};
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Feature-wise codec parameters.
#[derive(Debug, Clone, Copy)]
pub struct FeatureWiseConfig {
    /// Relative dispersion threshold in `[0, 1]`: channel `c` is dropped
    /// when `s_c < drop_threshold · s_max`. 0 keeps everything (of a
    /// non-constant sample); 1 keeps only the max-dispersion channels.
    pub drop_threshold: f64,
    /// Bit-width bounds for the kept channels.
    pub alloc: AllocationConfig,
}

impl Default for FeatureWiseConfig {
    fn default() -> Self {
        FeatureWiseConfig {
            drop_threshold: 0.2,
            alloc: AllocationConfig::default(),
        }
    }
}

/// Adaptive feature-wise drop/quantize codec. Spatial domain, deterministic.
#[derive(Debug, Clone)]
pub struct FeatureWiseCodec {
    cfg: FeatureWiseConfig,
}

/// Eq. 7-style linear ramp on the dispersion share (no log map: stds are
/// already scale-compressed relative to energies).
fn feature_bits(alloc: &AllocationConfig, s: f32, s_max: f32) -> u32 {
    let frac = ((s as f64) / (s_max as f64)).clamp(0.0, 1.0);
    let b = alloc.b_min as f64 + (alloc.b_max - alloc.b_min) as f64 * frac;
    (b + 0.5).floor().clamp(alloc.b_min as f64, alloc.b_max as f64) as u32
}

impl FeatureWiseCodec {
    /// Build from config (panics on out-of-range threshold/bounds).
    pub fn new(cfg: FeatureWiseConfig) -> Self {
        assert!(
            (0.0..=1.0).contains(&cfg.drop_threshold),
            "drop_threshold must be in [0, 1]"
        );
        cfg.alloc.validate().expect("feature-wise bit bounds");
        FeatureWiseCodec { cfg }
    }

    fn compress_impl(
        &self,
        x: &Tensor,
        scratch: &mut CodecScratch,
        body: Vec<u8>,
    ) -> Result<Payload> {
        let (b, c, m, n) = x.as_bchw();
        let mut w = BodyWriter::from_vec(body, 0);
        let stds = &mut scratch.vals;
        let bitmap = &mut scratch.bitmap;
        for bi in 0..b {
            stds.clear();
            let mut s_max = 0.0f32;
            for ci in 0..c {
                let s = crate::tensor::std_dev(x.channel(bi, ci));
                s_max = s_max.max(s);
                stds.push(s);
            }
            bitmap.clear();
            bitmap.resize((c + 7) / 8, 0);
            if s_max > 0.0 {
                for ci in 0..c {
                    if (stds[ci] as f64) >= self.cfg.drop_threshold * (s_max as f64) {
                        bitmap[ci / 8] |= 1 << (ci % 8);
                    }
                }
            }
            // s_max == 0 (all channels constant): bitmap stays empty and
            // the whole sample travels as C f16 means
            w.bytes(bitmap);
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) == 0 {
                    let ch = x.channel(bi, ci);
                    let mean = ch.iter().sum::<f32>() / ch.len() as f32;
                    w.f16(mean);
                }
            }
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) != 0 {
                    let ch = x.channel(bi, ci);
                    let bits = feature_bits(&self.cfg.alloc, stds[ci], s_max);
                    let q = LinearQuantizer::fit(bits, ch);
                    w.u8(bits as u8);
                    w.f32(q.min);
                    w.f32(q.max);
                    pack_levels_into(ch, &q, &mut w);
                }
            }
        }
        Ok(Payload {
            kind: CodecKind::FeatureWise as u8,
            shape: [b, c, m, n],
            body: w.finish(),
        })
    }
}

impl ActivationCodec for FeatureWiseCodec {
    fn name(&self) -> &'static str {
        "featurewise"
    }

    fn kind(&self) -> CodecKind {
        CodecKind::FeatureWise
    }

    fn compress(&self, x: &Tensor) -> Result<Payload> {
        super::compress_fresh(self, x)
    }

    fn decompress(&self, p: &Payload) -> Result<Tensor> {
        super::decompress_fresh(self, p)
    }

    fn compress_into(
        &self,
        x: &Tensor,
        _rng: &mut Pcg32,
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> Result<()> {
        let body = std::mem::take(&mut out.body);
        *out = self.compress_impl(x, scratch, body)?;
        Ok(())
    }

    fn decompress_into(
        &self,
        p: &Payload,
        scratch: &mut CodecScratch,
        out: &mut Tensor,
    ) -> Result<()> {
        let [b, c, m, n] = p.shape;
        let plane = m * n;
        out.reset_dense(&[b, c, m, n]);
        let mut r = BodyReader::new(&p.body);
        let bitmap = &mut scratch.bitmap;
        for bi in 0..b {
            bitmap.clear();
            bitmap.extend_from_slice(r.bytes((c + 7) / 8)?);
            // an empty bitmap is legitimate here (all-constant sample) —
            // unlike FC-SL, which always keeps >= 1 channel
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) == 0 {
                    let mean = r.f16()?;
                    out.channel_mut(bi, ci).fill(mean);
                }
            }
            for ci in 0..c {
                if bitmap[ci / 8] & (1 << (ci % 8)) != 0 {
                    let bits = r.u8()? as u32;
                    ensure!(
                        (1..=16).contains(&bits),
                        "corrupt feature-wise bit width {bits}"
                    );
                    let min = r.f32()?;
                    let max = r.f32()?;
                    let q = LinearQuantizer { bits, min, max };
                    unpack_levels_lut(
                        &mut r,
                        &q,
                        plane,
                        &mut scratch.lut,
                        out.channel_mut(bi, ci),
                    )?;
                }
            }
        }
        ensure!(
            r.remaining() == 0,
            "trailing bytes in feature-wise payload: {}",
            r.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::smooth_activations;
    use crate::rng::Pcg32;

    fn mk(thr: f64) -> FeatureWiseCodec {
        FeatureWiseCodec::new(FeatureWiseConfig {
            drop_threshold: thr,
            ..Default::default()
        })
    }

    #[test]
    fn zero_threshold_keeps_everything_and_roundtrips() {
        let x = smooth_activations(&[2, 4, 9, 9], 51);
        let c = mk(0.0);
        let p = c.compress(&x).unwrap();
        // bitmap of sample 0: all 4 channels set
        assert_eq!(p.body[0], 0b0000_1111);
        let back = c.decompress(&p).unwrap();
        assert!(back.rel_l2_error(&x) < 0.2);
    }

    #[test]
    fn flat_channels_dropped_and_mean_reconstructed() {
        let mut rng = Pcg32::seeded(52);
        let mut x = Tensor::zeros(&[1, 4, 6, 6]);
        for v in x.channel_mut(0, 1).iter_mut() {
            *v = rng.normal();
        }
        for ci in [0usize, 2, 3] {
            x.channel_mut(0, ci).fill(1.5); // exactly representable in f16
        }
        let c = mk(0.5);
        let p = c.compress(&x).unwrap();
        assert_eq!(p.body[0], 0b0000_0010, "only the noisy channel survives");
        let back = c.decompress(&p).unwrap();
        for ci in [0usize, 2, 3] {
            assert_eq!(back.channel(0, ci), x.channel(0, ci));
        }
        assert!(
            Tensor::new(&[36], back.channel(0, 1).to_vec())
                .rel_l2_error(&Tensor::new(&[36], x.channel(0, 1).to_vec()))
                < 0.05,
            "max-dispersion channel rides at b_max"
        );
    }

    #[test]
    fn all_constant_sample_drops_every_channel() {
        let x = Tensor::full(&[2, 3, 5, 5], -2.5);
        let c = mk(0.2);
        let p = c.compress(&x).unwrap();
        // 2 samples × (1 bitmap byte + 3 f16 means) — nothing else
        assert_eq!(p.body.len(), 2 * (1 + 3 * 2));
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.data(), x.data());
    }

    #[test]
    fn dispersion_share_drives_bit_widths() {
        let mut x = Tensor::zeros(&[1, 2, 6, 6]);
        for (i, v) in x.channel_mut(0, 0).iter_mut().enumerate() {
            *v = if i % 2 == 0 { 4.0 } else { -4.0 };
        }
        for (i, v) in x.channel_mut(0, 1).iter_mut().enumerate() {
            *v = if i % 2 == 0 { 0.4 } else { -0.4 };
        }
        let c = mk(0.0);
        let p = c.compress(&x).unwrap();
        let mut r = BodyReader::new(&p.body);
        r.bytes(1).unwrap(); // bitmap: both kept, no dropped means
        let b0 = r.u8().unwrap();
        assert_eq!(b0, 8, "s_max channel gets b_max");
        r.f32().unwrap();
        r.f32().unwrap();
        r.bytes((36 * b0 as usize + 7) / 8).unwrap();
        let b1 = r.u8().unwrap();
        // s_1/s_max = 0.1 → round(2 + 6·0.1) = 3
        assert_eq!(b1, 3, "low-dispersion channel rides near b_min");
    }

    #[test]
    fn wire_size_shrinks_as_threshold_rises() {
        // channels with geometrically decaying dispersion: each threshold
        // step drops more of them
        let mut rng = Pcg32::seeded(53);
        let mut x = Tensor::zeros(&[1, 8, 8, 8]);
        for ci in 0..8 {
            let scale = 0.5f32.powi(ci as i32);
            for v in x.channel_mut(0, ci).iter_mut() {
                *v = rng.normal() * scale;
            }
        }
        let sizes: Vec<usize> = [0.0, 0.3, 0.9]
            .iter()
            .map(|&t| mk(t).compress(&x).unwrap().wire_bytes())
            .collect();
        assert!(
            sizes[0] > sizes[1] && sizes[1] > sizes[2],
            "sizes {sizes:?} must decrease with threshold"
        );
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let x = smooth_activations(&[1, 3, 6, 6], 54);
        let c = mk(0.0);
        let mut p = c.compress(&x).unwrap();
        p.body.truncate(p.body.len() - 2);
        assert!(c.decompress(&p).is_err());
        let mut p2 = c.compress(&x).unwrap();
        p2.body.push(0);
        assert!(c.decompress(&p2).is_err());
    }
}
