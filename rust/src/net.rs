//! Network simulator: per-link bandwidth/latency model + byte accounting.
//!
//! The paper's testbed moves smashed data between GPUs over real links; here
//! the transfer is a function call, so communication cost is *modeled*:
//! each device↔server link has a bandwidth (bits/s), a propagation latency,
//! and optional jitter. The simulator charges every payload's exact wire
//! bytes and accumulates per-device and global statistics — these numbers
//! are what Fig. 2's x-axis ("communication rounds" at a fixed per-round
//! budget) and the comm-volume tables in EXPERIMENTS.md come from.
//!
//! Time is simulated (a deterministic clock), independent of wall time, so
//! experiments reproduce exactly regardless of host load.

use crate::rng::Pcg32;

/// Direction of a transfer (device→server or server→device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Device → server (activations).
    Uplink,
    /// Server → device (gradients).
    Downlink,
}

/// Configuration of one device↔server link.
#[derive(Debug, Clone, Copy)]
pub struct LinkConfig {
    /// Uplink bandwidth in bits per second.
    pub uplink_bps: f64,
    /// Downlink bandwidth in bits per second.
    pub downlink_bps: f64,
    /// One-way propagation latency in seconds.
    pub latency_s: f64,
    /// Multiplicative jitter amplitude (0 = deterministic; 0.1 ⇒ ±10%).
    pub jitter: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        // A WiFi-class edge link: 100 Mbit/s symmetric, 5 ms.
        LinkConfig {
            uplink_bps: 100e6,
            downlink_bps: 100e6,
            latency_s: 0.005,
            jitter: 0.0,
        }
    }
}

/// One simulated link with cumulative accounting.
#[derive(Debug)]
pub struct Link {
    /// Configuration.
    pub cfg: LinkConfig,
    rng: Pcg32,
    /// Total bytes sent device→server.
    pub uplink_bytes: u64,
    /// Total bytes sent server→device.
    pub downlink_bytes: u64,
    /// Total simulated transfer seconds (both directions).
    pub busy_s: f64,
    /// Number of transfers.
    pub transfers: u64,
}

impl Link {
    /// New link with deterministic per-link jitter stream.
    pub fn new(cfg: LinkConfig, seed: u64) -> Self {
        Link {
            cfg,
            rng: Pcg32::new(seed, 911),
            uplink_bytes: 0,
            downlink_bytes: 0,
            busy_s: 0.0,
            transfers: 0,
        }
    }

    /// Charge a transfer of `bytes` in `dir`; returns the simulated transfer
    /// time in seconds (latency + serialization, with jitter applied).
    pub fn transfer(&mut self, dir: Direction, bytes: usize) -> f64 {
        let bps = match dir {
            Direction::Uplink => self.cfg.uplink_bps,
            Direction::Downlink => self.cfg.downlink_bps,
        };
        let mut t = self.cfg.latency_s + (bytes as f64 * 8.0) / bps;
        if self.cfg.jitter > 0.0 {
            let j = 1.0 + self.cfg.jitter * (2.0 * self.rng.uniform_f64() - 1.0);
            t *= j.max(0.0);
        }
        match dir {
            Direction::Uplink => self.uplink_bytes += bytes as u64,
            Direction::Downlink => self.downlink_bytes += bytes as u64,
        }
        self.busy_s += t;
        self.transfers += 1;
        t
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }
}

/// Aggregated communication statistics for a set of links (one per device).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    /// Sum of uplink bytes across devices.
    pub uplink_bytes: u64,
    /// Sum of downlink bytes across devices.
    pub downlink_bytes: u64,
    /// Max per-device busy time — the round's communication makespan when
    /// devices transfer in parallel.
    pub makespan_s: f64,
    /// Sum of busy times — total network occupancy.
    pub total_busy_s: f64,
}

impl CommStats {
    /// Gather stats from links. Accumulation is in slice order — callers
    /// that need bit-reproducible `total_busy_s` across runs must pass
    /// links in device-id order (the trainer does), never in thread
    /// completion order.
    pub fn from_links(links: &[Link]) -> Self {
        let mut s = CommStats::default();
        for l in links {
            s.accumulate(l);
        }
        s
    }

    /// Fold one link into the aggregate (order-stable f64 summation: the
    /// caller fixes the fold order, so the parallel round engine reduces
    /// after its phase barrier in device-id order and gets bytes *and*
    /// times bit-identical to a sequential run).
    pub fn accumulate(&mut self, l: &Link) {
        self.uplink_bytes += l.uplink_bytes;
        self.downlink_bytes += l.downlink_bytes;
        self.total_busy_s += l.busy_s;
        if l.busy_s > self.makespan_s {
            self.makespan_s = l.busy_s;
        }
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Bit-exact equality (f64 fields compared by bit pattern, so `-0.0 !=
    /// 0.0` and NaNs compare by payload — exactly what the differential
    /// determinism tests need).
    pub fn bit_eq(&self, other: &CommStats) -> bool {
        self.uplink_bytes == other.uplink_bytes
            && self.downlink_bytes == other.downlink_bytes
            && self.makespan_s.to_bits() == other.makespan_s.to_bits()
            && self.total_busy_s.to_bits() == other.total_busy_s.to_bits()
    }
}

/// Compile-time guard: links (and their RNG streams) migrate into the
/// round engine's worker threads.
#[allow(dead_code)]
fn assert_link_is_send() {
    fn is_send<T: Send>() {}
    is_send::<Link>();
    is_send::<CommStats>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 8e6, // 1 MB/s
                downlink_bps: 8e6,
                latency_s: 0.01,
                jitter: 0.0,
            },
            1,
        );
        let t = l.transfer(Direction::Uplink, 1_000_000);
        assert!((t - 1.01).abs() < 1e-9, "t={t}");
        assert_eq!(l.uplink_bytes, 1_000_000);
        assert_eq!(l.downlink_bytes, 0);
    }

    #[test]
    fn deterministic_without_jitter() {
        let mk = || Link::new(LinkConfig::default(), 42);
        let (mut a, mut b) = (mk(), mk());
        for i in 0..10 {
            assert_eq!(
                a.transfer(Direction::Uplink, 1000 * i),
                b.transfer(Direction::Uplink, 1000 * i)
            );
        }
    }

    #[test]
    fn jitter_bounded() {
        let cfg = LinkConfig {
            jitter: 0.1,
            ..Default::default()
        };
        let mut l = Link::new(cfg, 7);
        let base = cfg.latency_s + 8.0 * 1e6 / cfg.uplink_bps;
        for _ in 0..100 {
            let t = l.transfer(Direction::Uplink, 1_000_000);
            assert!(t >= base * 0.89 && t <= base * 1.11, "t={t} base={base}");
        }
    }

    #[test]
    fn stats_aggregate_and_makespan() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 10_000_000);
        l2.transfer(Direction::Uplink, 1_000);
        l2.transfer(Direction::Downlink, 2_000);
        let s = CommStats::from_links(&[l1, l2]);
        assert_eq!(s.uplink_bytes, 10_001_000);
        assert_eq!(s.downlink_bytes, 2_000);
        assert!(s.makespan_s < s.total_busy_s);
    }

    #[test]
    fn accumulate_matches_from_links_and_bit_eq() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 5_000);
        l2.transfer(Direction::Downlink, 7_000);
        let batch = CommStats::from_links(&[l1, l2]);
        // re-create the same traffic and fold incrementally
        let mut a = Link::new(LinkConfig::default(), 1);
        let mut b = Link::new(LinkConfig::default(), 2);
        a.transfer(Direction::Uplink, 5_000);
        b.transfer(Direction::Downlink, 7_000);
        let mut inc = CommStats::default();
        inc.accumulate(&a);
        inc.accumulate(&b);
        assert!(batch.bit_eq(&inc));
        // any field difference breaks bit equality
        let mut other = inc.clone();
        other.total_busy_s += 1e-12;
        assert!(!inc.bit_eq(&other));
    }

    #[test]
    fn asymmetric_links() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 1e6,
                downlink_bps: 10e6,
                latency_s: 0.0,
                jitter: 0.0,
            },
            3,
        );
        let up = l.transfer(Direction::Uplink, 125_000); // 1 s at 1 Mb/s
        let down = l.transfer(Direction::Downlink, 125_000); // 0.1 s
        assert!((up - 1.0).abs() < 1e-9);
        assert!((down - 0.1).abs() < 1e-9);
    }
}
