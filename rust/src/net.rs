//! Legacy path for the network simulator — the implementation moved to
//! [`crate::transport::link`] when the transport API landed (event-driven
//! schedulers, device profiles, straggler policies live in
//! [`crate::transport`]). This re-export keeps `slfac::net::{Link, …}`
//! working for existing callers and tests.

pub use crate::transport::link::{CommStats, Direction, Link, LinkConfig};
