//! Straggler policies: when does an async round stop waiting?
//!
//! The async scheduler consumes uplinks as they land; the policy decides
//! when the round *closes* and what happens to devices still in flight:
//!
//! * [`StragglerPolicy::WaitAll`] — the round closes when every device has
//!   finished all its local steps (no drops; the async analogue of the
//!   sync barrier, and the mode that matches sync-mode byte totals under
//!   homogeneous profiles).
//! * [`StragglerPolicy::DeadlineDrop`] — the round closes at a fixed
//!   simulated deadline; devices that have not completed by then are
//!   dropped from this round's aggregation and their in-flight work is
//!   abandoned (bytes already on the wire stay charged — they were
//!   transmitted).
//! * [`StragglerPolicy::Quorum`] — the round closes the moment the `k`-th
//!   device completes; the remaining `n − k` are dropped. Ties at the same
//!   simulated instant resolve in event (seq) order, deterministically.
//!
//! Dropped devices still rejoin at the next round start (SplitFed resets
//! client weights to the aggregate), so a straggler is excluded per-round,
//! never evicted.
//!
//! This module also hosts [`ClientSampling`] — *who participates* in a
//! round, drawn per-round from a seed-derived stream — which composes with
//! the straggler policies (*when the round closes* over the sampled set).

use crate::rng::{stream, Pcg32};
use anyhow::{bail, Result};

/// Round-close policy for the async scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StragglerPolicy {
    /// Wait for every device to finish all its steps.
    WaitAll,
    /// Close the round at `deadline_s` of simulated time; drop devices
    /// that have not completed by then.
    DeadlineDrop {
        /// Simulated round deadline in seconds (> 0).
        deadline_s: f64,
    },
    /// Close the round when `k` devices have completed; drop the rest.
    Quorum {
        /// Number of devices that must complete (1 ≤ k ≤ devices).
        k: usize,
    },
}

impl StragglerPolicy {
    /// Build from config/CLI parts: a policy name plus the optional
    /// `deadline_s` / `quorum_k` parameters it needs. Parameters the named
    /// policy does not consume are rejected (typo safety — mirrors the
    /// config layer's unknown-key strictness).
    pub fn from_parts(name: &str, deadline_s: Option<f64>, k: Option<usize>) -> Result<Self> {
        let policy = match name.to_ascii_lowercase().as_str() {
            "wait-all" | "waitall" | "all" => StragglerPolicy::WaitAll,
            "deadline-drop" | "deadline" => {
                let Some(d) = deadline_s else {
                    bail!("straggler policy 'deadline-drop' needs deadline_s")
                };
                StragglerPolicy::DeadlineDrop { deadline_s: d }
            }
            "quorum" | "k-of-n" => {
                let Some(k) = k else {
                    bail!("straggler policy 'quorum' needs quorum_k")
                };
                StragglerPolicy::Quorum { k }
            }
            other => bail!("unknown straggler policy '{other}' (wait-all|deadline-drop|quorum)"),
        };
        match policy {
            StragglerPolicy::WaitAll if deadline_s.is_some() || k.is_some() => {
                bail!("straggler policy 'wait-all' takes no deadline_s/quorum_k")
            }
            StragglerPolicy::DeadlineDrop { .. } if k.is_some() => {
                bail!("straggler policy 'deadline-drop' does not take quorum_k")
            }
            StragglerPolicy::Quorum { .. } if deadline_s.is_some() => {
                bail!("straggler policy 'quorum' does not take deadline_s")
            }
            _ => {}
        }
        Ok(policy)
    }

    /// Stable display name (config key value).
    pub fn name(&self) -> &'static str {
        match self {
            StragglerPolicy::WaitAll => "wait-all",
            StragglerPolicy::DeadlineDrop { .. } => "deadline-drop",
            StragglerPolicy::Quorum { .. } => "quorum",
        }
    }

    /// Validate parameters against the device count.
    pub fn validate(&self, devices: usize) -> Result<()> {
        match *self {
            StragglerPolicy::WaitAll => {}
            StragglerPolicy::DeadlineDrop { deadline_s } => {
                if !(deadline_s.is_finite() && deadline_s > 0.0) {
                    bail!("deadline_s must be a positive finite number, got {deadline_s}");
                }
            }
            StragglerPolicy::Quorum { k } => {
                if k == 0 || k > devices {
                    bail!("quorum_k must be in [1, devices={devices}], got {k}");
                }
            }
        }
        Ok(())
    }
}

/// Per-round client sampling: which devices participate in a round.
///
/// Large fleets rarely run every device every round (FedAvg-style client
/// sampling); the sampled subset is drawn from a stream derived from
/// `(seed, stream::SAMPLE, round)`, so membership is a pure function of
/// the experiment seed and the round index — independent of worker count,
/// scheduler, or any other RNG consumer. Devices left out of a round
/// transfer nothing, carry zero FedAvg weight, and rejoin from the
/// aggregate at the next round start (exactly the straggler rejoin path,
/// minus the wasted bytes).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ClientSampling {
    /// Every device participates every round (default).
    #[default]
    Full,
    /// A fraction in `(0, 1]` of the fleet participates each round
    /// (`max(1, round(fraction × devices))`).
    Fraction(f64),
    /// Exactly `k` devices participate each round (`k ≥ devices` degrades
    /// to full participation).
    Count(usize),
}

impl ClientSampling {
    /// Build from the optional `sample_fraction` / `sample_k` config keys.
    /// Setting both is rejected — they are two spellings of one knob.
    pub fn from_parts(fraction: Option<f64>, k: Option<usize>) -> Result<Self> {
        match (fraction, k) {
            (None, None) => Ok(ClientSampling::Full),
            (Some(f), None) => Ok(ClientSampling::Fraction(f)),
            (None, Some(k)) => Ok(ClientSampling::Count(k)),
            (Some(f), Some(k)) => {
                bail!("sample_fraction = {f} and sample_k = {k} are mutually exclusive — set one")
            }
        }
    }

    /// Stable display name (config key family).
    pub fn name(&self) -> &'static str {
        match self {
            ClientSampling::Full => "full",
            ClientSampling::Fraction(_) => "sample_fraction",
            ClientSampling::Count(_) => "sample_k",
        }
    }

    /// Validate parameters: `sample_fraction` must lie in `(0, 1]`,
    /// `sample_k` must be ≥ 1. The upper bound is soft — `sample_k`
    /// beyond the fleet size degrades to full participation, so it takes
    /// no device count here (mirroring that asymmetry on purpose).
    pub fn validate(&self, _devices: usize) -> Result<()> {
        match *self {
            ClientSampling::Full => {}
            ClientSampling::Fraction(f) => {
                if !(f.is_finite() && f > 0.0 && f <= 1.0) {
                    bail!("sample_fraction must be in (0, 1], got {f}");
                }
            }
            ClientSampling::Count(k) => {
                if k == 0 {
                    bail!("sample_k must be >= 1, got 0");
                }
            }
        }
        Ok(())
    }

    /// Number of devices that participate each round, for a fleet of
    /// `devices` (always in `[1, devices]` after validation).
    pub fn effective_k(&self, devices: usize) -> usize {
        match *self {
            ClientSampling::Full => devices,
            ClientSampling::Fraction(f) => {
                (((f * devices as f64).round() as usize).max(1)).min(devices)
            }
            ClientSampling::Count(k) => k.min(devices),
        }
    }

    /// Draw the round's participant set: `effective_k` distinct device ids
    /// in **ascending order** (so every device-id-ordered convention —
    /// event seq ties, reductions, server order under the sync scheduler —
    /// holds within the sampled subset exactly as it does for the full
    /// fleet). `Full` never touches the RNG stream.
    pub fn draw(&self, seed: u64, round: usize, devices: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.draw_into(seed, round, devices, &mut out);
        out
    }

    /// [`ClientSampling::draw`] into a caller-owned buffer (cleared,
    /// capacity reused). Zero heap allocation once `out` is warm:
    /// sampled draws use selection sampling (Knuth's Algorithm S), which
    /// scans the fleet once and emits the subset **already sorted** —
    /// O(devices) time, O(1) extra space, uniform over k-subsets. The
    /// draw still depends only on `(seed, stream::SAMPLE, round)`; `Full`
    /// never touches the RNG stream.
    pub fn draw_into(&self, seed: u64, round: usize, devices: usize, out: &mut Vec<usize>) {
        out.clear();
        let k = self.effective_k(devices);
        if k == devices {
            out.extend(0..devices);
            return;
        }
        let mut rng = Pcg32::derived(seed, stream::SAMPLE, round as u64);
        let mut need = k;
        for d in 0..devices {
            // P(select d) = need / left — the classic selection-sampling
            // invariant; uniform_f64() < 1 guarantees selection whenever
            // need == left, so exactly k ids are always emitted
            let left = devices - d;
            if rng.uniform_f64() * left as f64 < need as f64 {
                out.push(d);
                need -= 1;
                if need == 0 {
                    break;
                }
            }
        }
        debug_assert_eq!(out.len(), k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_names() {
        assert_eq!(
            StragglerPolicy::from_parts("wait-all", None, None).unwrap(),
            StragglerPolicy::WaitAll
        );
        assert_eq!(
            StragglerPolicy::from_parts("deadline-drop", Some(0.5), None).unwrap(),
            StragglerPolicy::DeadlineDrop { deadline_s: 0.5 }
        );
        assert_eq!(
            StragglerPolicy::from_parts("quorum", None, Some(3)).unwrap(),
            StragglerPolicy::Quorum { k: 3 }
        );
        assert!(StragglerPolicy::from_parts("bogus", None, None).is_err());
    }

    #[test]
    fn missing_parameters_rejected() {
        assert!(StragglerPolicy::from_parts("deadline-drop", None, None).is_err());
        assert!(StragglerPolicy::from_parts("quorum", Some(1.0), None).is_err());
    }

    #[test]
    fn extraneous_parameters_rejected() {
        // a parameter the named policy does not consume is a config typo,
        // not something to drop on the floor
        assert!(StragglerPolicy::from_parts("wait-all", Some(1.0), None).is_err());
        assert!(StragglerPolicy::from_parts("wait-all", None, Some(2)).is_err());
        assert!(StragglerPolicy::from_parts("deadline-drop", Some(1.0), Some(2)).is_err());
        assert!(StragglerPolicy::from_parts("quorum", Some(1.0), Some(2)).is_err());
    }

    #[test]
    fn validation_bounds() {
        assert!(StragglerPolicy::WaitAll.validate(1).is_ok());
        assert!(StragglerPolicy::DeadlineDrop { deadline_s: 0.1 }.validate(4).is_ok());
        assert!(StragglerPolicy::DeadlineDrop { deadline_s: 0.0 }.validate(4).is_err());
        assert!(StragglerPolicy::DeadlineDrop {
            deadline_s: f64::NAN
        }
        .validate(4)
        .is_err());
        assert!(StragglerPolicy::Quorum { k: 4 }.validate(4).is_ok());
        assert!(StragglerPolicy::Quorum { k: 0 }.validate(4).is_err());
        assert!(StragglerPolicy::Quorum { k: 5 }.validate(4).is_err());
    }

    #[test]
    fn sampling_from_parts_and_validation() {
        assert_eq!(
            ClientSampling::from_parts(None, None).unwrap(),
            ClientSampling::Full
        );
        assert_eq!(
            ClientSampling::from_parts(Some(0.25), None).unwrap(),
            ClientSampling::Fraction(0.25)
        );
        assert_eq!(
            ClientSampling::from_parts(None, Some(8)).unwrap(),
            ClientSampling::Count(8)
        );
        assert!(ClientSampling::from_parts(Some(0.5), Some(2)).is_err());
        // fraction must be in (0, 1]
        for bad in [0.0, -0.1, 1.5, f64::NAN] {
            assert!(
                ClientSampling::Fraction(bad).validate(8).is_err(),
                "fraction {bad} should be rejected"
            );
        }
        assert!(ClientSampling::Fraction(1.0).validate(8).is_ok());
        assert!(ClientSampling::Count(0).validate(8).is_err());
        assert!(ClientSampling::Count(100).validate(8).is_ok(), "k > devices degrades");
    }

    #[test]
    fn sampling_effective_k() {
        assert_eq!(ClientSampling::Full.effective_k(10), 10);
        assert_eq!(ClientSampling::Fraction(0.5).effective_k(10), 5);
        assert_eq!(ClientSampling::Fraction(0.01).effective_k(10), 1, "at least one");
        assert_eq!(ClientSampling::Fraction(1.0).effective_k(10), 10);
        assert_eq!(ClientSampling::Count(3).effective_k(10), 3);
        assert_eq!(ClientSampling::Count(99).effective_k(10), 10, "clamped to fleet");
    }

    #[test]
    fn sampling_draw_is_sorted_distinct_and_round_deterministic() {
        let s = ClientSampling::Fraction(0.5);
        let a = s.draw(42, 3, 16);
        let b = s.draw(42, 3, 16);
        assert_eq!(a, b, "same (seed, round) => same participants");
        assert_eq!(a.len(), 8);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "ascending & distinct: {a:?}");
        assert!(a.iter().all(|&d| d < 16));
        // different rounds draw different subsets (overwhelmingly likely
        // for 16-choose-8; equality would indicate a broken stream)
        let rounds: Vec<Vec<usize>> = (1..=6).map(|r| s.draw(42, r, 16)).collect();
        assert!(
            rounds.windows(2).any(|w| w[0] != w[1]),
            "six rounds drew identical subsets"
        );
    }

    #[test]
    fn sampling_full_participation_shapes() {
        assert_eq!(ClientSampling::Full.draw(1, 1, 4), vec![0, 1, 2, 3]);
        // k >= devices degrades to full participation, identical vector
        assert_eq!(ClientSampling::Count(9).draw(1, 1, 4), vec![0, 1, 2, 3]);
        assert_eq!(ClientSampling::Fraction(1.0).draw(1, 1, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn names_roundtrip_through_from_parts() {
        for p in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 1.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            let (d, k) = match p {
                StragglerPolicy::WaitAll => (None, None),
                StragglerPolicy::DeadlineDrop { deadline_s } => (Some(deadline_s), None),
                StragglerPolicy::Quorum { k } => (None, Some(k)),
            };
            let back = StragglerPolicy::from_parts(p.name(), d, k).unwrap();
            assert_eq!(back, p);
        }
    }
}
