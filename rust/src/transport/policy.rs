//! Straggler policies: when does an async round stop waiting?
//!
//! The async scheduler consumes uplinks as they land; the policy decides
//! when the round *closes* and what happens to devices still in flight:
//!
//! * [`StragglerPolicy::WaitAll`] — the round closes when every device has
//!   finished all its local steps (no drops; the async analogue of the
//!   sync barrier, and the mode that matches sync-mode byte totals under
//!   homogeneous profiles).
//! * [`StragglerPolicy::DeadlineDrop`] — the round closes at a fixed
//!   simulated deadline; devices that have not completed by then are
//!   dropped from this round's aggregation and their in-flight work is
//!   abandoned (bytes already on the wire stay charged — they were
//!   transmitted).
//! * [`StragglerPolicy::Quorum`] — the round closes the moment the `k`-th
//!   device completes; the remaining `n − k` are dropped. Ties at the same
//!   simulated instant resolve in event (seq) order, deterministically.
//!
//! Dropped devices still rejoin at the next round start (SplitFed resets
//! client weights to the aggregate), so a straggler is excluded per-round,
//! never evicted.

use anyhow::{bail, Result};

/// Round-close policy for the async scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StragglerPolicy {
    /// Wait for every device to finish all its steps.
    WaitAll,
    /// Close the round at `deadline_s` of simulated time; drop devices
    /// that have not completed by then.
    DeadlineDrop {
        /// Simulated round deadline in seconds (> 0).
        deadline_s: f64,
    },
    /// Close the round when `k` devices have completed; drop the rest.
    Quorum {
        /// Number of devices that must complete (1 ≤ k ≤ devices).
        k: usize,
    },
}

impl StragglerPolicy {
    /// Build from config/CLI parts: a policy name plus the optional
    /// `deadline_s` / `quorum_k` parameters it needs. Parameters the named
    /// policy does not consume are rejected (typo safety — mirrors the
    /// config layer's unknown-key strictness).
    pub fn from_parts(name: &str, deadline_s: Option<f64>, k: Option<usize>) -> Result<Self> {
        let policy = match name.to_ascii_lowercase().as_str() {
            "wait-all" | "waitall" | "all" => StragglerPolicy::WaitAll,
            "deadline-drop" | "deadline" => {
                let Some(d) = deadline_s else {
                    bail!("straggler policy 'deadline-drop' needs deadline_s")
                };
                StragglerPolicy::DeadlineDrop { deadline_s: d }
            }
            "quorum" | "k-of-n" => {
                let Some(k) = k else {
                    bail!("straggler policy 'quorum' needs quorum_k")
                };
                StragglerPolicy::Quorum { k }
            }
            other => bail!("unknown straggler policy '{other}' (wait-all|deadline-drop|quorum)"),
        };
        match policy {
            StragglerPolicy::WaitAll if deadline_s.is_some() || k.is_some() => {
                bail!("straggler policy 'wait-all' takes no deadline_s/quorum_k")
            }
            StragglerPolicy::DeadlineDrop { .. } if k.is_some() => {
                bail!("straggler policy 'deadline-drop' does not take quorum_k")
            }
            StragglerPolicy::Quorum { .. } if deadline_s.is_some() => {
                bail!("straggler policy 'quorum' does not take deadline_s")
            }
            _ => {}
        }
        Ok(policy)
    }

    /// Stable display name (config key value).
    pub fn name(&self) -> &'static str {
        match self {
            StragglerPolicy::WaitAll => "wait-all",
            StragglerPolicy::DeadlineDrop { .. } => "deadline-drop",
            StragglerPolicy::Quorum { .. } => "quorum",
        }
    }

    /// Validate parameters against the device count.
    pub fn validate(&self, devices: usize) -> Result<()> {
        match *self {
            StragglerPolicy::WaitAll => {}
            StragglerPolicy::DeadlineDrop { deadline_s } => {
                if !(deadline_s.is_finite() && deadline_s > 0.0) {
                    bail!("deadline_s must be a positive finite number, got {deadline_s}");
                }
            }
            StragglerPolicy::Quorum { k } => {
                if k == 0 || k > devices {
                    bail!("quorum_k must be in [1, devices={devices}], got {k}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_names() {
        assert_eq!(
            StragglerPolicy::from_parts("wait-all", None, None).unwrap(),
            StragglerPolicy::WaitAll
        );
        assert_eq!(
            StragglerPolicy::from_parts("deadline-drop", Some(0.5), None).unwrap(),
            StragglerPolicy::DeadlineDrop { deadline_s: 0.5 }
        );
        assert_eq!(
            StragglerPolicy::from_parts("quorum", None, Some(3)).unwrap(),
            StragglerPolicy::Quorum { k: 3 }
        );
        assert!(StragglerPolicy::from_parts("bogus", None, None).is_err());
    }

    #[test]
    fn missing_parameters_rejected() {
        assert!(StragglerPolicy::from_parts("deadline-drop", None, None).is_err());
        assert!(StragglerPolicy::from_parts("quorum", Some(1.0), None).is_err());
    }

    #[test]
    fn extraneous_parameters_rejected() {
        // a parameter the named policy does not consume is a config typo,
        // not something to drop on the floor
        assert!(StragglerPolicy::from_parts("wait-all", Some(1.0), None).is_err());
        assert!(StragglerPolicy::from_parts("wait-all", None, Some(2)).is_err());
        assert!(StragglerPolicy::from_parts("deadline-drop", Some(1.0), Some(2)).is_err());
        assert!(StragglerPolicy::from_parts("quorum", Some(1.0), Some(2)).is_err());
    }

    #[test]
    fn validation_bounds() {
        assert!(StragglerPolicy::WaitAll.validate(1).is_ok());
        assert!(StragglerPolicy::DeadlineDrop { deadline_s: 0.1 }.validate(4).is_ok());
        assert!(StragglerPolicy::DeadlineDrop { deadline_s: 0.0 }.validate(4).is_err());
        assert!(StragglerPolicy::DeadlineDrop {
            deadline_s: f64::NAN
        }
        .validate(4)
        .is_err());
        assert!(StragglerPolicy::Quorum { k: 4 }.validate(4).is_ok());
        assert!(StragglerPolicy::Quorum { k: 0 }.validate(4).is_err());
        assert!(StragglerPolicy::Quorum { k: 5 }.validate(4).is_err());
    }

    #[test]
    fn names_roundtrip_through_from_parts() {
        for p in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 1.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            let (d, k) = match p {
                StragglerPolicy::WaitAll => (None, None),
                StragglerPolicy::DeadlineDrop { deadline_s } => (Some(deadline_s), None),
                StragglerPolicy::Quorum { k } => (None, Some(k)),
            };
            let back = StragglerPolicy::from_parts(p.name(), d, k).unwrap();
            assert_eq!(back, p);
        }
    }
}
