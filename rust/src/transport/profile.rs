//! Per-device heterogeneity: link classes and compute-speed profiles.
//!
//! The paper's motivating bottleneck is many *heterogeneous* edge devices
//! contending to ship smashed data. A [`DeviceProfile`] captures what
//! differs between them: the link class (bandwidth/latency of its
//! device↔server pipe) and a compute-speed multiplier (how much slower
//! than the reference device its client-side forward/backward runs).
//!
//! Profiles are selected by a **spec string** in the config/CLI
//! (`profile` key / `--profile` flag):
//!
//! * `"config"` (default) — every device uses the experiment's base
//!   `link` settings with multiplier 1.0: exactly the pre-transport
//!   homogeneous behavior.
//! * a single class name (`"wifi"`, `"lte"`, `"5g"`, `"ethernet"`) —
//!   every device gets that class;
//! * a slash-separated mix (`"wifi/lte"`, `"ethernet/5g/lte"`) — device
//!   `d` gets class `d % len` (round-robin), giving deterministic
//!   heterogeneous fleets at any device count.
//!
//! Class presets keep the experiment config's `jitter` setting so jittered
//! runs stay available under heterogeneous fleets; bandwidth and latency
//! come from the class table below.
//!
//! Under `uplink = "shared"` the per-device **uplink bandwidth** is
//! superseded by the shared pipe's capacity (concurrent transfers split it
//! fairly); the profile's propagation latency still applies per flow, and
//! downlinks keep using the profile's private downlink bandwidth.

use super::link::LinkConfig;
use anyhow::{bail, Result};

/// A link technology class with canonical bandwidth/latency numbers and a
/// compute-speed multiplier for the device class that typically sits
/// behind it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Wall-powered edge box on wired ethernet: 1 Gbit/s, 0.2 ms.
    Ethernet,
    /// 5G handset: 100 Mbit/s up / 400 Mbit/s down, 10 ms.
    FiveG,
    /// WiFi-class edge device: 100 Mbit/s symmetric, 5 ms.
    Wifi,
    /// LTE handset: 10 Mbit/s up / 40 Mbit/s down, 40 ms.
    Lte,
}

impl LinkClass {
    /// Parse a class name (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "ethernet" | "eth" | "wired" => LinkClass::Ethernet,
            "5g" | "fiveg" => LinkClass::FiveG,
            "wifi" => LinkClass::Wifi,
            "lte" | "4g" => LinkClass::Lte,
            other => bail!("unknown link class '{other}' (ethernet|5g|wifi|lte)"),
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Ethernet => "ethernet",
            LinkClass::FiveG => "5g",
            LinkClass::Wifi => "wifi",
            LinkClass::Lte => "lte",
        }
    }

    /// Canonical link parameters for the class (`jitter` comes from the
    /// experiment config, passed in by the caller).
    pub fn link_config(&self, jitter: f64) -> LinkConfig {
        let (up, down, lat) = match self {
            LinkClass::Ethernet => (1e9, 1e9, 0.0002),
            LinkClass::FiveG => (100e6, 400e6, 0.010),
            LinkClass::Wifi => (100e6, 100e6, 0.005),
            LinkClass::Lte => (10e6, 40e6, 0.040),
        };
        LinkConfig {
            uplink_bps: up,
            downlink_bps: down,
            latency_s: lat,
            jitter,
        }
    }

    /// Compute-speed multiplier of the device class typically behind this
    /// link (1.0 = reference; larger = slower client compute).
    pub fn compute_mult(&self) -> f64 {
        match self {
            LinkClass::Ethernet => 0.5,
            LinkClass::FiveG => 1.0,
            LinkClass::Wifi => 1.0,
            LinkClass::Lte => 2.0,
        }
    }
}

/// What one device looks like to the transport layer.
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    /// The class this profile came from (`None` = homogeneous `"config"`).
    pub class: Option<LinkClass>,
    /// Link cost-model parameters.
    pub link: LinkConfig,
    /// Client compute-speed multiplier (scales `base_compute_s`).
    pub compute_mult: f64,
}

impl DeviceProfile {
    /// The homogeneous profile: the experiment's base link, multiplier 1.0.
    pub fn homogeneous(link: LinkConfig) -> Self {
        DeviceProfile {
            class: None,
            link,
            compute_mult: 1.0,
        }
    }
}

/// Parse a profile spec (see module docs) and assign one profile per
/// device. `fallback` is the experiment's base `link` config; its `jitter`
/// also applies to class presets.
///
/// Assignment is **round-robin** (`device % classes`), which is what makes
/// the fleet-scale `cohorts` knob natural: devices `d` and `d + k·classes`
/// share a profile, so setting `cohorts` to the class count gives the
/// schedulers' cohort-compressed paths one group per distinct cost profile
/// (any value works — it only sizes the event-grouping table; results are
/// bit-identical regardless — but the class count is the efficient
/// choice).
pub fn assign_profiles(
    spec: &str,
    devices: usize,
    fallback: LinkConfig,
) -> Result<Vec<DeviceProfile>> {
    let spec = spec.trim();
    let homogeneous = spec.is_empty()
        || spec.eq_ignore_ascii_case("config")
        || spec.eq_ignore_ascii_case("uniform");
    if homogeneous {
        return Ok(vec![DeviceProfile::homogeneous(fallback); devices]);
    }
    let classes: Vec<LinkClass> = spec
        .split('/')
        .map(|part| LinkClass::parse(part.trim()))
        .collect::<Result<_>>()?;
    if classes.is_empty() {
        bail!("empty profile spec");
    }
    Ok((0..devices)
        .map(|d| {
            let class = classes[d % classes.len()];
            DeviceProfile {
                class: Some(class),
                link: class.link_config(fallback.jitter),
                compute_mult: class.compute_mult(),
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_spec_is_homogeneous_fallback() {
        let base = LinkConfig {
            uplink_bps: 42e6,
            downlink_bps: 7e6,
            latency_s: 0.001,
            jitter: 0.2,
        };
        for spec in ["config", "", "  ", "uniform"] {
            let ps = assign_profiles(spec, 3, base).unwrap();
            assert_eq!(ps.len(), 3);
            for p in &ps {
                assert!(p.class.is_none());
                assert_eq!(p.link.uplink_bps, 42e6);
                assert_eq!(p.compute_mult, 1.0);
            }
        }
    }

    #[test]
    fn single_class_applies_to_all() {
        let ps = assign_profiles("lte", 4, LinkConfig::default()).unwrap();
        for p in &ps {
            assert_eq!(p.class, Some(LinkClass::Lte));
            assert_eq!(p.link.uplink_bps, 10e6);
            assert_eq!(p.compute_mult, 2.0);
        }
    }

    #[test]
    fn mixes_round_robin() {
        let ps = assign_profiles("wifi/lte", 5, LinkConfig::default()).unwrap();
        let classes: Vec<_> = ps.iter().map(|p| p.class.unwrap()).collect();
        assert_eq!(
            classes,
            vec![
                LinkClass::Wifi,
                LinkClass::Lte,
                LinkClass::Wifi,
                LinkClass::Lte,
                LinkClass::Wifi
            ]
        );
    }

    #[test]
    fn presets_inherit_config_jitter() {
        let base = LinkConfig {
            jitter: 0.15,
            ..Default::default()
        };
        let ps = assign_profiles("ethernet/5g", 2, base).unwrap();
        assert_eq!(ps[0].link.jitter, 0.15);
        assert_eq!(ps[1].link.jitter, 0.15);
        // but bandwidth/latency are the class's, not the fallback's
        assert_eq!(ps[0].link.uplink_bps, 1e9);
        assert_eq!(ps[1].link.downlink_bps, 400e6);
    }

    #[test]
    fn unknown_class_rejected() {
        assert!(assign_profiles("wifi/bogus", 2, LinkConfig::default()).is_err());
        assert!(LinkClass::parse("dialup").is_err());
    }

    #[test]
    fn class_names_roundtrip() {
        for c in [
            LinkClass::Ethernet,
            LinkClass::FiveG,
            LinkClass::Wifi,
            LinkClass::Lte,
        ] {
            assert_eq!(LinkClass::parse(c.name()).unwrap(), c);
        }
    }
}
