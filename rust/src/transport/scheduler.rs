//! Round schedulers: barriered lockstep and event-driven async, behind one
//! [`RoundScheduler`] trait.
//!
//! A scheduler decides *when* device work happens inside one communication
//! round — it never touches model state itself. The training side exposes
//! a narrow [`RoundOps`] interface (the trainer implements it over its
//! device table and executor); the scheduler drives that interface through
//! the deterministic [`EventQueue`].
//!
//! * [`SyncEventScheduler`] — the classic lockstep round re-expressed as
//!   events: every local step is fan-out over all devices, a barrier
//!   (every uplink must land), server steps in **device-id order**, then
//!   fan-in over all devices. The event queue supplies the timing
//!   (barrier time = last arrival), and because the op sequence is
//!   identical to the pre-transport engine, results are bit-identical to
//!   it.
//! * [`AsyncEventScheduler`] — the server consumes uplinks **as they
//!   land** (event order, i.e. simulated arrival time with deterministic
//!   seq tie-breaking), devices pipeline their local steps independently,
//!   and a [`StragglerPolicy`] decides when the round closes and which
//!   devices get dropped.
//!
//! # Determinism contract
//!
//! Everything a scheduler decides — server processing order, batch
//! composition, straggler drops, round close time — derives from the
//! `(time, seq)` event order, which is a pure function of the experiment
//! seed and configuration. Worker counts and thread scheduling never
//! enter: device-local work dispatched in batches goes through the
//! engine's sharded pool, whose bit-transparency is established
//! separately (`coordinator::engine`). The `parallel_determinism`
//! integration test pins this end to end for both schedulers.
//!
//! The compute model: each fan-out and each fan-in on device `d` costs
//! `compute_s(d)` simulated seconds (the config's `base_compute_s` × the
//! device profile's multiplier). Server processing occupies a serial
//! busy resource for `server_service_s` per batch
//! ([`super::event::ServerResource`]; `0` = the historical instantaneous
//! server, and the resource is **fresh every round** — see the
//! round-boundary semantics on that type). Uplink transfer times come
//! either from the private link cost model ([`super::link`]) or, in
//! `uplink = "shared"` mode, from the fair-share fluid model
//! ([`super::link::SharedUplink`]) that both schedulers drive through
//! `UplinkStart`/`SharedDrain` events. In `downlink = "shared"` mode the
//! server's egress is a second instance of the same fluid model, driven
//! through the mirror-image `DownlinkStart`/`DownDrain` events.
//!
//! # Fleet scale: cohort-compressed rounds
//!
//! At 1M devices the per-device event queue and the per-round `Vec`
//! churn dominate. When `RoundOps::cohorts() > 0` and both pipes are
//! private, the schedulers switch to cohort-compressed control flow that
//! is **bit-identical** to the per-device path:
//!
//! * sync rounds drop the heap entirely — the barrier is a running
//!   `max` over arrival times (max over finite non-negative f64 is
//!   order-independent), and the server phase already runs in device-id
//!   order;
//! * async rounds group same-instant events: instead of one heap entry
//!   per device, the queue carries one [`Event::UplinkBatch`] /
//!   [`Event::DownlinkBatch`] / [`Event::DoneBatch`] entry per *distinct
//!   arrival instant* within a submission batch, with members parked in a
//!   round arena in push order. Replaying a group's members in push order
//!   reproduces the per-device pop sequence exactly: same-time per-device
//!   pushes within one submission batch are consecutive in seq, so no
//!   foreign event can interleave between them. A homogeneous fleet of a
//!   million devices therefore costs O(cohorts) heap traffic per step.
//!
//! Both schedulers keep their working state in round-persistent scratch
//! buffers (behind a `Mutex`, since `run_round` takes `&self`), so the
//! steady-state round performs no heap allocation — pinned by
//! `tests/compute_zero_alloc.rs`.

use super::event::{DeviceId, Event, EventQueue, ServerResource};
use super::fault::FaultPlan;
use super::link::SharedUplink;
use super::policy::StragglerPolicy;
use anyhow::{bail, Result};
use std::sync::Mutex;

/// Which round scheduler to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Barriered lockstep phases (the default; pre-transport behavior).
    Sync,
    /// Event-driven: server consumes uplinks as they land.
    Async,
}

impl SchedulerKind {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sync" | "barrier" | "lockstep" => SchedulerKind::Sync,
            "async" | "event" | "event-driven" => SchedulerKind::Async,
            other => bail!("unknown scheduler '{other}' (sync | async)"),
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Sync => "sync",
            SchedulerKind::Async => "async",
        }
    }
}

/// What one server step produced (returned by [`RoundOps::server_step`]).
#[derive(Debug, Clone, Copy)]
pub struct ServerOut {
    /// Simulated seconds the downlink transfer took (private mode; `0.0`
    /// in `downlink = "shared"` mode, where the fair-share model decides).
    pub downlink_s: f64,
    /// Exact wire bytes of the gradient payload (drives the shared
    /// downlink pipe; informational in private mode).
    pub wire_bytes: usize,
    /// Batch loss.
    pub loss: f64,
    /// Correct predictions in the batch.
    pub correct: u64,
    /// Samples in the batch.
    pub samples: u64,
}

/// What one fan-out produced for one device: the payload's exact wire
/// size plus, in private-uplink mode, the already-charged transfer cost.
#[derive(Debug, Clone, Copy)]
pub struct UplinkMsg {
    /// Exact wire bytes of the compressed payload.
    pub wire_bytes: usize,
    /// Private-mode transfer seconds (latency + serialization + jitter),
    /// charged to the device link inside `fanout`. `0.0` in shared-uplink
    /// mode, where the fair-share model decides the duration and the
    /// scheduler charges it via [`RoundOps::charge_uplink`].
    pub cost_s: f64,
}

/// The training-side operations a scheduler drives. Implemented by the
/// trainer; all methods are device-local except `server_step`, which
/// mutates shared server state and must be called serially (schedulers
/// guarantee that).
///
/// The contention-model accessors (`server_service_s`,
/// `shared_uplink_bps`, `shared_downlink_bps`, latency and charge hooks)
/// default to the pre-contention behavior — instantaneous server, private
/// links — and `cohorts` defaults to the per-device control flow, so
/// simple implementations (mocks, sequential mode) need not override
/// them.
pub trait RoundOps {
    /// Number of devices in the round.
    fn n_devices(&self) -> usize;

    /// Local steps each device runs per round (`batches_per_round`).
    fn steps(&self) -> usize;

    /// Simulated client compute seconds for one fan-out *or* one fan-in
    /// phase on `dev` (profile-scaled).
    fn compute_s(&self, dev: DeviceId) -> f64;

    /// Simulated seconds one server batch occupies the server resource
    /// (`server_service_s`; `0` = infinitely fast server).
    fn server_service_s(&self) -> f64 {
        0.0
    }

    /// `Some(capacity_bps)` when all uplinks contend for one shared pipe
    /// (`uplink = "shared"`); `None` for private per-device uplinks.
    fn shared_uplink_bps(&self) -> Option<f64> {
        None
    }

    /// `Some(capacity_bps)` when all downlinks contend for one shared
    /// server-egress pipe (`downlink = "shared"`); `None` for private
    /// per-device downlinks.
    fn shared_downlink_bps(&self) -> Option<f64> {
        None
    }

    /// Per-flow propagation latency for `dev`'s uplink in shared mode
    /// (private mode folds latency into the `fanout` cost).
    fn uplink_latency_s(&self, _dev: DeviceId) -> f64 {
        0.0
    }

    /// Per-flow propagation latency for `dev`'s downlink in shared mode
    /// (private mode folds latency into the `server_step` cost).
    fn downlink_latency_s(&self, _dev: DeviceId) -> f64 {
        0.0
    }

    /// Shared-mode accounting hook: record a drained flow's occupancy
    /// seconds against `dev`'s link. (Bytes are charged at fan-out time,
    /// charge-at-send, exactly like the private path — so a flow the
    /// deadline abandons mid-pipe still counts its transmitted bytes.)
    fn charge_uplink(&mut self, _dev: DeviceId, _busy_s: f64) {}

    /// Shared-downlink accounting hook — the egress twin of
    /// [`RoundOps::charge_uplink`], with the same charge-at-send byte
    /// convention (bytes land in `server_step`, occupancy lands here).
    fn charge_downlink(&mut self, _dev: DeviceId, _busy_s: f64) {}

    /// Cohort count for cohort-compressed control flow; `0` (the
    /// default) keeps the per-device event path. Any `> 0` value is
    /// *exact* — it only sizes the same-instant grouping table, so
    /// heterogeneous fleets merely group less.
    fn cohorts(&self) -> usize {
        0
    }

    /// Client forward + codec encode (+ uplink charge in private mode)
    /// for each listed device (the implementation may fan work across its
    /// thread pool). Clears `out` and fills it with each device's
    /// [`UplinkMsg`], in `devs` order — the buffer is round-persistent
    /// scheduler scratch, so steady-state rounds allocate nothing.
    fn fanout(&mut self, devs: &[DeviceId], out: &mut Vec<UplinkMsg>) -> Result<()>;

    /// Server decode + train step + downlink charge for one device's
    /// pending uplink.
    fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut>;

    /// Gradient decode + client backward for each listed device.
    fn fanin(&mut self, devs: &[DeviceId]) -> Result<()>;

    /// Straggler drop: discard any in-flight state for `dev` so the next
    /// round starts clean.
    fn cancel(&mut self, dev: DeviceId);

    /// Fault plan for this round; `None` (the default) disables the fault
    /// layer entirely — schedulers take their legacy paths, draw-free and
    /// bit-identical to pre-fault behavior.
    fn fault_plan(&self) -> Option<FaultPlan> {
        None
    }

    /// Fault hook: the transport detected (checksum model) that copy
    /// `attempt` of `dev`'s uplink for `step` arrived corrupted.
    /// Implementations flip seeded bits in the stored payload, exercise
    /// their decode path fail-closed, and restore the clean copy for the
    /// retransmission the scheduler is about to arm. Default: nothing
    /// (timing-only mocks have no payload to corrupt).
    fn corrupt_uplink(&mut self, _dev: DeviceId, _step: usize, _attempt: u32) {}

    /// Fault hook: account one retransmitted uplink copy (`bytes` on the
    /// wire, `busy_s` link occupancy) against `dev` — charge-at-send,
    /// exactly like the original copy charged in `fanout`.
    fn charge_retransmit_uplink(&mut self, _dev: DeviceId, _bytes: usize, _busy_s: f64) {}

    /// Fault hook: account one retransmitted downlink copy against `dev`
    /// — the egress twin of [`RoundOps::charge_retransmit_uplink`].
    fn charge_retransmit_downlink(&mut self, _dev: DeviceId, _bytes: usize, _busy_s: f64) {}

    /// Server step that converts a decode failure on `dev`'s pending
    /// payload into [`ServerStep::Corrupt`] instead of an `Err`. The
    /// default wraps [`RoundOps::server_step`] (any success is served) —
    /// trainers with a real decode path override it so one corrupt
    /// payload fails only its own device, never the round.
    fn server_step_checked(&mut self, dev: DeviceId) -> Result<ServerStep> {
        Ok(ServerStep::Served(self.server_step(dev)?))
    }
}

/// Result of a checked server step ([`RoundOps::server_step_checked`]).
#[derive(Debug, Clone, Copy)]
pub enum ServerStep {
    /// The uplink decoded and the server trained on it.
    Served(ServerOut),
    /// The pending payload failed to decode (corruption the transport
    /// checksum missed). Fail-closed: the device drops out of the round;
    /// no other device and no shared server state is affected.
    Corrupt,
}

/// What one round produced, scheduler-agnostic. Per-device outcomes are
/// not materialized here (a million-device round would pay O(devices) for
/// a report) — completion is a running count, and the trainer learns the
/// identity of dropped devices through [`RoundOps::cancel`].
#[derive(Debug, Clone, Copy)]
pub struct RoundReport {
    /// Sum of batch losses over executed server steps (event order).
    pub loss_sum: f64,
    /// Correct predictions over executed server steps.
    pub correct: u64,
    /// Samples over executed server steps.
    pub samples: u64,
    /// Server steps actually executed (dropped uplinks never run).
    pub server_steps: u64,
    /// Event-clock duration of the round (compute + transfers + queueing;
    /// for deadline rounds, capped at the deadline).
    pub sim_round_s: f64,
    /// Total simulated seconds uplinks spent queued for the server busy
    /// resource this round (summed over executed server steps; `0` when
    /// `server_service_s = 0`).
    pub queue_wait_s: f64,
    /// Devices that entered the round.
    pub n_devices: usize,
    /// Devices that finished all their steps and participate in this
    /// round's aggregation. Every other device received a
    /// [`RoundOps::cancel`].
    pub completed: usize,
    /// Message copies retransmitted after loss, corruption, or an ack
    /// timeout (fault injection; `0` in fault-free rounds).
    pub retransmits: u64,
    /// Wire bytes of message copies lost in flight (fault injection).
    pub lost_bytes: u64,
    /// Uplink payloads that arrived corrupted (fault injection; includes
    /// decode failures the fail-closed server path converted to drops).
    pub corrupt_payloads: u64,
    /// Simulated seconds batches spent paused on a server outage window
    /// before service resumed (fault injection).
    pub recovery_wait_s: f64,
}

impl RoundReport {
    /// Devices dropped by the straggler policy this round.
    pub fn dropped(&self) -> usize {
        self.n_devices - self.completed
    }

    /// All-zero report — the functional-update base (`..RoundReport::zeroed()`)
    /// for construction sites that leave the fault counters at rest.
    pub fn zeroed() -> RoundReport {
        RoundReport {
            loss_sum: 0.0,
            correct: 0,
            samples: 0,
            server_steps: 0,
            sim_round_s: 0.0,
            queue_wait_s: 0.0,
            n_devices: 0,
            completed: 0,
            retransmits: 0,
            lost_bytes: 0,
            corrupt_payloads: 0,
            recovery_wait_s: 0.0,
        }
    }
}

/// One communication round's control flow.
pub trait RoundScheduler: Send + Sync {
    /// Stable display name.
    fn name(&self) -> &'static str;

    /// Drive one round over `ops`.
    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport>;
}

/// Build the configured scheduler. Sync ignores the policy (it is
/// inherently wait-all; the config layer rejects other combinations).
pub fn build_scheduler(kind: SchedulerKind, policy: StragglerPolicy) -> Box<dyn RoundScheduler> {
    match kind {
        SchedulerKind::Sync => Box::new(SyncEventScheduler::new()),
        SchedulerKind::Async => Box::new(AsyncEventScheduler::new(policy)),
    }
}

/// Push one device's uplink into the round's timeline: private mode
/// schedules the arrival directly (cost already known); shared mode
/// schedules a flow start for the fair-share pipe.
fn submit_uplink(
    q: &mut EventQueue,
    shared: bool,
    start_t: f64,
    dev: DeviceId,
    step: usize,
    msg: &UplinkMsg,
) {
    if shared {
        q.push(
            start_t,
            dev,
            Event::UplinkStart {
                step,
                bytes: msg.wire_bytes,
            },
        );
    } else {
        q.push(start_t + msg.cost_s, dev, Event::UplinkArrived { step });
    }
}

/// Round-persistent working state for the fault-injection paths. Left
/// empty (no allocation) unless a round actually runs with an active
/// [`FaultPlan`] — the zero-overhead guarantee the counting-allocator
/// test pins for fault-free rounds.
#[derive(Default)]
struct FaultScratch {
    /// Retransmission attempt of the in-flight uplink copy, per device.
    up_attempt: Vec<u32>,
    /// Retransmission attempt of the in-flight downlink copy, per device.
    down_attempt: Vec<u32>,
    /// Last fanned-out uplink message, per device (retransmissions reuse
    /// its cost and byte count — same payload, same link state).
    up_msg: Vec<UplinkMsg>,
    /// Last served downlink `(cost_s, wire_bytes)`, per device.
    down_msg: Vec<(f64, usize)>,
    /// Devices out of the round (crashed, retries exhausted, or decode
    /// failure) — they take no further part and are cancelled.
    failed: Vec<bool>,
    /// Devices still in the round (rebuilt per phase).
    alive: Vec<DeviceId>,
    /// Valid-arrival order of the sync barrier (the server drains its
    /// receive queue in this order — the same `(time, seq)` order the
    /// async scheduler serves in).
    order: Vec<DeviceId>,
    /// Fan-in list (served devices that also received their gradient).
    fan: Vec<DeviceId>,
    /// Retransmitted copies this round.
    retransmits: u64,
    /// Wire bytes of copies lost in flight this round.
    lost_bytes: u64,
    /// Corrupted uplink deliveries this round.
    corrupt_payloads: u64,
}

impl FaultScratch {
    /// Size for `n` devices and zero the round counters.
    fn begin_round(&mut self, n: usize) {
        self.up_attempt.clear();
        self.up_attempt.resize(n, 0);
        self.down_attempt.clear();
        self.down_attempt.resize(n, 0);
        self.up_msg.clear();
        self.up_msg.resize(
            n,
            UplinkMsg {
                wire_bytes: 0,
                cost_s: 0.0,
            },
        );
        self.down_msg.clear();
        self.down_msg.resize(n, (0.0, 0));
        self.failed.clear();
        self.failed.resize(n, false);
        self.alive.clear();
        self.order.clear();
        self.fan.clear();
        self.retransmits = 0;
        self.lost_bytes = 0;
        self.corrupt_payloads = 0;
    }
}

/// Submit the current uplink copy of `(dev, step)` under the fault plan:
/// a lost copy arms a deterministic ack-timeout [`Event::UplinkRetry`]
/// at `send_t + backoff` instead of an arrival. The loss verdict is a
/// pure function of `(dev, step, attempt)` — never of queue state.
fn submit_uplink_faulty(
    q: &mut EventQueue,
    plan: &FaultPlan,
    fs: &mut FaultScratch,
    send_t: f64,
    dev: DeviceId,
    step: usize,
) {
    let attempt = fs.up_attempt[dev];
    let msg = fs.up_msg[dev];
    if plan.uplink_lost(dev, step, attempt) {
        fs.lost_bytes += msg.wire_bytes as u64;
        q.push(
            send_t + plan.backoff_s(dev, step, attempt),
            dev,
            Event::UplinkRetry { step },
        );
    } else {
        q.push(send_t + msg.cost_s, dev, Event::UplinkArrived { step });
    }
}

/// Submit the current downlink copy of `(dev, step)` under the fault
/// plan — the egress twin of [`submit_uplink_faulty`].
fn submit_downlink_faulty(
    q: &mut EventQueue,
    plan: &FaultPlan,
    fs: &mut FaultScratch,
    send_t: f64,
    dev: DeviceId,
    step: usize,
) {
    let attempt = fs.down_attempt[dev];
    let (cost_s, bytes) = fs.down_msg[dev];
    if plan.downlink_lost(dev, step, attempt) {
        fs.lost_bytes += bytes as u64;
        q.push(
            send_t + plan.backoff_s(dev, step, attempt),
            dev,
            Event::DownlinkRetry { step },
        );
    } else {
        q.push(send_t + cost_s, dev, Event::DownlinkArrived { step });
    }
}

/// Handle a popped [`Event::UplinkRetry`]: with retries left, charge and
/// resubmit the copy (returns `false`); with retries exhausted, return
/// `true` — the caller fails the device into the straggler-drop path.
fn handle_uplink_retry(
    q: &mut EventQueue,
    plan: &FaultPlan,
    fs: &mut FaultScratch,
    ops: &mut dyn RoundOps,
    t: f64,
    dev: DeviceId,
    step: usize,
) -> bool {
    if fs.up_attempt[dev] >= plan.max_retries() {
        return true;
    }
    fs.up_attempt[dev] += 1;
    fs.retransmits += 1;
    let msg = fs.up_msg[dev];
    ops.charge_retransmit_uplink(dev, msg.wire_bytes, msg.cost_s);
    submit_uplink_faulty(q, plan, fs, t, dev, step);
    false
}

/// Handle a popped [`Event::DownlinkRetry`] — the egress twin of
/// [`handle_uplink_retry`].
fn handle_downlink_retry(
    q: &mut EventQueue,
    plan: &FaultPlan,
    fs: &mut FaultScratch,
    ops: &mut dyn RoundOps,
    t: f64,
    dev: DeviceId,
    step: usize,
) -> bool {
    if fs.down_attempt[dev] >= plan.max_retries() {
        return true;
    }
    fs.down_attempt[dev] += 1;
    fs.retransmits += 1;
    let (cost_s, bytes) = fs.down_msg[dev];
    ops.charge_retransmit_downlink(dev, bytes, cost_s);
    submit_downlink_faulty(q, plan, fs, t, dev, step);
    false
}

/// On an uplink arrival, apply the corruption verdict: a corrupted copy
/// is counted, injected into the trainer's stored payload
/// ([`RoundOps::corrupt_uplink`] — which exercises the decode path
/// fail-closed and restores the clean copy), and a NACK-driven
/// retransmission is armed. Returns `true` when the arrival was consumed
/// as corrupt.
fn arrival_corrupt(
    q: &mut EventQueue,
    plan: &FaultPlan,
    fs: &mut FaultScratch,
    ops: &mut dyn RoundOps,
    t: f64,
    dev: DeviceId,
    step: usize,
) -> bool {
    let attempt = fs.up_attempt[dev];
    if plan.uplink_corrupt(dev, step, attempt) {
        fs.corrupt_payloads += 1;
        ops.corrupt_uplink(dev, step, attempt);
        q.push(
            t + plan.backoff_s(dev, step, attempt),
            dev,
            Event::UplinkRetry { step },
        );
        true
    } else {
        false
    }
}

/// Drive the shared-uplink fluid model for one popped event. Returns
/// `true` when the event belonged to the pipe (flow start or drain
/// prediction) and was consumed; delivery is re-entered into the queue as
/// a plain [`Event::UplinkArrived`], so scheduler control flow only ever
/// reacts to arrivals.
///
/// The device id on a rescheduled [`Event::SharedDrain`] is the device
/// that triggered the recompute — the flow actually draining is resolved
/// inside [`SharedUplink::complete`], deterministically.
fn pipe_event(
    pipe: &mut SharedUplink,
    q: &mut EventQueue,
    ops: &mut dyn RoundOps,
    ev: &super::event::Scheduled,
) -> bool {
    match ev.event {
        Event::UplinkStart { step, bytes } => {
            let (t_drain, gen) =
                pipe.start(ev.time, ev.device, step, bytes, ops.uplink_latency_s(ev.device));
            q.push(t_drain, ev.device, Event::SharedDrain { generation: gen });
            true
        }
        Event::SharedDrain { generation } => {
            if let Some((done, next)) = pipe.complete(generation) {
                ops.charge_uplink(done.device, done.busy_s);
                q.push(done.arrival_t, done.device, Event::UplinkArrived { step: done.step });
                if let Some((t_next, gen)) = next {
                    q.push(t_next, done.device, Event::SharedDrain { generation: gen });
                }
            }
            true
        }
        _ => false,
    }
}

/// Drive the shared-*downlink* fluid model for one popped event — the
/// server-egress mirror of [`pipe_event`], reusing [`SharedUplink`] (the
/// fluid model is direction-agnostic). Delivery re-enters the queue as a
/// plain [`Event::DownlinkArrived`].
fn down_pipe_event(
    pipe: &mut SharedUplink,
    q: &mut EventQueue,
    ops: &mut dyn RoundOps,
    ev: &super::event::Scheduled,
) -> bool {
    match ev.event {
        Event::DownlinkStart { step, bytes } => {
            let (t_drain, gen) =
                pipe.start(ev.time, ev.device, step, bytes, ops.downlink_latency_s(ev.device));
            q.push(t_drain, ev.device, Event::DownDrain { generation: gen });
            true
        }
        Event::DownDrain { generation } => {
            if let Some((done, next)) = pipe.complete(generation) {
                ops.charge_downlink(done.device, done.busy_s);
                q.push(done.arrival_t, done.device, Event::DownlinkArrived { step: done.step });
                if let Some((t_next, gen)) = next {
                    q.push(t_next, done.device, Event::DownDrain { generation: gen });
                }
            }
            true
        }
        _ => false,
    }
}

/// Bounded distinct-time table for same-instant event grouping. One
/// segment of a submission batch is scattered into per-group arena runs
/// via counting sort (counts → prefix offsets → cursor scatter), which
/// preserves submission order within each group — the property the
/// bit-identity argument rests on.
#[derive(Debug, Default)]
struct GroupTable {
    /// Distinct arrival-time bits, in first-occurrence order.
    times: Vec<u64>,
    /// Per-group member count.
    len: Vec<u32>,
    /// Per-group arena start offset.
    off: Vec<u32>,
    /// Per-group scatter cursor.
    cur: Vec<u32>,
    /// Per-member group index for the current segment.
    gidx: Vec<u32>,
}

/// Group `members` (parallel to `times`) by exact arrival instant
/// (`f64::to_bits`) and push one event per distinct instant, members
/// parked in `arena[off .. off + len]` in submission order. The table is
/// bounded at `cap` distinct instants; when a batch holds more, it is
/// flushed in segments — two groups at the same instant then pop in push
/// order, which is exactly the per-device order, so segmentation never
/// breaks bit-identity (it only groups less).
fn submit_grouped(
    q: &mut EventQueue,
    arena: &mut Vec<(DeviceId, u32)>,
    tbl: &mut GroupTable,
    members: &[(DeviceId, u32)],
    times: &[f64],
    cap: usize,
    mk: impl Fn(u32, u32) -> Event,
) {
    debug_assert_eq!(members.len(), times.len());
    let mut seg = 0usize;
    while seg < members.len() {
        tbl.times.clear();
        tbl.gidx.clear();
        let mut i = seg;
        while i < members.len() {
            let bits = times[i].to_bits();
            // linear probe: the table never exceeds `cap` entries
            let g = match tbl.times.iter().position(|&t| t == bits) {
                Some(g) => g,
                None if tbl.times.len() == cap => break, // flush segment
                None => {
                    tbl.times.push(bits);
                    tbl.times.len() - 1
                }
            };
            tbl.gidx.push(g as u32);
            i += 1;
        }
        let seg_end = i;
        tbl.len.clear();
        tbl.len.resize(tbl.times.len(), 0);
        for &g in &tbl.gidx {
            tbl.len[g as usize] += 1;
        }
        let base = arena.len();
        assert!(
            base + (seg_end - seg) <= u32::MAX as usize,
            "round arena overflow: more than u32::MAX grouped events in one round"
        );
        tbl.off.clear();
        tbl.cur.clear();
        let mut off = base as u32;
        for &l in &tbl.len {
            tbl.off.push(off);
            tbl.cur.push(off);
            off += l;
        }
        arena.resize(base + (seg_end - seg), (0, 0));
        for (k, &g) in tbl.gidx.iter().enumerate() {
            let slot = tbl.cur[g as usize] as usize;
            arena[slot] = members[seg + k];
            tbl.cur[g as usize] += 1;
        }
        for ((&off, &len), &bits) in tbl.off.iter().zip(tbl.len.iter()).zip(tbl.times.iter()) {
            // the group's device is its first member's — only used for
            // event provenance; handlers fan over the arena run
            q.push(f64::from_bits(bits), arena[off as usize].0, mk(off, len));
        }
        seg = seg_end;
    }
}

/// Round-persistent scratch for the sync scheduler (see the module-level
/// "Fleet scale" notes).
#[derive(Default)]
struct SyncScratch {
    q: EventQueue,
    all: Vec<DeviceId>,
    ups: Vec<UplinkMsg>,
    fault: FaultScratch,
}

/// Lockstep phases on the event queue — bit-identical op sequence to the
/// pre-transport engine (fan-out all → server in device-id order → fan-in
/// all, per local step) when the contention model is off
/// (`uplink = private`, `server_service_s = 0`). With
/// `RoundOps::cohorts() > 0` and both pipes private, the round runs a
/// heap-free barrier fold that is bit-identical to the event path (the
/// barrier is a max over the same arrival times; max over finite
/// non-negative f64 is order-independent).
pub struct SyncEventScheduler {
    scratch: Mutex<SyncScratch>,
}

impl SyncEventScheduler {
    /// Scheduler with empty (lazily grown) round scratch.
    pub fn new() -> Self {
        SyncEventScheduler {
            scratch: Mutex::new(SyncScratch::default()),
        }
    }
}

impl Default for SyncEventScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl RoundScheduler for SyncEventScheduler {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let mut guard = self.scratch.lock().expect("sync scheduler scratch poisoned");
        let scr = &mut *guard;
        if let Some(plan) = ops.fault_plan() {
            // Faults take the dedicated path so the legacy round below
            // stays structurally untouched (bit-identical, draw-free).
            return run_sync_faulty(scr, ops, plan);
        }
        let n = ops.n_devices();
        let steps = ops.steps();
        if scr.all.len() != n {
            scr.all.clear();
            scr.all.extend(0..n);
        }
        // Fresh server each round: busy time never leaks across round
        // boundaries (see ServerResource's round-boundary semantics).
        let mut server = ServerResource::new(ops.server_service_s());
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        let mut queue_wait_s = 0.0f64;
        let mut t = 0.0f64;

        if ops.cohorts() > 0
            && ops.shared_uplink_bps().is_none()
            && ops.shared_downlink_bps().is_none()
        {
            // Cohort fold path: no heap. Arrival and ready times use the
            // exact arithmetic of the event path, folded with max.
            for _step in 0..steps {
                ops.fanout(&scr.all, &mut scr.ups)?;
                let mut barrier_t = t;
                for d in 0..n {
                    let arrive = (t + ops.compute_s(d)) + scr.ups[d].cost_s;
                    barrier_t = barrier_t.max(arrive);
                }
                let mut step_loss = 0.0f64;
                let mut ready_t = barrier_t;
                for d in 0..n {
                    let (start, end) = server.acquire(barrier_t);
                    queue_wait_s += start - barrier_t;
                    let out = ops.server_step(d)?;
                    step_loss += out.loss;
                    correct += out.correct;
                    samples += out.samples;
                    server_steps += 1;
                    ready_t = ready_t.max((end + out.downlink_s) + ops.compute_s(d));
                }
                loss_sum += step_loss;
                ops.fanin(&scr.all)?;
                t = ready_t;
            }
            return Ok(RoundReport {
                loss_sum,
                correct,
                samples,
                server_steps,
                sim_round_s: t,
                queue_wait_s,
                n_devices: n,
                completed: n,
                ..RoundReport::zeroed()
            });
        }

        scr.q.clear();
        let mut pipe = ops.shared_uplink_bps().map(SharedUplink::new);
        let mut down_pipe = ops.shared_downlink_bps().map(SharedUplink::new);
        for step in 0..steps {
            ops.fanout(&scr.all, &mut scr.ups)?;
            for d in 0..n {
                submit_uplink(
                    &mut scr.q,
                    pipe.is_some(),
                    t + ops.compute_s(d),
                    d,
                    step,
                    &scr.ups[d],
                );
            }
            // Barrier: every uplink lands before the server phase starts.
            // The queue fixes the arrival order; lockstep mode then serves
            // in device-id order regardless (legacy semantics). Shared-pipe
            // bookkeeping events are consumed in-line.
            let mut barrier_t = t;
            let mut landed = 0usize;
            while landed < n {
                let ev = scr.q.pop().expect("uplinks still in flight");
                if let Some(p) = pipe.as_mut() {
                    if pipe_event(p, &mut scr.q, ops, &ev) {
                        continue;
                    }
                }
                debug_assert!(matches!(ev.event, Event::UplinkArrived { .. }));
                barrier_t = barrier_t.max(ev.time);
                landed += 1;
            }
            // Server phase: device-id order; uplinks all became ready at
            // the barrier and queue for the serial server resource.
            // per-step partial sum, folded into the round total afterwards —
            // the exact f64 fold order of the pre-transport engine, so
            // reported losses stay bit-identical to it
            let mut step_loss = 0.0f64;
            for d in 0..n {
                let (start, end) = server.acquire(barrier_t);
                queue_wait_s += start - barrier_t;
                let out = ops.server_step(d)?;
                step_loss += out.loss;
                correct += out.correct;
                samples += out.samples;
                server_steps += 1;
                if down_pipe.is_some() {
                    scr.q.push(end, d, Event::DownlinkStart { step, bytes: out.wire_bytes });
                } else {
                    scr.q.push(end + out.downlink_s, d, Event::DownlinkArrived { step });
                }
            }
            loss_sum += step_loss;
            // Step ends when the slowest device has its gradient applied.
            // (Only downlinks count: a stale shared-drain prediction may
            // still be queued at the same instant as the last arrival.)
            let mut ready_t = barrier_t;
            while let Some(ev) = scr.q.pop() {
                if let Some(p) = down_pipe.as_mut() {
                    if down_pipe_event(p, &mut scr.q, ops, &ev) {
                        continue;
                    }
                }
                if matches!(ev.event, Event::DownlinkArrived { .. }) {
                    ready_t = ready_t.max(ev.time + ops.compute_s(ev.device));
                }
            }
            ops.fanin(&scr.all)?;
            t = ready_t;
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: t,
            queue_wait_s,
            n_devices: n,
            completed: n,
            ..RoundReport::zeroed()
        })
    }
}

/// The sync round under an active [`FaultPlan`]: lockstep phases with
/// per-message loss/corruption, retry backoff, per-round crashes, and a
/// server outage window. Runs the per-device event path regardless of
/// `cohorts()` (faults make arrival instants device-specific, so there is
/// nothing to group — the same fallback shared pipes already take).
///
/// Semantics deltas from the fault-free sync round, all confined to this
/// function:
/// * crashed devices are excluded before the first fan-out (no compute,
///   no bytes) and cancelled at round end — FedAvg rejoins them at zero
///   weight next round, like any straggler drop;
/// * the barrier waits for one **valid** uplink copy per live device
///   (corrupted copies are NACKed and retransmitted; exhausted retries
///   fail the device into the straggler-drop path);
/// * the server phase serves in barrier **arrival order** — the exact
///   `(time, seq)` order the async scheduler serves in, so faulty sync
///   and async rounds fold losses identically;
/// * downlinks are lossy too, retransmitted from the server with the
///   same backoff schedule.
fn run_sync_faulty(
    scr: &mut SyncScratch,
    ops: &mut dyn RoundOps,
    plan: FaultPlan,
) -> Result<RoundReport> {
    let n = ops.n_devices();
    let steps = ops.steps();
    let fs = &mut scr.fault;
    fs.begin_round(n);
    let mut server = ServerResource::new(ops.server_service_s());
    server.set_outage(plan.outage_window());
    let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
    let mut queue_wait_s = 0.0f64;
    let mut t = 0.0f64;
    scr.q.clear();
    for d in 0..n {
        if plan.device_crashed(d) {
            fs.failed[d] = true;
        }
    }
    for step in 0..steps {
        fs.alive.clear();
        fs.alive.extend((0..n).filter(|&d| !fs.failed[d]));
        if fs.alive.is_empty() {
            break;
        }
        ops.fanout(&fs.alive, &mut scr.ups)?;
        for i in 0..fs.alive.len() {
            let d = fs.alive[i];
            fs.up_msg[d] = scr.ups[i];
            fs.up_attempt[d] = 0;
            submit_uplink_faulty(&mut scr.q, &plan, fs, t + ops.compute_s(d), d, step);
        }
        // Barrier: one valid arrival — or retry exhaustion — per device.
        let mut barrier_t = t;
        let mut landed = 0usize;
        let expected = fs.alive.len();
        fs.order.clear();
        while landed < expected {
            let ev = scr.q.pop().expect("uplinks still in flight");
            match ev.event {
                Event::UplinkArrived { step: s } => {
                    if arrival_corrupt(&mut scr.q, &plan, fs, ops, ev.time, ev.device, s) {
                        continue;
                    }
                    barrier_t = barrier_t.max(ev.time);
                    fs.order.push(ev.device);
                    landed += 1;
                }
                Event::UplinkRetry { step: s } => {
                    if handle_uplink_retry(&mut scr.q, &plan, fs, ops, ev.time, ev.device, s) {
                        fs.failed[ev.device] = true;
                        landed += 1;
                    }
                }
                _ => unreachable!("faulty sync barrier sees only uplink events"),
            }
        }
        // Server phase at the barrier, in arrival order. A decode failure
        // (checksum escape) fails only its own device.
        let mut step_loss = 0.0f64;
        let mut pending_down = 0usize;
        for i in 0..fs.order.len() {
            let d = fs.order[i];
            let (start, end) = server.acquire(barrier_t);
            queue_wait_s += start - barrier_t;
            match ops.server_step_checked(d)? {
                ServerStep::Served(out) => {
                    step_loss += out.loss;
                    correct += out.correct;
                    samples += out.samples;
                    server_steps += 1;
                    fs.down_msg[d] = (out.downlink_s, out.wire_bytes);
                    fs.down_attempt[d] = 0;
                    submit_downlink_faulty(&mut scr.q, &plan, fs, end, d, step);
                    pending_down += 1;
                }
                ServerStep::Corrupt => {
                    fs.corrupt_payloads += 1;
                    fs.failed[d] = true;
                }
            }
        }
        loss_sum += step_loss;
        // Drain downlinks: one arrival or exhaustion per served device.
        let mut ready_t = barrier_t;
        while pending_down > 0 {
            let ev = scr.q.pop().expect("downlinks still in flight");
            match ev.event {
                Event::DownlinkArrived { .. } => {
                    ready_t = ready_t.max(ev.time + ops.compute_s(ev.device));
                    pending_down -= 1;
                }
                Event::DownlinkRetry { step: s } => {
                    if handle_downlink_retry(&mut scr.q, &plan, fs, ops, ev.time, ev.device, s) {
                        fs.failed[ev.device] = true;
                        pending_down -= 1;
                    }
                }
                _ => unreachable!("faulty sync drain sees only downlink events"),
            }
        }
        // Fan-in over devices that actually hold a gradient, in the same
        // arrival order the server served them.
        fs.fan.clear();
        for i in 0..fs.order.len() {
            let d = fs.order[i];
            if !fs.failed[d] {
                fs.fan.push(d);
            }
        }
        if !fs.fan.is_empty() {
            ops.fanin(&fs.fan)?;
        }
        t = ready_t;
    }
    let mut completed = 0usize;
    for d in 0..n {
        if fs.failed[d] {
            ops.cancel(d);
        } else {
            completed += 1;
        }
    }
    Ok(RoundReport {
        loss_sum,
        correct,
        samples,
        server_steps,
        sim_round_s: t,
        queue_wait_s,
        n_devices: n,
        completed,
        retransmits: fs.retransmits,
        lost_bytes: fs.lost_bytes,
        corrupt_payloads: fs.corrupt_payloads,
        recovery_wait_s: server.recovery_wait_s(),
    })
}

/// Round-persistent scratch for the async scheduler: the event queue, the
/// grouped-event member arena, and every working vector a round touches.
#[derive(Default)]
struct AsyncScratch {
    q: EventQueue,
    all: Vec<DeviceId>,
    ups: Vec<UplinkMsg>,
    done_mask: Vec<bool>,
    batch: Vec<(DeviceId, usize)>,
    devs: Vec<DeviceId>,
    cont: Vec<(DeviceId, usize)>,
    cont_devs: Vec<DeviceId>,
    /// Grouped-event member arena: `(device, step)` runs addressed by the
    /// `off/len` carried on batch events. Cleared per round, capacity
    /// retained.
    arena: Vec<(DeviceId, u32)>,
    /// Members of the group currently being replayed (copied out of the
    /// arena so handlers can append new groups while iterating).
    members: Vec<(DeviceId, u32)>,
    m2: Vec<(DeviceId, u32)>,
    times: Vec<f64>,
    t2: Vec<f64>,
    tbl: GroupTable,
    fault: FaultScratch,
}

/// Event-driven rounds: devices pipeline local steps independently, the
/// server consumes uplinks in arrival order, and the straggler policy
/// closes the round. With `RoundOps::cohorts() > 0` and both pipes
/// private, rounds run on cohort-grouped events (one heap entry per
/// distinct arrival instant) — bit-identical to the per-device path.
pub struct AsyncEventScheduler {
    /// Round-close policy.
    pub policy: StragglerPolicy,
    scratch: Mutex<AsyncScratch>,
}

impl AsyncEventScheduler {
    /// Scheduler with the given round-close policy and empty scratch.
    pub fn new(policy: StragglerPolicy) -> Self {
        AsyncEventScheduler {
            policy,
            scratch: Mutex::new(AsyncScratch::default()),
        }
    }
}

impl RoundScheduler for AsyncEventScheduler {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let mut guard = self.scratch.lock().expect("async scheduler scratch poisoned");
        let scr = &mut *guard;
        let n = ops.n_devices();
        let steps = ops.steps();
        if n == 0 || steps == 0 {
            return Ok(RoundReport {
                n_devices: n,
                completed: n,
                ..RoundReport::zeroed()
            });
        }
        let deadline = match self.policy {
            StragglerPolicy::DeadlineDrop { deadline_s } => Some(deadline_s),
            _ => None,
        };
        let quorum = match self.policy {
            StragglerPolicy::Quorum { k } => Some(k),
            _ => None,
        };
        if let Some(plan) = ops.fault_plan() {
            // Faults take the dedicated path so the legacy round below
            // stays structurally untouched (bit-identical, draw-free).
            return run_async_faulty(scr, ops, plan, deadline, quorum);
        }

        if scr.all.len() != n {
            scr.all.clear();
            scr.all.extend(0..n);
        }
        scr.done_mask.clear();
        scr.done_mask.resize(n, false);
        scr.q.clear();
        // Fresh server each round (ServerResource round-boundary
        // semantics): abandoned batches never charge the next round.
        let mut server = ServerResource::new(ops.server_service_s());
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        let mut queue_wait_s = 0.0f64;
        let mut done = 0usize;
        let mut close_t: Option<f64> = None;
        let mut last_t = 0.0f64;

        let grouped = ops.cohorts() > 0
            && ops.shared_uplink_bps().is_none()
            && ops.shared_downlink_bps().is_none();

        if grouped {
            let cap = ops.cohorts().max(16);
            scr.arena.clear();
            // Kick-off: every device starts its first local step at t = 0.
            ops.fanout(&scr.all, &mut scr.ups)?;
            scr.members.clear();
            scr.times.clear();
            for d in 0..n {
                scr.members.push((d, 0u32));
                scr.times.push(ops.compute_s(d) + scr.ups[d].cost_s);
            }
            submit_grouped(
                &mut scr.q,
                &mut scr.arena,
                &mut scr.tbl,
                &scr.members,
                &scr.times,
                cap,
                |off, len| Event::UplinkBatch { off, len },
            );

            'outer: while let Some(ev) = scr.q.pop() {
                if let Some(t_max) = deadline {
                    if ev.time > t_max {
                        close_t = Some(t_max);
                        break;
                    }
                }
                last_t = ev.time;
                match ev.event {
                    Event::UplinkBatch { off, len } => {
                        scr.members.clear();
                        scr.members
                            .extend_from_slice(&scr.arena[off as usize..(off + len) as usize]);
                        scr.times.clear();
                        for &(d, _s) in scr.members.iter() {
                            let (start, end) = server.acquire(ev.time);
                            queue_wait_s += start - ev.time;
                            let out = ops.server_step(d)?;
                            loss_sum += out.loss;
                            correct += out.correct;
                            samples += out.samples;
                            server_steps += 1;
                            scr.times.push(end + out.downlink_s);
                        }
                        submit_grouped(
                            &mut scr.q,
                            &mut scr.arena,
                            &mut scr.tbl,
                            &scr.members,
                            &scr.times,
                            cap,
                            |off, len| Event::DownlinkBatch { off, len },
                        );
                    }
                    Event::DownlinkBatch { off, len } => {
                        scr.members.clear();
                        scr.members
                            .extend_from_slice(&scr.arena[off as usize..(off + len) as usize]);
                        // Merge tied downlink groups (same bit-instant)
                        // into one dispatch — group pop order is group
                        // push order, so the merged member sequence is
                        // exactly the per-device tie-batch.
                        loop {
                            let tie = matches!(
                                scr.q.peek(),
                                Some(next) if matches!(next.event, Event::DownlinkBatch { .. })
                                    && next.time.to_bits() == ev.time.to_bits()
                            );
                            if !tie {
                                break;
                            }
                            let nev = scr.q.pop().expect("peeked event");
                            let Event::DownlinkBatch { off: o2, len: l2 } = nev.event else {
                                unreachable!("tie check admits only downlink batches")
                            };
                            scr.members
                                .extend_from_slice(&scr.arena[o2 as usize..(o2 + l2) as usize]);
                        }
                        scr.devs.clear();
                        scr.devs.extend(scr.members.iter().map(|&(d, _)| d));
                        ops.fanin(&scr.devs)?;
                        // continuing members pipeline into their next step
                        scr.m2.clear();
                        scr.cont_devs.clear();
                        for &(d, s) in scr.members.iter() {
                            if (s as usize) + 1 < steps {
                                scr.m2.push((d, s + 1));
                                scr.cont_devs.push(d);
                            }
                        }
                        if !scr.m2.is_empty() {
                            ops.fanout(&scr.cont_devs, &mut scr.ups)?;
                            scr.t2.clear();
                            for (i, &(d, _s)) in scr.m2.iter().enumerate() {
                                // fan-in compute + next fan-out compute,
                                // then the private uplink
                                scr.t2
                                    .push((ev.time + 2.0 * ops.compute_s(d)) + scr.ups[i].cost_s);
                            }
                            submit_grouped(
                                &mut scr.q,
                                &mut scr.arena,
                                &mut scr.tbl,
                                &scr.m2,
                                &scr.t2,
                                cap,
                                |off, len| Event::UplinkBatch { off, len },
                            );
                        }
                        scr.m2.clear();
                        scr.t2.clear();
                        for &(d, s) in scr.members.iter() {
                            if (s as usize) + 1 == steps {
                                scr.m2.push((d, s));
                                scr.t2.push(ev.time + ops.compute_s(d));
                            }
                        }
                        if !scr.m2.is_empty() {
                            submit_grouped(
                                &mut scr.q,
                                &mut scr.arena,
                                &mut scr.tbl,
                                &scr.m2,
                                &scr.t2,
                                cap,
                                |off, len| Event::DoneBatch { off, len },
                            );
                        }
                    }
                    Event::DoneBatch { off, len } => {
                        scr.members.clear();
                        scr.members
                            .extend_from_slice(&scr.arena[off as usize..(off + len) as usize]);
                        for &(d, _s) in scr.members.iter() {
                            scr.done_mask[d] = true;
                            done += 1;
                            if let Some(k) = quorum {
                                if done >= k {
                                    // mid-group close: remaining members
                                    // stay incomplete, exactly like the
                                    // per-device tied DeviceDone events a
                                    // quorum close abandons
                                    close_t = Some(ev.time);
                                    break 'outer;
                                }
                            }
                        }
                    }
                    _ => unreachable!("cohort path schedules only batch events"),
                }
            }
        } else {
            // Per-device event path (also the only path under a shared
            // pipe, whose flow bookkeeping is inherently per-device).
            let mut pipe = ops.shared_uplink_bps().map(SharedUplink::new);
            let mut down_pipe = ops.shared_downlink_bps().map(SharedUplink::new);

            // Kick-off: every device starts its first local step at t = 0
            // (one thread-parallel fan-out batch).
            ops.fanout(&scr.all, &mut scr.ups)?;
            for d in 0..n {
                submit_uplink(&mut scr.q, pipe.is_some(), ops.compute_s(d), d, 0, &scr.ups[d]);
            }

            while let Some(ev) = scr.q.pop() {
                // A stale drain prediction is bookkeeping noise, not network
                // activity — discard it before the deadline check so a
                // long-superseded prediction cannot close a round whose real
                // events all finished in time.
                if let (Some(p), Event::SharedDrain { generation }) = (pipe.as_ref(), ev.event) {
                    if generation != p.generation() {
                        continue;
                    }
                }
                if let (Some(p), Event::DownDrain { generation }) = (down_pipe.as_ref(), ev.event)
                {
                    if generation != p.generation() {
                        continue;
                    }
                }
                if let Some(t_max) = deadline {
                    if ev.time > t_max {
                        close_t = Some(t_max);
                        break;
                    }
                }
                if let Some(p) = pipe.as_mut() {
                    if pipe_event(p, &mut scr.q, ops, &ev) {
                        continue;
                    }
                }
                if let Some(p) = down_pipe.as_mut() {
                    if down_pipe_event(p, &mut scr.q, ops, &ev) {
                        continue;
                    }
                }
                last_t = ev.time;
                match ev.event {
                    Event::UplinkArrived { step } => {
                        // The uplink queues for the serial server resource;
                        // fan-in order is arrival order, service back-to-back.
                        let (start, end) = server.acquire(ev.time);
                        queue_wait_s += start - ev.time;
                        let out = ops.server_step(ev.device)?;
                        loss_sum += out.loss;
                        correct += out.correct;
                        samples += out.samples;
                        server_steps += 1;
                        if down_pipe.is_some() {
                            scr.q.push(
                                end,
                                ev.device,
                                Event::DownlinkStart { step, bytes: out.wire_bytes },
                            );
                        } else {
                            scr.q.push(
                                end + out.downlink_s,
                                ev.device,
                                Event::DownlinkArrived { step },
                            );
                        }
                    }
                    Event::DownlinkArrived { step } => {
                        // Batch ties: downlinks landing at the bit-same instant
                        // run fan-in/fan-out through one worker-pool dispatch
                        // (homogeneous fleets stay as parallel as lockstep mode).
                        // Batch composition is event order — deterministic.
                        scr.batch.clear();
                        scr.batch.push((ev.device, step));
                        loop {
                            let tie = matches!(
                                scr.q.peek(),
                                Some(next) if matches!(next.event, Event::DownlinkArrived { .. })
                                    && next.time.to_bits() == ev.time.to_bits()
                            );
                            if !tie {
                                break;
                            }
                            let nev = scr.q.pop().expect("peeked event");
                            let Event::DownlinkArrived { step: s2 } = nev.event else {
                                unreachable!("tie check admits only downlinks")
                            };
                            scr.batch.push((nev.device, s2));
                        }
                        scr.devs.clear();
                        scr.devs.extend(scr.batch.iter().map(|&(d, _)| d));
                        ops.fanin(&scr.devs)?;
                        scr.cont.clear();
                        scr.cont
                            .extend(scr.batch.iter().filter(|&&(_, s)| s + 1 < steps).copied());
                        if !scr.cont.is_empty() {
                            scr.cont_devs.clear();
                            scr.cont_devs.extend(scr.cont.iter().map(|&(d, _)| d));
                            ops.fanout(&scr.cont_devs, &mut scr.ups)?;
                            for (i, &(d, s)) in scr.cont.iter().enumerate() {
                                // fan-in compute + next fan-out compute, then
                                // the uplink (direct arrival or shared flow)
                                submit_uplink(
                                    &mut scr.q,
                                    pipe.is_some(),
                                    ev.time + 2.0 * ops.compute_s(d),
                                    d,
                                    s + 1,
                                    &scr.ups[i],
                                );
                            }
                        }
                        for &(d, s) in scr.batch.iter() {
                            if s + 1 == steps {
                                scr.q.push(ev.time + ops.compute_s(d), d, Event::DeviceDone);
                            }
                        }
                    }
                    Event::DeviceDone => {
                        scr.done_mask[ev.device] = true;
                        done += 1;
                        if let Some(k) = quorum {
                            if done >= k {
                                close_t = Some(ev.time);
                                break;
                            }
                        }
                    }
                    Event::UplinkStart { .. }
                    | Event::SharedDrain { .. }
                    | Event::DownlinkStart { .. }
                    | Event::DownDrain { .. } => {
                        unreachable!("pipe events are consumed before dispatch")
                    }
                    Event::UplinkBatch { .. }
                    | Event::DownlinkBatch { .. }
                    | Event::DoneBatch { .. } => {
                        unreachable!("grouped events exist only on the cohort path")
                    }
                }
            }
        }
        scr.q.clear();
        for d in 0..n {
            if !scr.done_mask[d] {
                ops.cancel(d);
            }
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: close_t.unwrap_or(last_t),
            queue_wait_s,
            n_devices: n,
            completed: done,
            ..RoundReport::zeroed()
        })
    }
}

/// The async round under an active [`FaultPlan`]: the same event-driven
/// pipeline, with per-message loss/corruption, retry backoff, per-round
/// crashes, and a server outage window. Runs per-device regardless of
/// `cohorts()` (fault verdicts are per-message, so arrival instants stop
/// coinciding and there is nothing to group); fan-in/fan-out dispatch one
/// device at a time — device-local work, so results are unchanged, only
/// wall-clock batching is lost. Retry events obey the same `(time, seq)`
/// ordering as every other event, so the whole faulty round remains a
/// pure function of the seed.
fn run_async_faulty(
    scr: &mut AsyncScratch,
    ops: &mut dyn RoundOps,
    plan: FaultPlan,
    deadline: Option<f64>,
    quorum: Option<usize>,
) -> Result<RoundReport> {
    let n = ops.n_devices();
    let steps = ops.steps();
    let fs = &mut scr.fault;
    fs.begin_round(n);
    scr.done_mask.clear();
    scr.done_mask.resize(n, false);
    scr.q.clear();
    let mut server = ServerResource::new(ops.server_service_s());
    server.set_outage(plan.outage_window());
    let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
    let mut queue_wait_s = 0.0f64;
    let mut done = 0usize;
    let mut close_t: Option<f64> = None;
    let mut last_t = 0.0f64;

    for d in 0..n {
        if plan.device_crashed(d) {
            fs.failed[d] = true;
        } else {
            fs.alive.push(d);
        }
    }
    if !fs.alive.is_empty() {
        ops.fanout(&fs.alive, &mut scr.ups)?;
        for i in 0..fs.alive.len() {
            let d = fs.alive[i];
            fs.up_msg[d] = scr.ups[i];
            fs.up_attempt[d] = 0;
            submit_uplink_faulty(&mut scr.q, &plan, fs, ops.compute_s(d), d, 0);
        }
    }
    while let Some(ev) = scr.q.pop() {
        if let Some(t_max) = deadline {
            if ev.time > t_max {
                close_t = Some(t_max);
                break;
            }
        }
        last_t = ev.time;
        let d = ev.device;
        match ev.event {
            Event::UplinkArrived { step } => {
                if arrival_corrupt(&mut scr.q, &plan, fs, ops, ev.time, d, step) {
                    continue;
                }
                let (start, end) = server.acquire(ev.time);
                queue_wait_s += start - ev.time;
                match ops.server_step_checked(d)? {
                    ServerStep::Served(out) => {
                        loss_sum += out.loss;
                        correct += out.correct;
                        samples += out.samples;
                        server_steps += 1;
                        fs.down_msg[d] = (out.downlink_s, out.wire_bytes);
                        fs.down_attempt[d] = 0;
                        submit_downlink_faulty(&mut scr.q, &plan, fs, end, d, step);
                    }
                    ServerStep::Corrupt => {
                        fs.corrupt_payloads += 1;
                        fs.failed[d] = true;
                    }
                }
            }
            Event::UplinkRetry { step } => {
                if handle_uplink_retry(&mut scr.q, &plan, fs, ops, ev.time, d, step) {
                    fs.failed[d] = true;
                }
            }
            Event::DownlinkArrived { step } => {
                scr.devs.clear();
                scr.devs.push(d);
                ops.fanin(&scr.devs)?;
                if step + 1 < steps {
                    ops.fanout(&scr.devs, &mut scr.ups)?;
                    fs.up_msg[d] = scr.ups[0];
                    fs.up_attempt[d] = 0;
                    submit_uplink_faulty(
                        &mut scr.q,
                        &plan,
                        fs,
                        ev.time + 2.0 * ops.compute_s(d),
                        d,
                        step + 1,
                    );
                } else {
                    scr.q.push(ev.time + ops.compute_s(d), d, Event::DeviceDone);
                }
            }
            Event::DownlinkRetry { step } => {
                if handle_downlink_retry(&mut scr.q, &plan, fs, ops, ev.time, d, step) {
                    fs.failed[d] = true;
                }
            }
            Event::DeviceDone => {
                scr.done_mask[d] = true;
                done += 1;
                if let Some(k) = quorum {
                    if done >= k {
                        close_t = Some(ev.time);
                        break;
                    }
                }
            }
            _ => unreachable!("faulty async path schedules only per-device events"),
        }
    }
    scr.q.clear();
    for d in 0..n {
        if !scr.done_mask[d] {
            ops.cancel(d);
        }
    }
    Ok(RoundReport {
        loss_sum,
        correct,
        samples,
        server_steps,
        sim_round_s: close_t.unwrap_or(last_t),
        queue_wait_s,
        n_devices: n,
        completed: done,
        retransmits: fs.retransmits,
        lost_bytes: fs.lost_bytes,
        corrupt_payloads: fs.corrupt_payloads,
        recovery_wait_s: server.recovery_wait_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pure-timing mock: per-device compute/uplink/downlink costs, plus an
    /// op log so tests can pin exact scheduling decisions. The contention
    /// knobs (`service_s`, `shared_bps`, `shared_down_bps`, per-device
    /// `bytes`/`dbytes`/`latency`) default to the pre-contention behavior,
    /// and `n_cohorts` defaults to the per-device control flow.
    struct MockOps {
        steps: usize,
        compute: Vec<f64>,
        up_s: Vec<f64>,
        down_s: Vec<f64>,
        bytes: Vec<usize>,
        dbytes: Vec<usize>,
        latency: Vec<f64>,
        service_s: f64,
        shared_bps: Option<f64>,
        shared_down_bps: Option<f64>,
        n_cohorts: usize,
        fault: Option<FaultPlan>,
        log: Vec<String>,
        cancelled: Vec<DeviceId>,
        charges: Vec<(DeviceId, u64)>,
        down_charges: Vec<(DeviceId, u64)>,
        corrupts: Vec<(DeviceId, usize, u32)>,
        retr_charges: Vec<(&'static str, DeviceId, usize)>,
    }

    impl MockOps {
        fn uniform(n: usize, steps: usize, c: f64, up: f64, down: f64) -> Self {
            MockOps {
                steps,
                compute: vec![c; n],
                up_s: vec![up; n],
                down_s: vec![down; n],
                bytes: vec![0; n],
                dbytes: vec![0; n],
                latency: vec![0.0; n],
                service_s: 0.0,
                shared_bps: None,
                shared_down_bps: None,
                n_cohorts: 0,
                fault: None,
                log: Vec::new(),
                cancelled: Vec::new(),
                charges: Vec::new(),
                down_charges: Vec::new(),
                corrupts: Vec::new(),
                retr_charges: Vec::new(),
            }
        }

        fn server_order(&self) -> Vec<DeviceId> {
            self.log
                .iter()
                .filter_map(|l| l.strip_prefix("server:").map(|d| d.parse().unwrap()))
                .collect()
        }
    }

    impl RoundOps for MockOps {
        fn n_devices(&self) -> usize {
            self.compute.len()
        }
        fn steps(&self) -> usize {
            self.steps
        }
        fn compute_s(&self, dev: DeviceId) -> f64 {
            self.compute[dev]
        }
        fn server_service_s(&self) -> f64 {
            self.service_s
        }
        fn shared_uplink_bps(&self) -> Option<f64> {
            self.shared_bps
        }
        fn shared_downlink_bps(&self) -> Option<f64> {
            self.shared_down_bps
        }
        fn uplink_latency_s(&self, dev: DeviceId) -> f64 {
            self.latency[dev]
        }
        fn downlink_latency_s(&self, dev: DeviceId) -> f64 {
            self.latency[dev]
        }
        fn charge_uplink(&mut self, dev: DeviceId, busy_s: f64) {
            self.charges.push((dev, busy_s.to_bits()));
        }
        fn charge_downlink(&mut self, dev: DeviceId, busy_s: f64) {
            self.down_charges.push((dev, busy_s.to_bits()));
        }
        fn cohorts(&self) -> usize {
            self.n_cohorts
        }
        fn fanout(&mut self, devs: &[DeviceId], out: &mut Vec<UplinkMsg>) -> Result<()> {
            self.log.push(format!("fanout:{devs:?}"));
            out.clear();
            out.extend(devs.iter().map(|&d| UplinkMsg {
                wire_bytes: self.bytes[d],
                cost_s: self.up_s[d],
            }));
            Ok(())
        }
        fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut> {
            self.log.push(format!("server:{dev}"));
            Ok(ServerOut {
                downlink_s: self.down_s[dev],
                wire_bytes: self.dbytes[dev],
                loss: 1.0 + dev as f64,
                correct: 1,
                samples: 2,
            })
        }
        fn fanin(&mut self, devs: &[DeviceId]) -> Result<()> {
            self.log.push(format!("fanin:{devs:?}"));
            Ok(())
        }
        fn cancel(&mut self, dev: DeviceId) {
            self.cancelled.push(dev);
        }
        fn fault_plan(&self) -> Option<FaultPlan> {
            self.fault
        }
        fn corrupt_uplink(&mut self, dev: DeviceId, step: usize, attempt: u32) {
            self.corrupts.push((dev, step, attempt));
        }
        fn charge_retransmit_uplink(&mut self, dev: DeviceId, bytes: usize, _busy_s: f64) {
            self.retr_charges.push(("up", dev, bytes));
        }
        fn charge_retransmit_downlink(&mut self, dev: DeviceId, bytes: usize, _busy_s: f64) {
            self.retr_charges.push(("down", dev, bytes));
        }
    }

    #[test]
    fn scheduler_kind_parses() {
        assert_eq!(SchedulerKind::parse("sync").unwrap(), SchedulerKind::Sync);
        assert_eq!(SchedulerKind::parse("ASYNC").unwrap(), SchedulerKind::Async);
        assert!(SchedulerKind::parse("warp").is_err());
        assert_eq!(SchedulerKind::Async.name(), "async");
    }

    #[test]
    fn sync_runs_lockstep_phases_in_device_order() {
        let mut ops = MockOps::uniform(2, 2, 1.0, 2.0, 4.0);
        let report = SyncEventScheduler::new().run_round(&mut ops).unwrap();
        assert_eq!(
            ops.log,
            vec![
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
            ]
        );
        assert_eq!(report.server_steps, 4);
        assert_eq!((report.n_devices, report.completed), (2, 2));
        assert_eq!(report.dropped(), 0);
        // per step: fanout compute 1 + up 2 (barrier 3), down 4 + fanin 1
        // => 8 per step, 2 steps = 16 (integers: exact in f64)
        assert_eq!(report.sim_round_s, 16.0);
        // loss fold order: (1 + 2) per step-phase
        assert_eq!(report.loss_sum, 6.0);
    }

    #[test]
    fn sync_scratch_reuse_across_rounds_is_invisible() {
        // the same scheduler instance must give bit-identical rounds on a
        // fresh mock — round-persistent scratch (queue clock, seq counter,
        // buffers) never leaks into results
        let sched = SyncEventScheduler::new();
        let run = |sched: &SyncEventScheduler| {
            let mut ops = MockOps {
                service_s: 2.0,
                ..MockOps::uniform(3, 2, 1.0, 2.0, 4.0)
            };
            let r = sched.run_round(&mut ops).unwrap();
            (ops.log, r.sim_round_s.to_bits(), r.queue_wait_s.to_bits(), r.loss_sum.to_bits())
        };
        assert_eq!(run(&sched), run(&sched));
    }

    #[test]
    fn async_server_consumes_in_arrival_order() {
        // arrival = compute + up: dev2 lands first, then dev0, then dev1
        let mut ops = MockOps {
            up_s: vec![2.0, 5.0, 0.5],
            ..MockOps::uniform(3, 1, 1.0, 0.0, 1.0)
        };
        let report = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.server_order(), vec![2, 0, 1]);
        assert_eq!((report.n_devices, report.completed), (3, 3));
        // slowest chain: dev1 done at 1 + 5 (up) + 1 (down) + 1 (fanin) = 8
        assert_eq!(report.sim_round_s, 8.0);
        assert!(ops.cancelled.is_empty());
    }

    #[test]
    fn async_wait_all_pipeline_timing() {
        // single device, 2 steps: up@3, down@7, next up@11, down@15, done@16
        let mut ops = MockOps::uniform(1, 2, 1.0, 2.0, 4.0);
        let report = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(report.server_steps, 2);
        assert_eq!(report.sim_round_s, 16.0);
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn async_deadline_drops_unfinished_devices() {
        let mut ops = MockOps {
            compute: vec![1.0, 10.0],
            up_s: vec![1.0, 10.0],
            down_s: vec![1.0, 10.0],
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let report = AsyncEventScheduler::new(StragglerPolicy::DeadlineDrop { deadline_s: 5.0 })
            .run_round(&mut ops)
            .unwrap();
        // dev0: up@2, down@3, done@4 — inside the deadline
        // dev1: up@20 — never processed
        assert_eq!((report.n_devices, report.completed), (2, 1));
        assert_eq!(report.dropped(), 1);
        assert_eq!(report.server_steps, 1, "dropped uplink never hits the server");
        assert_eq!(ops.server_order(), vec![0]);
        assert_eq!(ops.cancelled, vec![1]);
        assert_eq!(report.sim_round_s, 5.0, "round closes at the deadline");
    }

    #[test]
    fn async_deadline_everyone_drops_when_too_tight() {
        let mut ops = MockOps::uniform(3, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler::new(StragglerPolicy::DeadlineDrop { deadline_s: 1e-6 })
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(report.completed, 0);
        assert_eq!(report.server_steps, 0);
        assert_eq!(ops.cancelled, vec![0, 1, 2]);
    }

    #[test]
    fn async_quorum_closes_on_kth_completion_with_seq_ties() {
        // identical devices: completions tie at the same instant; the
        // deterministic seq order makes devices 0 and 1 the quorum
        let mut ops = MockOps::uniform(4, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler::new(StragglerPolicy::Quorum { k: 2 })
            .run_round(&mut ops)
            .unwrap();
        assert_eq!((report.n_devices, report.completed), (4, 2));
        assert_eq!(ops.cancelled, vec![2, 3]);
        // done at fanout 1 + up 1 + down 1 + fanin 1 = 4
        assert_eq!(report.sim_round_s, 4.0);
    }

    #[test]
    fn async_quorum_equal_to_n_is_wait_all() {
        let mk = || MockOps::uniform(3, 2, 0.5, 1.0, 1.0);
        let mut a = mk();
        let ra = AsyncEventScheduler::new(StragglerPolicy::Quorum { k: 3 })
            .run_round(&mut a)
            .unwrap();
        let mut b = mk();
        let rb = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut b)
            .unwrap();
        assert_eq!(ra.completed, rb.completed);
        assert_eq!(ra.server_steps, rb.server_steps);
        assert_eq!(ra.sim_round_s.to_bits(), rb.sim_round_s.to_bits());
        assert_eq!(a.log, b.log);
    }

    #[test]
    fn async_homogeneous_ties_batch_but_keep_server_id_order() {
        // homogeneous fleet: every uplink of a step lands at the same
        // instant, so the server sees device-id order — the property that
        // makes async wait-all match sync byte-for-byte
        let mut ops = MockOps::uniform(3, 2, 1.0, 2.0, 3.0);
        let report = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.server_order(), vec![0, 1, 2, 0, 1, 2]);
        // tie-batched fan-in/fan-out: one dispatch for all three devices
        assert!(ops.log.contains(&"fanin:[0, 1, 2]".to_string()));
        assert_eq!(report.completed, 3);
    }

    #[test]
    fn async_is_deterministic_across_runs() {
        let mk = || MockOps {
            compute: vec![0.25, 1.0, 0.5, 2.0],
            up_s: vec![0.125, 0.5, 2.0, 0.0625],
            down_s: vec![0.5, 0.25, 1.0, 0.125],
            ..MockOps::uniform(4, 3, 0.0, 0.0, 0.0)
        };
        let run = |policy: StragglerPolicy| {
            let mut ops = mk();
            let r = AsyncEventScheduler::new(policy).run_round(&mut ops).unwrap();
            (
                ops.log.clone(),
                ops.cancelled.clone(),
                r.completed,
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.server_steps,
            )
        };
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 6.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            assert_eq!(run(policy), run(policy), "{}", policy.name());
        }
    }

    #[test]
    fn server_service_serializes_tied_arrivals_in_seq_order() {
        // homogeneous fleet, async: all three uplinks land at t=2 (tie),
        // seq order = device order; the 1 s server service then fans in
        // back-to-back at 2, 3, 4 — and queue wait is 0 + 1 + 2 = 3 s
        let mut ops = MockOps {
            service_s: 1.0,
            ..MockOps::uniform(3, 1, 1.0, 1.0, 0.5)
        };
        let report = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.server_order(), vec![0, 1, 2], "FIFO under ties");
        assert_eq!(report.queue_wait_s, 3.0);
        // dev2: service ends 5.0, downlink 0.5, fanin compute 1.0 => 6.5
        assert_eq!(report.sim_round_s, 6.5);
        assert_eq!(report.completed, 3);
    }

    #[test]
    fn sync_server_service_queues_after_barrier() {
        // sync, 2 devices, 1 step: barrier at 3.0, service 2 s each =>
        // dev0 waits 0, dev1 waits 2; downlinks at 5+4, 7+4
        let mut ops = MockOps {
            service_s: 2.0,
            ..MockOps::uniform(2, 1, 1.0, 2.0, 4.0)
        };
        let report = SyncEventScheduler::new().run_round(&mut ops).unwrap();
        assert_eq!(report.queue_wait_s, 2.0);
        // dev1 gradient lands at 7 + 4 = 11, fanin compute 1 => 12
        assert_eq!(report.sim_round_s, 12.0);
    }

    #[test]
    fn zero_service_time_reports_zero_queue_wait() {
        for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mut ops = MockOps::uniform(3, 2, 1.0, 2.0, 3.0);
            let report = build_scheduler(scheduler, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            assert_eq!(
                report.queue_wait_s.to_bits(),
                0.0f64.to_bits(),
                "{}: instantaneous server never queues",
                scheduler.name()
            );
        }
    }

    #[test]
    fn shared_uplink_single_device_is_bitwise_private() {
        // one device on the shared pipe: fair share of 1 is the whole
        // pipe, so timings must be bit-for-bit the private-link run
        let capacity = 8e6;
        let latency = 0.013;
        let bytes = 750_000usize;
        let private_cost = latency + (bytes as f64 * 8.0) / capacity;
        let run = |shared: bool| {
            let mut ops = MockOps {
                bytes: vec![bytes],
                latency: vec![latency],
                up_s: vec![if shared { 0.0 } else { private_cost }],
                shared_bps: if shared { Some(capacity) } else { None },
                ..MockOps::uniform(1, 2, 0.5, 0.0, 0.25)
            };
            let r = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            (r.sim_round_s.to_bits(), r.loss_sum.to_bits(), ops.server_order())
        };
        assert_eq!(run(true), run(false), "single shared flow == private cost");
    }

    #[test]
    fn shared_uplink_concurrent_transfers_contend() {
        // two identical devices, shared pipe the size of one private
        // link: both uplinks serialize in 2x the solo time (fair share),
        // and the round is correspondingly longer than private mode
        let capacity = 8e6;
        let bytes = 1_000_000usize; // 1 s solo at 8 Mbit/s
        let solo = (bytes as f64 * 8.0) / capacity;
        let mk = |shared: bool| MockOps {
            bytes: vec![bytes; 2],
            up_s: vec![if shared { 0.0 } else { solo }; 2],
            shared_bps: if shared { Some(capacity) } else { None },
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let shared = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut mk(true))
            .unwrap();
        let private = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut mk(false))
            .unwrap();
        assert!((private.sim_round_s - 1.0).abs() < 1e-9, "private: both in 1 s");
        assert!(
            (shared.sim_round_s - 2.0).abs() < 1e-9,
            "shared: fair-share halves the rate, got {}",
            shared.sim_round_s
        );
        assert_eq!(shared.server_steps, 2);
        assert_eq!(shared.completed, 2);
    }

    #[test]
    fn shared_uplink_charges_occupancy_at_drain() {
        // bytes are charged at fan-out (trainer side, charge-at-send);
        // the scheduler's hook carries only drained occupancy seconds
        let mut ops = MockOps {
            bytes: vec![1_000_000; 2],
            shared_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.charges.len(), 2, "one occupancy charge per drained flow");
        for &(_, t) in &ops.charges {
            assert!((f64::from_bits(t) - 2.0).abs() < 1e-9, "each flow took 2 s fair-share");
        }
    }

    #[test]
    fn shared_uplink_works_under_sync_scheduler() {
        // sync + shared: the barrier is the last fair-share drain
        let mut ops = MockOps {
            bytes: vec![1_000_000; 2],
            shared_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let report = SyncEventScheduler::new().run_round(&mut ops).unwrap();
        assert_eq!(ops.server_order(), vec![0, 1], "lockstep stays device-id order");
        assert!((report.sim_round_s - 2.0).abs() < 1e-9, "barrier at the 2 s drain");
        assert_eq!(report.server_steps, 2);
    }

    #[test]
    fn shared_uplink_async_deterministic_across_runs() {
        let mk = || MockOps {
            compute: vec![0.25, 1.0, 0.5, 2.0],
            down_s: vec![0.5, 0.25, 1.0, 0.125],
            bytes: vec![300_000, 1_000_000, 650_000, 125_000],
            latency: vec![0.005, 0.04, 0.005, 0.04],
            shared_bps: Some(10e6),
            service_s: 0.01,
            ..MockOps::uniform(4, 3, 0.0, 0.0, 0.0)
        };
        let run = |policy: StragglerPolicy| {
            let mut ops = mk();
            let r = AsyncEventScheduler::new(policy).run_round(&mut ops).unwrap();
            (
                ops.log.clone(),
                ops.charges.clone(),
                r.completed,
                r.sim_round_s.to_bits(),
                r.queue_wait_s.to_bits(),
                r.server_steps,
            )
        };
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 4.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            assert_eq!(run(policy), run(policy), "{}", policy.name());
        }
    }

    #[test]
    fn shared_downlink_single_device_is_bitwise_private() {
        // one flow on the shared egress pipe == the private downlink cost,
        // bit for bit — the downlink twin of the uplink guarantee
        let capacity = 8e6;
        let latency = 0.013;
        let bytes = 750_000usize;
        let private_cost = latency + (bytes as f64 * 8.0) / capacity;
        let run = |shared: bool| {
            let mut ops = MockOps {
                dbytes: vec![bytes],
                latency: vec![latency],
                down_s: vec![if shared { 0.0 } else { private_cost }],
                shared_down_bps: if shared { Some(capacity) } else { None },
                ..MockOps::uniform(1, 2, 0.5, 0.25, 0.0)
            };
            let r = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            (r.sim_round_s.to_bits(), r.loss_sum.to_bits(), ops.server_order())
        };
        assert_eq!(run(true), run(false), "single shared egress flow == private cost");
    }

    #[test]
    fn shared_downlink_concurrent_transfers_contend() {
        // two gradients leave the server at the same instant on a pipe
        // sized for one: fair share doubles both transfer times
        let capacity = 8e6;
        let bytes = 1_000_000usize; // 1 s solo at 8 Mbit/s
        let solo = (bytes as f64 * 8.0) / capacity;
        let mk = |shared: bool| MockOps {
            dbytes: vec![bytes; 2],
            down_s: vec![if shared { 0.0 } else { solo }; 2],
            shared_down_bps: if shared { Some(capacity) } else { None },
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let shared = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut mk(true))
            .unwrap();
        let private = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut mk(false))
            .unwrap();
        assert!((private.sim_round_s - 1.0).abs() < 1e-9, "private: both in 1 s");
        assert!(
            (shared.sim_round_s - 2.0).abs() < 1e-9,
            "shared egress: fair-share halves the rate, got {}",
            shared.sim_round_s
        );
        assert_eq!(shared.completed, 2);
    }

    #[test]
    fn shared_downlink_charges_occupancy_at_drain() {
        let mut ops = MockOps {
            dbytes: vec![1_000_000; 2],
            shared_down_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.down_charges.len(), 2, "one occupancy charge per drained flow");
        for &(_, t) in &ops.down_charges {
            assert!((f64::from_bits(t) - 2.0).abs() < 1e-9, "each flow took 2 s fair-share");
        }
    }

    #[test]
    fn shared_downlink_works_under_sync_scheduler() {
        let mut ops = MockOps {
            dbytes: vec![1_000_000; 2],
            shared_down_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let report = SyncEventScheduler::new().run_round(&mut ops).unwrap();
        assert_eq!(ops.server_order(), vec![0, 1]);
        assert!(
            (report.sim_round_s - 2.0).abs() < 1e-9,
            "round ends at the 2 s fair-share drain"
        );
        assert_eq!(report.server_steps, 2);
    }

    /// Heterogeneous 6-device mock (two timing classes) used by the
    /// cohort-equivalence tests — exercises distinct arrival instants,
    /// tie-batches, server queueing, and multi-step pipelining at once.
    fn het_fleet(n_cohorts: usize) -> MockOps {
        let n = 6;
        MockOps {
            compute: (0..n).map(|d| [0.25, 1.0][d % 2]).collect(),
            up_s: (0..n).map(|d| [0.125, 0.5][d % 2]).collect(),
            down_s: (0..n).map(|d| [0.5, 0.25][d % 2]).collect(),
            service_s: 0.01,
            n_cohorts,
            ..MockOps::uniform(n, 3, 0.0, 0.0, 0.0)
        }
    }

    /// Everything a round decides, for bit-level comparison.
    #[allow(clippy::type_complexity)]
    fn round_fingerprint(
        ops: MockOps,
        r: RoundReport,
    ) -> (Vec<String>, Vec<DeviceId>, u64, u64, u64, u64, usize, usize) {
        (
            ops.log,
            ops.cancelled,
            r.loss_sum.to_bits(),
            r.sim_round_s.to_bits(),
            r.queue_wait_s.to_bits(),
            r.server_steps,
            r.completed,
            r.n_devices,
        )
    }

    #[test]
    fn cohort_grouped_async_is_bitwise_per_device() {
        // the tentpole guarantee: cohort-grouped control flow replays the
        // exact per-device op sequence — op log, drops, and every f64 bit —
        // across all three straggler policies, het and hom fleets
        let policies = [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 2.5 },
            StragglerPolicy::Quorum { k: 4 },
        ];
        for policy in policies {
            let run = |cohorts: usize| {
                let mut ops = het_fleet(cohorts);
                let r = AsyncEventScheduler::new(policy).run_round(&mut ops).unwrap();
                round_fingerprint(ops, r)
            };
            assert_eq!(run(2), run(0), "het fleet, {}", policy.name());

            let run_hom = |cohorts: usize| {
                let mut ops = MockOps {
                    n_cohorts: cohorts,
                    ..MockOps::uniform(6, 2, 1.0, 2.0, 3.0)
                };
                let r = AsyncEventScheduler::new(policy).run_round(&mut ops).unwrap();
                round_fingerprint(ops, r)
            };
            assert_eq!(run_hom(1), run_hom(0), "hom fleet, {}", policy.name());
        }
    }

    #[test]
    fn cohort_fold_sync_is_bitwise_event_path() {
        let run = |cohorts: usize| {
            let mut ops = MockOps {
                service_s: 2.0,
                ..het_fleet(cohorts)
            };
            let r = SyncEventScheduler::new().run_round(&mut ops).unwrap();
            round_fingerprint(ops, r)
        };
        assert_eq!(run(4), run(0), "heap-free sync fold == event path");
    }

    #[test]
    fn cohort_grouping_handles_table_overflow() {
        // 40 distinct arrival instants against a 16-entry grouping table
        // (cohorts = 1 → cap = 16): the batch flushes in segments, which
        // must stay bit-identical (segmentation only groups less)
        let run = |cohorts: usize| {
            let mut ops = MockOps {
                compute: (0..40).map(|d| d as f64 * 0.01).collect(),
                n_cohorts: cohorts,
                ..MockOps::uniform(40, 2, 0.0, 0.25, 0.125)
            };
            let r = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            round_fingerprint(ops, r)
        };
        assert_eq!(run(1), run(0));
    }

    #[test]
    fn cohort_grouped_homogeneous_fleet_uses_one_group_per_phase() {
        // 64 identical devices, grouped: every phase collapses to a single
        // batch event, so the server order is device-id order and fan-in
        // is one dispatch over the whole fleet
        let mut ops = MockOps {
            n_cohorts: 1,
            ..MockOps::uniform(64, 1, 1.0, 2.0, 3.0)
        };
        let report = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        assert_eq!(ops.server_order(), (0..64).collect::<Vec<_>>());
        let fanin_calls = ops.log.iter().filter(|l| l.starts_with("fanin:")).count();
        assert_eq!(fanin_calls, 1, "one grouped fan-in dispatch");
        assert_eq!(report.completed, 64);
        assert_eq!(report.sim_round_s, 7.0); // 1 + 2 + 3 + 1
    }

    use super::super::fault::FaultConfig;

    fn plan(cfg: FaultConfig, seed: u64) -> FaultPlan {
        FaultPlan::new(cfg, seed, 0)
    }

    #[test]
    fn fault_certain_loss_exhausts_retries_into_drop() {
        // loss_prob = 1: every copy of every message is lost; after
        // max_retries retransmissions each device falls into the
        // straggler-drop path — the round completes with zero server work
        // instead of hanging or erroring.
        let cfg = FaultConfig {
            loss_prob: 1.0,
            max_retries: 2,
            ..FaultConfig::default()
        };
        for kind in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mut ops = MockOps {
                bytes: vec![100; 3],
                fault: Some(plan(cfg, 7)),
                ..MockOps::uniform(3, 1, 1.0, 2.0, 3.0)
            };
            let r = build_scheduler(kind, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            assert_eq!(r.completed, 0, "{}", kind.name());
            assert_eq!(r.dropped(), 3);
            assert_eq!(r.server_steps, 0, "lost uplinks never hit the server");
            assert_eq!(r.retransmits, 2 * 3, "max_retries copies per device");
            // initial copy + 2 retransmissions, all lost, header+body bytes
            assert_eq!(r.lost_bytes, 3 * 3 * 100);
            assert_eq!(r.corrupt_payloads, 0);
            assert_eq!(ops.cancelled, vec![0, 1, 2]);
            // each retransmission re-charges its wire bytes
            assert_eq!(ops.retr_charges.len(), 6);
            assert!(ops
                .retr_charges
                .iter()
                .all(|&(dir, _, bytes)| dir == "up" && bytes == 100));
        }
    }

    #[test]
    fn fault_certain_corruption_nacks_and_exhausts() {
        // corrupt_prob = 1: every delivery is corrupted, NACKed (the
        // corrupt_uplink hook fires with the exact attempt), and
        // retransmitted until retries exhaust into the drop path.
        let cfg = FaultConfig {
            corrupt_prob: 1.0,
            max_retries: 1,
            ..FaultConfig::default()
        };
        for kind in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mut ops = MockOps {
                fault: Some(plan(cfg, 11)),
                ..MockOps::uniform(2, 1, 1.0, 2.0, 3.0)
            };
            let r = build_scheduler(kind, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            assert_eq!(r.completed, 0, "{}", kind.name());
            assert_eq!(r.server_steps, 0, "corrupt payloads never train");
            assert_eq!(r.corrupt_payloads, 4, "two deliveries per device");
            assert_eq!(r.retransmits, 2);
            let mut corrupts = ops.corrupts.clone();
            corrupts.sort_unstable();
            assert_eq!(corrupts, vec![(0, 0, 0), (0, 0, 1), (1, 0, 0), (1, 0, 1)]);
            assert_eq!(ops.cancelled, vec![0, 1]);
        }
    }

    #[test]
    fn fault_crashed_devices_sit_out_the_round() {
        let cfg = FaultConfig {
            crash_rate: 0.4,
            ..FaultConfig::default()
        };
        // pick a seed where the crash draw actually splits the fleet
        let seed = (0..1000u64)
            .find(|&s| {
                let p = plan(cfg, s);
                let crashed = (0..6).filter(|&d| p.device_crashed(d)).count();
                crashed > 0 && crashed < 6
            })
            .expect("some seed splits 6 devices at 40%");
        let p = plan(cfg, seed);
        let crashed: Vec<DeviceId> = (0..6).filter(|&d| p.device_crashed(d)).collect();
        for kind in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mut ops = MockOps {
                fault: Some(p),
                ..MockOps::uniform(6, 2, 1.0, 2.0, 3.0)
            };
            let r = build_scheduler(kind, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            assert_eq!(r.completed, 6 - crashed.len(), "{}", kind.name());
            assert_eq!(ops.cancelled, crashed, "crashed devices get cancelled");
            assert_eq!(
                r.server_steps,
                2 * (6 - crashed.len()) as u64,
                "crashed devices never reach the server"
            );
            let alive: Vec<DeviceId> = (0..6).filter(|&d| !p.device_crashed(d)).collect();
            assert_eq!(
                ops.log[0],
                format!("fanout:{alive:?}"),
                "crashed devices are excluded before the first fan-out"
            );
            for &c in &crashed {
                assert!(!ops.server_order().contains(&c));
            }
        }
    }

    #[test]
    fn fault_outage_pauses_service_and_reports_recovery_wait() {
        let cfg = FaultConfig {
            server_outage_s: 2.0,
            ..FaultConfig::default()
        };
        let p = plan(cfg, 3);
        let (o_start, o_end) = p.outage_window().unwrap();
        assert!(o_start > 0.0, "seed 3 draws a strictly positive window start");
        // arrivals tie at t = 2.0 (compute 1 + up 1); the first batch hits
        // the outage window and waits out its remainder, later batches
        // queue behind it past the window.
        let mut ops = MockOps {
            service_s: 1.0,
            fault: Some(p),
            ..MockOps::uniform(2, 1, 1.0, 1.0, 1.0)
        };
        let r = AsyncEventScheduler::new(StragglerPolicy::WaitAll)
            .run_round(&mut ops)
            .unwrap();
        // window = [o_start, o_end), o_start < 2 ⇒ the first acquire at
        // t = 2.0 waits exactly until recovery
        assert_eq!(r.recovery_wait_s.to_bits(), (o_end - 2.0).to_bits());
        assert!(r.recovery_wait_s > 0.0);
        assert_eq!(r.completed, 2);
        assert!(r.sim_round_s > 5.0, "outage stretches the round");
    }

    #[test]
    fn faulty_sync_serves_in_arrival_order() {
        // under faults the sync server drains its receive queue in
        // arrival order — the same (time, seq) order async serves in —
        // instead of the fault-free device-id order
        let cfg = FaultConfig {
            corrupt_prob: 1e-12, // active, but no draw will ever fire
            ..FaultConfig::default()
        };
        let mut ops = MockOps {
            up_s: vec![2.0, 5.0, 0.5],
            fault: Some(plan(cfg, 1)),
            ..MockOps::uniform(3, 1, 1.0, 0.0, 1.0)
        };
        let r = SyncEventScheduler::new().run_round(&mut ops).unwrap();
        assert_eq!(ops.server_order(), vec![2, 0, 1]);
        assert_eq!(r.completed, 3);
        assert_eq!((r.retransmits, r.corrupt_payloads, r.lost_bytes), (0, 0, 0));
    }

    #[test]
    fn faulty_rounds_are_deterministic_across_runs() {
        let cfg = FaultConfig {
            loss_prob: 0.3,
            corrupt_prob: 0.2,
            crash_rate: 0.1,
            server_outage_s: 0.5,
            retry_base_s: 0.1,
            ..FaultConfig::default()
        };
        for kind in [SchedulerKind::Sync, SchedulerKind::Async] {
            let run = || {
                let mut ops = MockOps {
                    bytes: vec![50; 6],
                    dbytes: vec![30; 6],
                    service_s: 0.01,
                    fault: Some(plan(cfg, 42)),
                    ..het_fleet(0)
                };
                let r = build_scheduler(kind, StragglerPolicy::WaitAll)
                    .run_round(&mut ops)
                    .unwrap();
                (
                    ops.log.clone(),
                    ops.cancelled.clone(),
                    ops.corrupts.clone(),
                    ops.retr_charges.clone(),
                    r.loss_sum.to_bits(),
                    r.sim_round_s.to_bits(),
                    r.queue_wait_s.to_bits(),
                    r.recovery_wait_s.to_bits(),
                    (r.retransmits, r.lost_bytes, r.corrupt_payloads),
                    (r.completed, r.server_steps),
                )
            };
            assert_eq!(run(), run(), "{}", kind.name());
        }
    }

    #[test]
    fn faulty_sync_and_async_agree_without_exhaustion() {
        // corrupt + crash only (no loss: downlink retransmission chains
        // anchor at the server's send instant, which under sync is the
        // barrier — an intrinsic semantic difference). With a homogeneous
        // fleet, one local step, and no device exhausting its retries,
        // the two schedulers must produce bit-identical reports.
        let cfg = FaultConfig {
            corrupt_prob: 0.4,
            crash_rate: 0.2,
            ..FaultConfig::default()
        };
        let n = 8;
        let seed = (0..10_000u64)
            .find(|&s| {
                let p = plan(cfg, s);
                let crashed = (0..n).filter(|&d| p.device_crashed(d)).count();
                let corrupted = (0..n)
                    .filter(|&d| !p.device_crashed(d) && p.uplink_corrupt(d, 0, 0))
                    .count();
                let exhausted = (0..n).any(|d| {
                    !p.device_crashed(d)
                        && (0..=cfg.max_retries).all(|a| p.uplink_corrupt(d, 0, a))
                });
                crashed > 0 && crashed < n && corrupted > 0 && !exhausted
            })
            .expect("a seed with crashes and recoverable corruption exists");
        let p = plan(cfg, seed);
        let run = |kind: SchedulerKind| {
            let mut ops = MockOps {
                fault: Some(p),
                ..MockOps::uniform(n, 1, 1.0, 2.0, 3.0)
            };
            let r = build_scheduler(kind, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            (
                ops.server_order(),
                ops.cancelled.clone(),
                ops.corrupts.clone(),
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.queue_wait_s.to_bits(),
                (r.retransmits, r.lost_bytes, r.corrupt_payloads),
                (r.completed, r.server_steps, r.n_devices),
            )
        };
        assert_eq!(run(SchedulerKind::Sync), run(SchedulerKind::Async));
    }

    #[test]
    fn build_scheduler_routes_kinds() {
        assert_eq!(
            build_scheduler(SchedulerKind::Sync, StragglerPolicy::WaitAll).name(),
            "sync"
        );
        assert_eq!(
            build_scheduler(SchedulerKind::Async, StragglerPolicy::Quorum { k: 1 }).name(),
            "async"
        );
    }
}
