//! Round schedulers: barriered lockstep and event-driven async, behind one
//! [`RoundScheduler`] trait.
//!
//! A scheduler decides *when* device work happens inside one communication
//! round — it never touches model state itself. The training side exposes
//! a narrow [`RoundOps`] interface (the trainer implements it over its
//! device table and executor); the scheduler drives that interface through
//! the deterministic [`EventQueue`].
//!
//! * [`SyncEventScheduler`] — the classic lockstep round re-expressed as
//!   events: every local step is fan-out over all devices, a barrier
//!   (every uplink must land), server steps in **device-id order**, then
//!   fan-in over all devices. The event queue supplies the timing
//!   (barrier time = last arrival), and because the op sequence is
//!   identical to the pre-transport engine, results are bit-identical to
//!   it.
//! * [`AsyncEventScheduler`] — the server consumes uplinks **as they
//!   land** (event order, i.e. simulated arrival time with deterministic
//!   seq tie-breaking), devices pipeline their local steps independently,
//!   and a [`StragglerPolicy`] decides when the round closes and which
//!   devices get dropped.
//!
//! # Determinism contract
//!
//! Everything a scheduler decides — server processing order, batch
//! composition, straggler drops, round close time — derives from the
//! `(time, seq)` event order, which is a pure function of the experiment
//! seed and configuration. Worker counts and thread scheduling never
//! enter: device-local work dispatched in batches goes through the
//! engine's sharded pool, whose bit-transparency is established
//! separately (`coordinator::engine`). The `parallel_determinism`
//! integration test pins this end to end for both schedulers.
//!
//! The compute model is deliberately simple: each fan-out and each fan-in
//! on device `d` costs `compute_s(d)` simulated seconds (the config's
//! `base_compute_s` × the device profile's multiplier); server processing
//! is instantaneous. Transfer times come from the link cost model
//! ([`super::link`]).

use super::event::{DeviceId, Event, EventQueue};
use super::policy::StragglerPolicy;
use anyhow::{bail, Result};

/// Which round scheduler to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Barriered lockstep phases (the default; pre-transport behavior).
    Sync,
    /// Event-driven: server consumes uplinks as they land.
    Async,
}

impl SchedulerKind {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sync" | "barrier" | "lockstep" => SchedulerKind::Sync,
            "async" | "event" | "event-driven" => SchedulerKind::Async,
            other => bail!("unknown scheduler '{other}' (sync | async)"),
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Sync => "sync",
            SchedulerKind::Async => "async",
        }
    }
}

/// What one server step produced (returned by [`RoundOps::server_step`]).
#[derive(Debug, Clone, Copy)]
pub struct ServerOut {
    /// Simulated seconds the downlink transfer took.
    pub downlink_s: f64,
    /// Batch loss.
    pub loss: f64,
    /// Correct predictions in the batch.
    pub correct: u64,
    /// Samples in the batch.
    pub samples: u64,
}

/// The training-side operations a scheduler drives. Implemented by the
/// trainer; all methods are device-local except `server_step`, which
/// mutates shared server state and must be called serially (schedulers
/// guarantee that).
pub trait RoundOps {
    /// Number of devices in the round.
    fn n_devices(&self) -> usize;

    /// Local steps each device runs per round (`batches_per_round`).
    fn steps(&self) -> usize;

    /// Simulated client compute seconds for one fan-out *or* one fan-in
    /// phase on `dev` (profile-scaled).
    fn compute_s(&self, dev: DeviceId) -> f64;

    /// Client forward + codec encode + uplink charge for each listed
    /// device (the implementation may fan work across its thread pool).
    /// Returns each device's uplink transfer seconds, in `devs` order.
    fn fanout(&mut self, devs: &[DeviceId]) -> Result<Vec<f64>>;

    /// Server decode + train step + downlink charge for one device's
    /// pending uplink.
    fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut>;

    /// Gradient decode + client backward for each listed device.
    fn fanin(&mut self, devs: &[DeviceId]) -> Result<()>;

    /// Straggler drop: discard any in-flight state for `dev` so the next
    /// round starts clean.
    fn cancel(&mut self, dev: DeviceId);
}

/// What one round produced, scheduler-agnostic.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// Sum of batch losses over executed server steps (event order).
    pub loss_sum: f64,
    /// Correct predictions over executed server steps.
    pub correct: u64,
    /// Samples over executed server steps.
    pub samples: u64,
    /// Server steps actually executed (dropped uplinks never run).
    pub server_steps: u64,
    /// Event-clock duration of the round (compute + transfers + queueing;
    /// for deadline rounds, capped at the deadline).
    pub sim_round_s: f64,
    /// `completed[d]`: device `d` finished all its steps and participates
    /// in this round's aggregation.
    pub completed: Vec<bool>,
}

impl RoundReport {
    /// Devices dropped by the straggler policy this round.
    pub fn dropped(&self) -> usize {
        self.completed.iter().filter(|&&c| !c).count()
    }
}

/// One communication round's control flow.
pub trait RoundScheduler: Send + Sync {
    /// Stable display name.
    fn name(&self) -> &'static str;

    /// Drive one round over `ops`.
    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport>;
}

/// Build the configured scheduler. Sync ignores the policy (it is
/// inherently wait-all; the config layer rejects other combinations).
pub fn build_scheduler(kind: SchedulerKind, policy: StragglerPolicy) -> Box<dyn RoundScheduler> {
    match kind {
        SchedulerKind::Sync => Box::new(SyncEventScheduler),
        SchedulerKind::Async => Box::new(AsyncEventScheduler { policy }),
    }
}

/// Lockstep phases on the event queue — bit-identical op sequence to the
/// pre-transport engine (fan-out all → server in device-id order → fan-in
/// all, per local step).
pub struct SyncEventScheduler;

impl RoundScheduler for SyncEventScheduler {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let n = ops.n_devices();
        let steps = ops.steps();
        let all: Vec<DeviceId> = (0..n).collect();
        let mut q = EventQueue::new();
        let mut t = 0.0f64;
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        for step in 0..steps {
            let ups = ops.fanout(&all)?;
            for d in 0..n {
                q.push(t + ops.compute_s(d) + ups[d], d, Event::UplinkArrived { step });
            }
            // Barrier: every uplink lands before the server phase starts.
            // The queue fixes the arrival order; lockstep mode then serves
            // in device-id order regardless (legacy semantics).
            let mut barrier_t = t;
            while let Some(ev) = q.pop() {
                barrier_t = barrier_t.max(ev.time);
            }
            let mut downs = vec![0.0f64; n];
            // per-step partial sum, folded into the round total afterwards —
            // the exact f64 fold order of the pre-transport engine, so
            // reported losses stay bit-identical to it
            let mut step_loss = 0.0f64;
            for (d, down) in downs.iter_mut().enumerate() {
                let out = ops.server_step(d)?;
                step_loss += out.loss;
                correct += out.correct;
                samples += out.samples;
                server_steps += 1;
                *down = out.downlink_s;
            }
            loss_sum += step_loss;
            for d in 0..n {
                q.push(barrier_t + downs[d], d, Event::DownlinkArrived { step });
            }
            // Step ends when the slowest device has its gradient applied.
            let mut ready_t = barrier_t;
            while let Some(ev) = q.pop() {
                ready_t = ready_t.max(ev.time + ops.compute_s(ev.device));
            }
            ops.fanin(&all)?;
            t = ready_t;
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: t,
            completed: vec![true; n],
        })
    }
}

/// Event-driven rounds: devices pipeline local steps independently, the
/// server consumes uplinks in arrival order, and the straggler policy
/// closes the round.
pub struct AsyncEventScheduler {
    /// Round-close policy.
    pub policy: StragglerPolicy,
}

impl RoundScheduler for AsyncEventScheduler {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let n = ops.n_devices();
        let steps = ops.steps();
        let mut completed = vec![false; n];
        if n == 0 || steps == 0 {
            return Ok(RoundReport {
                loss_sum: 0.0,
                correct: 0,
                samples: 0,
                server_steps: 0,
                sim_round_s: 0.0,
                completed: vec![true; n],
            });
        }
        let deadline = match self.policy {
            StragglerPolicy::DeadlineDrop { deadline_s } => Some(deadline_s),
            _ => None,
        };
        let quorum = match self.policy {
            StragglerPolicy::Quorum { k } => Some(k),
            _ => None,
        };

        let mut q = EventQueue::new();
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        let mut done = 0usize;
        let mut close_t: Option<f64> = None;
        let mut last_t = 0.0f64;

        // Kick-off: every device starts its first local step at t = 0
        // (one thread-parallel fan-out batch).
        let all: Vec<DeviceId> = (0..n).collect();
        let ups = ops.fanout(&all)?;
        for d in 0..n {
            q.push(ops.compute_s(d) + ups[d], d, Event::UplinkArrived { step: 0 });
        }

        while let Some(ev) = q.pop() {
            if let Some(t_max) = deadline {
                if ev.time > t_max {
                    close_t = Some(t_max);
                    break;
                }
            }
            last_t = ev.time;
            match ev.event {
                Event::UplinkArrived { step } => {
                    let out = ops.server_step(ev.device)?;
                    loss_sum += out.loss;
                    correct += out.correct;
                    samples += out.samples;
                    server_steps += 1;
                    q.push(ev.time + out.downlink_s, ev.device, Event::DownlinkArrived { step });
                }
                Event::DownlinkArrived { step } => {
                    // Batch ties: downlinks landing at the bit-same instant
                    // run fan-in/fan-out through one worker-pool dispatch
                    // (homogeneous fleets stay as parallel as lockstep mode).
                    // Batch composition is event order — deterministic.
                    let mut batch: Vec<(DeviceId, usize)> = vec![(ev.device, step)];
                    loop {
                        let tie = matches!(
                            q.peek(),
                            Some(next) if matches!(next.event, Event::DownlinkArrived { .. })
                                && next.time.to_bits() == ev.time.to_bits()
                        );
                        if !tie {
                            break;
                        }
                        let nev = q.pop().expect("peeked event");
                        let Event::DownlinkArrived { step: s2 } = nev.event else {
                            unreachable!("tie check admits only downlinks")
                        };
                        batch.push((nev.device, s2));
                    }
                    let devs: Vec<DeviceId> = batch.iter().map(|&(d, _)| d).collect();
                    ops.fanin(&devs)?;
                    let continuing: Vec<(DeviceId, usize)> = batch
                        .iter()
                        .filter(|&&(_, s)| s + 1 < steps)
                        .copied()
                        .collect();
                    if !continuing.is_empty() {
                        let cont_devs: Vec<DeviceId> =
                            continuing.iter().map(|&(d, _)| d).collect();
                        let ups = ops.fanout(&cont_devs)?;
                        for (i, &(d, s)) in continuing.iter().enumerate() {
                            // fan-in compute + next fan-out compute + uplink
                            q.push(
                                ev.time + 2.0 * ops.compute_s(d) + ups[i],
                                d,
                                Event::UplinkArrived { step: s + 1 },
                            );
                        }
                    }
                    for &(d, s) in &batch {
                        if s + 1 == steps {
                            q.push(ev.time + ops.compute_s(d), d, Event::DeviceDone);
                        }
                    }
                }
                Event::DeviceDone => {
                    completed[ev.device] = true;
                    done += 1;
                    if let Some(k) = quorum {
                        if done >= k {
                            close_t = Some(ev.time);
                            break;
                        }
                    }
                }
            }
        }
        q.clear();
        for (d, &c) in completed.iter().enumerate() {
            if !c {
                ops.cancel(d);
            }
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: close_t.unwrap_or(last_t),
            completed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pure-timing mock: per-device compute/uplink/downlink costs, plus an
    /// op log so tests can pin exact scheduling decisions.
    struct MockOps {
        steps: usize,
        compute: Vec<f64>,
        up_s: Vec<f64>,
        down_s: Vec<f64>,
        log: Vec<String>,
        cancelled: Vec<DeviceId>,
    }

    impl MockOps {
        fn uniform(n: usize, steps: usize, c: f64, up: f64, down: f64) -> Self {
            MockOps {
                steps,
                compute: vec![c; n],
                up_s: vec![up; n],
                down_s: vec![down; n],
                log: Vec::new(),
                cancelled: Vec::new(),
            }
        }

        fn server_order(&self) -> Vec<DeviceId> {
            self.log
                .iter()
                .filter_map(|l| l.strip_prefix("server:").map(|d| d.parse().unwrap()))
                .collect()
        }
    }

    impl RoundOps for MockOps {
        fn n_devices(&self) -> usize {
            self.compute.len()
        }
        fn steps(&self) -> usize {
            self.steps
        }
        fn compute_s(&self, dev: DeviceId) -> f64 {
            self.compute[dev]
        }
        fn fanout(&mut self, devs: &[DeviceId]) -> Result<Vec<f64>> {
            self.log.push(format!("fanout:{devs:?}"));
            Ok(devs.iter().map(|&d| self.up_s[d]).collect())
        }
        fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut> {
            self.log.push(format!("server:{dev}"));
            Ok(ServerOut {
                downlink_s: self.down_s[dev],
                loss: 1.0 + dev as f64,
                correct: 1,
                samples: 2,
            })
        }
        fn fanin(&mut self, devs: &[DeviceId]) -> Result<()> {
            self.log.push(format!("fanin:{devs:?}"));
            Ok(())
        }
        fn cancel(&mut self, dev: DeviceId) {
            self.cancelled.push(dev);
        }
    }

    #[test]
    fn scheduler_kind_parses() {
        assert_eq!(SchedulerKind::parse("sync").unwrap(), SchedulerKind::Sync);
        assert_eq!(SchedulerKind::parse("ASYNC").unwrap(), SchedulerKind::Async);
        assert!(SchedulerKind::parse("warp").is_err());
        assert_eq!(SchedulerKind::Async.name(), "async");
    }

    #[test]
    fn sync_runs_lockstep_phases_in_device_order() {
        let mut ops = MockOps::uniform(2, 2, 1.0, 2.0, 4.0);
        let report = SyncEventScheduler.run_round(&mut ops).unwrap();
        assert_eq!(
            ops.log,
            vec![
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
            ]
        );
        assert_eq!(report.server_steps, 4);
        assert_eq!(report.completed, vec![true, true]);
        assert_eq!(report.dropped(), 0);
        // per step: fanout compute 1 + up 2 (barrier 3), down 4 + fanin 1
        // => 8 per step, 2 steps = 16 (integers: exact in f64)
        assert_eq!(report.sim_round_s, 16.0);
        // loss fold order: (1 + 2) per step-phase
        assert_eq!(report.loss_sum, 6.0);
    }

    #[test]
    fn async_server_consumes_in_arrival_order() {
        // arrival = compute + up: dev2 lands first, then dev0, then dev1
        let mut ops = MockOps {
            steps: 1,
            compute: vec![1.0, 1.0, 1.0],
            up_s: vec![2.0, 5.0, 0.5],
            down_s: vec![1.0; 3],
            log: Vec::new(),
            cancelled: Vec::new(),
        };
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.server_order(), vec![2, 0, 1]);
        assert_eq!(report.completed, vec![true, true, true]);
        // slowest chain: dev1 done at 1 + 5 (up) + 1 (down) + 1 (fanin) = 8
        assert_eq!(report.sim_round_s, 8.0);
        assert!(ops.cancelled.is_empty());
    }

    #[test]
    fn async_wait_all_pipeline_timing() {
        // single device, 2 steps: up@3, down@7, next up@11, down@15, done@16
        let mut ops = MockOps::uniform(1, 2, 1.0, 2.0, 4.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.server_steps, 2);
        assert_eq!(report.sim_round_s, 16.0);
        assert_eq!(report.completed, vec![true]);
    }

    #[test]
    fn async_deadline_drops_unfinished_devices() {
        let mut ops = MockOps {
            steps: 1,
            compute: vec![1.0, 10.0],
            up_s: vec![1.0, 10.0],
            down_s: vec![1.0, 10.0],
            log: Vec::new(),
            cancelled: Vec::new(),
        };
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::DeadlineDrop { deadline_s: 5.0 },
        }
        .run_round(&mut ops)
        .unwrap();
        // dev0: up@2, down@3, done@4 — inside the deadline
        // dev1: up@20 — never processed
        assert_eq!(report.completed, vec![true, false]);
        assert_eq!(report.dropped(), 1);
        assert_eq!(report.server_steps, 1, "dropped uplink never hits the server");
        assert_eq!(ops.server_order(), vec![0]);
        assert_eq!(ops.cancelled, vec![1]);
        assert_eq!(report.sim_round_s, 5.0, "round closes at the deadline");
    }

    #[test]
    fn async_deadline_everyone_drops_when_too_tight() {
        let mut ops = MockOps::uniform(3, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::DeadlineDrop { deadline_s: 1e-6 },
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.completed, vec![false; 3]);
        assert_eq!(report.server_steps, 0);
        assert_eq!(ops.cancelled, vec![0, 1, 2]);
    }

    #[test]
    fn async_quorum_closes_on_kth_completion_with_seq_ties() {
        // identical devices: completions tie at the same instant; the
        // deterministic seq order makes devices 0 and 1 the quorum
        let mut ops = MockOps::uniform(4, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::Quorum { k: 2 },
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.completed, vec![true, true, false, false]);
        assert_eq!(ops.cancelled, vec![2, 3]);
        // done at fanout 1 + up 1 + down 1 + fanin 1 = 4
        assert_eq!(report.sim_round_s, 4.0);
    }

    #[test]
    fn async_quorum_equal_to_n_is_wait_all() {
        let mk = || MockOps::uniform(3, 2, 0.5, 1.0, 1.0);
        let mut a = mk();
        let ra = AsyncEventScheduler {
            policy: StragglerPolicy::Quorum { k: 3 },
        }
        .run_round(&mut a)
        .unwrap();
        let mut b = mk();
        let rb = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut b)
        .unwrap();
        assert_eq!(ra.completed, rb.completed);
        assert_eq!(ra.server_steps, rb.server_steps);
        assert_eq!(ra.sim_round_s.to_bits(), rb.sim_round_s.to_bits());
        assert_eq!(a.log, b.log);
    }

    #[test]
    fn async_homogeneous_ties_batch_but_keep_server_id_order() {
        // homogeneous fleet: every uplink of a step lands at the same
        // instant, so the server sees device-id order — the property that
        // makes async wait-all match sync byte-for-byte
        let mut ops = MockOps::uniform(3, 2, 1.0, 2.0, 3.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.server_order(), vec![0, 1, 2, 0, 1, 2]);
        // tie-batched fan-in/fan-out: one dispatch for all three devices
        assert!(ops.log.contains(&"fanin:[0, 1, 2]".to_string()));
        assert_eq!(report.completed, vec![true; 3]);
    }

    #[test]
    fn async_is_deterministic_across_runs() {
        let mk = || MockOps {
            steps: 3,
            compute: vec![0.25, 1.0, 0.5, 2.0],
            up_s: vec![0.125, 0.5, 2.0, 0.0625],
            down_s: vec![0.5, 0.25, 1.0, 0.125],
            log: Vec::new(),
            cancelled: Vec::new(),
        };
        let run = |policy: StragglerPolicy| {
            let mut ops = mk();
            let r = AsyncEventScheduler { policy }.run_round(&mut ops).unwrap();
            (
                ops.log.clone(),
                ops.cancelled.clone(),
                r.completed.clone(),
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.server_steps,
            )
        };
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 6.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            assert_eq!(run(policy), run(policy), "{}", policy.name());
        }
    }

    #[test]
    fn build_scheduler_routes_kinds() {
        assert_eq!(
            build_scheduler(SchedulerKind::Sync, StragglerPolicy::WaitAll).name(),
            "sync"
        );
        assert_eq!(
            build_scheduler(SchedulerKind::Async, StragglerPolicy::Quorum { k: 1 }).name(),
            "async"
        );
    }
}
