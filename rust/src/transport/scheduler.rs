//! Round schedulers: barriered lockstep and event-driven async, behind one
//! [`RoundScheduler`] trait.
//!
//! A scheduler decides *when* device work happens inside one communication
//! round — it never touches model state itself. The training side exposes
//! a narrow [`RoundOps`] interface (the trainer implements it over its
//! device table and executor); the scheduler drives that interface through
//! the deterministic [`EventQueue`].
//!
//! * [`SyncEventScheduler`] — the classic lockstep round re-expressed as
//!   events: every local step is fan-out over all devices, a barrier
//!   (every uplink must land), server steps in **device-id order**, then
//!   fan-in over all devices. The event queue supplies the timing
//!   (barrier time = last arrival), and because the op sequence is
//!   identical to the pre-transport engine, results are bit-identical to
//!   it.
//! * [`AsyncEventScheduler`] — the server consumes uplinks **as they
//!   land** (event order, i.e. simulated arrival time with deterministic
//!   seq tie-breaking), devices pipeline their local steps independently,
//!   and a [`StragglerPolicy`] decides when the round closes and which
//!   devices get dropped.
//!
//! # Determinism contract
//!
//! Everything a scheduler decides — server processing order, batch
//! composition, straggler drops, round close time — derives from the
//! `(time, seq)` event order, which is a pure function of the experiment
//! seed and configuration. Worker counts and thread scheduling never
//! enter: device-local work dispatched in batches goes through the
//! engine's sharded pool, whose bit-transparency is established
//! separately (`coordinator::engine`). The `parallel_determinism`
//! integration test pins this end to end for both schedulers.
//!
//! The compute model: each fan-out and each fan-in on device `d` costs
//! `compute_s(d)` simulated seconds (the config's `base_compute_s` × the
//! device profile's multiplier). Server processing occupies a serial
//! busy resource for `server_service_s` per batch
//! ([`super::event::ServerResource`]; `0` = the historical instantaneous
//! server), and uplink transfer times come either from the private link
//! cost model ([`super::link`]) or, in `uplink = "shared"` mode, from the
//! fair-share fluid model ([`super::link::SharedUplink`]) that both
//! schedulers drive through `UplinkStart`/`SharedDrain` events.

use super::event::{DeviceId, Event, EventQueue, ServerResource};
use super::link::SharedUplink;
use super::policy::StragglerPolicy;
use anyhow::{bail, Result};

/// Which round scheduler to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Barriered lockstep phases (the default; pre-transport behavior).
    Sync,
    /// Event-driven: server consumes uplinks as they land.
    Async,
}

impl SchedulerKind {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sync" | "barrier" | "lockstep" => SchedulerKind::Sync,
            "async" | "event" | "event-driven" => SchedulerKind::Async,
            other => bail!("unknown scheduler '{other}' (sync | async)"),
        })
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Sync => "sync",
            SchedulerKind::Async => "async",
        }
    }
}

/// What one server step produced (returned by [`RoundOps::server_step`]).
#[derive(Debug, Clone, Copy)]
pub struct ServerOut {
    /// Simulated seconds the downlink transfer took.
    pub downlink_s: f64,
    /// Batch loss.
    pub loss: f64,
    /// Correct predictions in the batch.
    pub correct: u64,
    /// Samples in the batch.
    pub samples: u64,
}

/// What one fan-out produced for one device: the payload's exact wire
/// size plus, in private-uplink mode, the already-charged transfer cost.
#[derive(Debug, Clone, Copy)]
pub struct UplinkMsg {
    /// Exact wire bytes of the compressed payload.
    pub wire_bytes: usize,
    /// Private-mode transfer seconds (latency + serialization + jitter),
    /// charged to the device link inside `fanout`. `0.0` in shared-uplink
    /// mode, where the fair-share model decides the duration and the
    /// scheduler charges it via [`RoundOps::charge_uplink`].
    pub cost_s: f64,
}

/// The training-side operations a scheduler drives. Implemented by the
/// trainer; all methods are device-local except `server_step`, which
/// mutates shared server state and must be called serially (schedulers
/// guarantee that).
///
/// The contention-model accessors (`server_service_s`,
/// `shared_uplink_bps`, `uplink_latency_s`, `charge_uplink`) default to
/// the pre-contention behavior — instantaneous server, private links — so
/// simple implementations (mocks, sequential mode) need not override
/// them.
pub trait RoundOps {
    /// Number of devices in the round.
    fn n_devices(&self) -> usize;

    /// Local steps each device runs per round (`batches_per_round`).
    fn steps(&self) -> usize;

    /// Simulated client compute seconds for one fan-out *or* one fan-in
    /// phase on `dev` (profile-scaled).
    fn compute_s(&self, dev: DeviceId) -> f64;

    /// Simulated seconds one server batch occupies the server resource
    /// (`server_service_s`; `0` = infinitely fast server).
    fn server_service_s(&self) -> f64 {
        0.0
    }

    /// `Some(capacity_bps)` when all uplinks contend for one shared pipe
    /// (`uplink = "shared"`); `None` for private per-device uplinks.
    fn shared_uplink_bps(&self) -> Option<f64> {
        None
    }

    /// Per-flow propagation latency for `dev`'s uplink in shared mode
    /// (private mode folds latency into the `fanout` cost).
    fn uplink_latency_s(&self, _dev: DeviceId) -> f64 {
        0.0
    }

    /// Shared-mode accounting hook: record a drained flow's occupancy
    /// seconds against `dev`'s link. (Bytes are charged at fan-out time,
    /// charge-at-send, exactly like the private path — so a flow the
    /// deadline abandons mid-pipe still counts its transmitted bytes.)
    fn charge_uplink(&mut self, _dev: DeviceId, _busy_s: f64) {}

    /// Client forward + codec encode (+ uplink charge in private mode)
    /// for each listed device (the implementation may fan work across its
    /// thread pool). Returns each device's [`UplinkMsg`], in `devs` order.
    fn fanout(&mut self, devs: &[DeviceId]) -> Result<Vec<UplinkMsg>>;

    /// Server decode + train step + downlink charge for one device's
    /// pending uplink.
    fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut>;

    /// Gradient decode + client backward for each listed device.
    fn fanin(&mut self, devs: &[DeviceId]) -> Result<()>;

    /// Straggler drop: discard any in-flight state for `dev` so the next
    /// round starts clean.
    fn cancel(&mut self, dev: DeviceId);
}

/// What one round produced, scheduler-agnostic.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// Sum of batch losses over executed server steps (event order).
    pub loss_sum: f64,
    /// Correct predictions over executed server steps.
    pub correct: u64,
    /// Samples over executed server steps.
    pub samples: u64,
    /// Server steps actually executed (dropped uplinks never run).
    pub server_steps: u64,
    /// Event-clock duration of the round (compute + transfers + queueing;
    /// for deadline rounds, capped at the deadline).
    pub sim_round_s: f64,
    /// Total simulated seconds uplinks spent queued for the server busy
    /// resource this round (summed over executed server steps; `0` when
    /// `server_service_s = 0`).
    pub queue_wait_s: f64,
    /// `completed[d]`: device `d` finished all its steps and participates
    /// in this round's aggregation.
    pub completed: Vec<bool>,
}

impl RoundReport {
    /// Devices dropped by the straggler policy this round.
    pub fn dropped(&self) -> usize {
        self.completed.iter().filter(|&&c| !c).count()
    }
}

/// One communication round's control flow.
pub trait RoundScheduler: Send + Sync {
    /// Stable display name.
    fn name(&self) -> &'static str;

    /// Drive one round over `ops`.
    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport>;
}

/// Build the configured scheduler. Sync ignores the policy (it is
/// inherently wait-all; the config layer rejects other combinations).
pub fn build_scheduler(kind: SchedulerKind, policy: StragglerPolicy) -> Box<dyn RoundScheduler> {
    match kind {
        SchedulerKind::Sync => Box::new(SyncEventScheduler),
        SchedulerKind::Async => Box::new(AsyncEventScheduler { policy }),
    }
}

/// Push one device's uplink into the round's timeline: private mode
/// schedules the arrival directly (cost already known); shared mode
/// schedules a flow start for the fair-share pipe.
fn submit_uplink(
    q: &mut EventQueue,
    shared: bool,
    start_t: f64,
    dev: DeviceId,
    step: usize,
    msg: &UplinkMsg,
) {
    if shared {
        q.push(
            start_t,
            dev,
            Event::UplinkStart {
                step,
                bytes: msg.wire_bytes,
            },
        );
    } else {
        q.push(start_t + msg.cost_s, dev, Event::UplinkArrived { step });
    }
}

/// Drive the shared-uplink fluid model for one popped event. Returns
/// `true` when the event belonged to the pipe (flow start or drain
/// prediction) and was consumed; delivery is re-entered into the queue as
/// a plain [`Event::UplinkArrived`], so scheduler control flow only ever
/// reacts to arrivals.
///
/// The device id on a rescheduled [`Event::SharedDrain`] is the device
/// that triggered the recompute — the flow actually draining is resolved
/// inside [`SharedUplink::complete`], deterministically.
fn pipe_event(
    pipe: &mut SharedUplink,
    q: &mut EventQueue,
    ops: &mut dyn RoundOps,
    ev: &super::event::Scheduled,
) -> bool {
    match ev.event {
        Event::UplinkStart { step, bytes } => {
            let (t_drain, gen) =
                pipe.start(ev.time, ev.device, step, bytes, ops.uplink_latency_s(ev.device));
            q.push(t_drain, ev.device, Event::SharedDrain { generation: gen });
            true
        }
        Event::SharedDrain { generation } => {
            if let Some((done, next)) = pipe.complete(generation) {
                ops.charge_uplink(done.device, done.busy_s);
                q.push(done.arrival_t, done.device, Event::UplinkArrived { step: done.step });
                if let Some((t_next, gen)) = next {
                    q.push(t_next, done.device, Event::SharedDrain { generation: gen });
                }
            }
            true
        }
        _ => false,
    }
}

/// Lockstep phases on the event queue — bit-identical op sequence to the
/// pre-transport engine (fan-out all → server in device-id order → fan-in
/// all, per local step) when the contention model is off
/// (`uplink = private`, `server_service_s = 0`).
pub struct SyncEventScheduler;

impl RoundScheduler for SyncEventScheduler {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let n = ops.n_devices();
        let steps = ops.steps();
        let all: Vec<DeviceId> = (0..n).collect();
        let mut q = EventQueue::new();
        let mut pipe = ops.shared_uplink_bps().map(SharedUplink::new);
        let mut server = ServerResource::new(ops.server_service_s());
        let mut t = 0.0f64;
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        let mut queue_wait_s = 0.0f64;
        for step in 0..steps {
            let ups = ops.fanout(&all)?;
            for d in 0..n {
                submit_uplink(&mut q, pipe.is_some(), t + ops.compute_s(d), d, step, &ups[d]);
            }
            // Barrier: every uplink lands before the server phase starts.
            // The queue fixes the arrival order; lockstep mode then serves
            // in device-id order regardless (legacy semantics). Shared-pipe
            // bookkeeping events are consumed in-line.
            let mut barrier_t = t;
            let mut landed = 0usize;
            while landed < n {
                let ev = q.pop().expect("uplinks still in flight");
                if let Some(p) = pipe.as_mut() {
                    if pipe_event(p, &mut q, ops, &ev) {
                        continue;
                    }
                }
                debug_assert!(matches!(ev.event, Event::UplinkArrived { .. }));
                barrier_t = barrier_t.max(ev.time);
                landed += 1;
            }
            // Server phase: device-id order; uplinks all became ready at
            // the barrier and queue for the serial server resource.
            // per-step partial sum, folded into the round total afterwards —
            // the exact f64 fold order of the pre-transport engine, so
            // reported losses stay bit-identical to it
            let mut step_loss = 0.0f64;
            for d in 0..n {
                let (start, end) = server.acquire(barrier_t);
                queue_wait_s += start - barrier_t;
                let out = ops.server_step(d)?;
                step_loss += out.loss;
                correct += out.correct;
                samples += out.samples;
                server_steps += 1;
                q.push(end + out.downlink_s, d, Event::DownlinkArrived { step });
            }
            loss_sum += step_loss;
            // Step ends when the slowest device has its gradient applied.
            // (Only downlinks count: a stale shared-drain prediction may
            // still be queued at the same instant as the last arrival.)
            let mut ready_t = barrier_t;
            while let Some(ev) = q.pop() {
                if matches!(ev.event, Event::DownlinkArrived { .. }) {
                    ready_t = ready_t.max(ev.time + ops.compute_s(ev.device));
                }
            }
            ops.fanin(&all)?;
            t = ready_t;
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: t,
            queue_wait_s,
            completed: vec![true; n],
        })
    }
}

/// Event-driven rounds: devices pipeline local steps independently, the
/// server consumes uplinks in arrival order, and the straggler policy
/// closes the round.
pub struct AsyncEventScheduler {
    /// Round-close policy.
    pub policy: StragglerPolicy,
}

impl RoundScheduler for AsyncEventScheduler {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run_round(&self, ops: &mut dyn RoundOps) -> Result<RoundReport> {
        let n = ops.n_devices();
        let steps = ops.steps();
        let mut completed = vec![false; n];
        if n == 0 || steps == 0 {
            return Ok(RoundReport {
                loss_sum: 0.0,
                correct: 0,
                samples: 0,
                server_steps: 0,
                sim_round_s: 0.0,
                queue_wait_s: 0.0,
                completed: vec![true; n],
            });
        }
        let deadline = match self.policy {
            StragglerPolicy::DeadlineDrop { deadline_s } => Some(deadline_s),
            _ => None,
        };
        let quorum = match self.policy {
            StragglerPolicy::Quorum { k } => Some(k),
            _ => None,
        };

        let mut q = EventQueue::new();
        let mut pipe = ops.shared_uplink_bps().map(SharedUplink::new);
        let mut server = ServerResource::new(ops.server_service_s());
        let (mut loss_sum, mut correct, mut samples, mut server_steps) = (0.0f64, 0u64, 0u64, 0u64);
        let mut queue_wait_s = 0.0f64;
        let mut done = 0usize;
        let mut close_t: Option<f64> = None;
        let mut last_t = 0.0f64;

        // Kick-off: every device starts its first local step at t = 0
        // (one thread-parallel fan-out batch).
        let all: Vec<DeviceId> = (0..n).collect();
        let ups = ops.fanout(&all)?;
        for d in 0..n {
            submit_uplink(&mut q, pipe.is_some(), ops.compute_s(d), d, 0, &ups[d]);
        }

        while let Some(ev) = q.pop() {
            // A stale drain prediction is bookkeeping noise, not network
            // activity — discard it before the deadline check so a
            // long-superseded prediction cannot close a round whose real
            // events all finished in time.
            if let (Some(p), Event::SharedDrain { generation }) = (pipe.as_ref(), ev.event) {
                if generation != p.generation() {
                    continue;
                }
            }
            if let Some(t_max) = deadline {
                if ev.time > t_max {
                    close_t = Some(t_max);
                    break;
                }
            }
            if let Some(p) = pipe.as_mut() {
                if pipe_event(p, &mut q, ops, &ev) {
                    continue;
                }
            }
            last_t = ev.time;
            match ev.event {
                Event::UplinkArrived { step } => {
                    // The uplink queues for the serial server resource;
                    // fan-in order is arrival order, service back-to-back.
                    let (start, end) = server.acquire(ev.time);
                    queue_wait_s += start - ev.time;
                    let out = ops.server_step(ev.device)?;
                    loss_sum += out.loss;
                    correct += out.correct;
                    samples += out.samples;
                    server_steps += 1;
                    q.push(end + out.downlink_s, ev.device, Event::DownlinkArrived { step });
                }
                Event::DownlinkArrived { step } => {
                    // Batch ties: downlinks landing at the bit-same instant
                    // run fan-in/fan-out through one worker-pool dispatch
                    // (homogeneous fleets stay as parallel as lockstep mode).
                    // Batch composition is event order — deterministic.
                    let mut batch: Vec<(DeviceId, usize)> = vec![(ev.device, step)];
                    loop {
                        let tie = matches!(
                            q.peek(),
                            Some(next) if matches!(next.event, Event::DownlinkArrived { .. })
                                && next.time.to_bits() == ev.time.to_bits()
                        );
                        if !tie {
                            break;
                        }
                        let nev = q.pop().expect("peeked event");
                        let Event::DownlinkArrived { step: s2 } = nev.event else {
                            unreachable!("tie check admits only downlinks")
                        };
                        batch.push((nev.device, s2));
                    }
                    let devs: Vec<DeviceId> = batch.iter().map(|&(d, _)| d).collect();
                    ops.fanin(&devs)?;
                    let continuing: Vec<(DeviceId, usize)> = batch
                        .iter()
                        .filter(|&&(_, s)| s + 1 < steps)
                        .copied()
                        .collect();
                    if !continuing.is_empty() {
                        let cont_devs: Vec<DeviceId> =
                            continuing.iter().map(|&(d, _)| d).collect();
                        let ups = ops.fanout(&cont_devs)?;
                        for (i, &(d, s)) in continuing.iter().enumerate() {
                            // fan-in compute + next fan-out compute, then
                            // the uplink (direct arrival or shared flow)
                            submit_uplink(
                                &mut q,
                                pipe.is_some(),
                                ev.time + 2.0 * ops.compute_s(d),
                                d,
                                s + 1,
                                &ups[i],
                            );
                        }
                    }
                    for &(d, s) in &batch {
                        if s + 1 == steps {
                            q.push(ev.time + ops.compute_s(d), d, Event::DeviceDone);
                        }
                    }
                }
                Event::DeviceDone => {
                    completed[ev.device] = true;
                    done += 1;
                    if let Some(k) = quorum {
                        if done >= k {
                            close_t = Some(ev.time);
                            break;
                        }
                    }
                }
                Event::UplinkStart { .. } | Event::SharedDrain { .. } => {
                    unreachable!("pipe events are consumed before dispatch")
                }
            }
        }
        q.clear();
        for (d, &c) in completed.iter().enumerate() {
            if !c {
                ops.cancel(d);
            }
        }
        Ok(RoundReport {
            loss_sum,
            correct,
            samples,
            server_steps,
            sim_round_s: close_t.unwrap_or(last_t),
            queue_wait_s,
            completed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pure-timing mock: per-device compute/uplink/downlink costs, plus an
    /// op log so tests can pin exact scheduling decisions. The contention
    /// knobs (`service_s`, `shared_bps`, per-device `bytes`/`latency`)
    /// default to the pre-contention behavior.
    struct MockOps {
        steps: usize,
        compute: Vec<f64>,
        up_s: Vec<f64>,
        down_s: Vec<f64>,
        bytes: Vec<usize>,
        latency: Vec<f64>,
        service_s: f64,
        shared_bps: Option<f64>,
        log: Vec<String>,
        cancelled: Vec<DeviceId>,
        charges: Vec<(DeviceId, u64)>,
    }

    impl MockOps {
        fn uniform(n: usize, steps: usize, c: f64, up: f64, down: f64) -> Self {
            MockOps {
                steps,
                compute: vec![c; n],
                up_s: vec![up; n],
                down_s: vec![down; n],
                bytes: vec![0; n],
                latency: vec![0.0; n],
                service_s: 0.0,
                shared_bps: None,
                log: Vec::new(),
                cancelled: Vec::new(),
                charges: Vec::new(),
            }
        }

        fn server_order(&self) -> Vec<DeviceId> {
            self.log
                .iter()
                .filter_map(|l| l.strip_prefix("server:").map(|d| d.parse().unwrap()))
                .collect()
        }
    }

    impl RoundOps for MockOps {
        fn n_devices(&self) -> usize {
            self.compute.len()
        }
        fn steps(&self) -> usize {
            self.steps
        }
        fn compute_s(&self, dev: DeviceId) -> f64 {
            self.compute[dev]
        }
        fn server_service_s(&self) -> f64 {
            self.service_s
        }
        fn shared_uplink_bps(&self) -> Option<f64> {
            self.shared_bps
        }
        fn uplink_latency_s(&self, dev: DeviceId) -> f64 {
            self.latency[dev]
        }
        fn charge_uplink(&mut self, dev: DeviceId, busy_s: f64) {
            self.charges.push((dev, busy_s.to_bits()));
        }
        fn fanout(&mut self, devs: &[DeviceId]) -> Result<Vec<UplinkMsg>> {
            self.log.push(format!("fanout:{devs:?}"));
            Ok(devs
                .iter()
                .map(|&d| UplinkMsg {
                    wire_bytes: self.bytes[d],
                    cost_s: self.up_s[d],
                })
                .collect())
        }
        fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut> {
            self.log.push(format!("server:{dev}"));
            Ok(ServerOut {
                downlink_s: self.down_s[dev],
                loss: 1.0 + dev as f64,
                correct: 1,
                samples: 2,
            })
        }
        fn fanin(&mut self, devs: &[DeviceId]) -> Result<()> {
            self.log.push(format!("fanin:{devs:?}"));
            Ok(())
        }
        fn cancel(&mut self, dev: DeviceId) {
            self.cancelled.push(dev);
        }
    }

    #[test]
    fn scheduler_kind_parses() {
        assert_eq!(SchedulerKind::parse("sync").unwrap(), SchedulerKind::Sync);
        assert_eq!(SchedulerKind::parse("ASYNC").unwrap(), SchedulerKind::Async);
        assert!(SchedulerKind::parse("warp").is_err());
        assert_eq!(SchedulerKind::Async.name(), "async");
    }

    #[test]
    fn sync_runs_lockstep_phases_in_device_order() {
        let mut ops = MockOps::uniform(2, 2, 1.0, 2.0, 4.0);
        let report = SyncEventScheduler.run_round(&mut ops).unwrap();
        assert_eq!(
            ops.log,
            vec![
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
                "fanout:[0, 1]",
                "server:0",
                "server:1",
                "fanin:[0, 1]",
            ]
        );
        assert_eq!(report.server_steps, 4);
        assert_eq!(report.completed, vec![true, true]);
        assert_eq!(report.dropped(), 0);
        // per step: fanout compute 1 + up 2 (barrier 3), down 4 + fanin 1
        // => 8 per step, 2 steps = 16 (integers: exact in f64)
        assert_eq!(report.sim_round_s, 16.0);
        // loss fold order: (1 + 2) per step-phase
        assert_eq!(report.loss_sum, 6.0);
    }

    #[test]
    fn async_server_consumes_in_arrival_order() {
        // arrival = compute + up: dev2 lands first, then dev0, then dev1
        let mut ops = MockOps {
            up_s: vec![2.0, 5.0, 0.5],
            ..MockOps::uniform(3, 1, 1.0, 0.0, 1.0)
        };
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.server_order(), vec![2, 0, 1]);
        assert_eq!(report.completed, vec![true, true, true]);
        // slowest chain: dev1 done at 1 + 5 (up) + 1 (down) + 1 (fanin) = 8
        assert_eq!(report.sim_round_s, 8.0);
        assert!(ops.cancelled.is_empty());
    }

    #[test]
    fn async_wait_all_pipeline_timing() {
        // single device, 2 steps: up@3, down@7, next up@11, down@15, done@16
        let mut ops = MockOps::uniform(1, 2, 1.0, 2.0, 4.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.server_steps, 2);
        assert_eq!(report.sim_round_s, 16.0);
        assert_eq!(report.completed, vec![true]);
    }

    #[test]
    fn async_deadline_drops_unfinished_devices() {
        let mut ops = MockOps {
            compute: vec![1.0, 10.0],
            up_s: vec![1.0, 10.0],
            down_s: vec![1.0, 10.0],
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::DeadlineDrop { deadline_s: 5.0 },
        }
        .run_round(&mut ops)
        .unwrap();
        // dev0: up@2, down@3, done@4 — inside the deadline
        // dev1: up@20 — never processed
        assert_eq!(report.completed, vec![true, false]);
        assert_eq!(report.dropped(), 1);
        assert_eq!(report.server_steps, 1, "dropped uplink never hits the server");
        assert_eq!(ops.server_order(), vec![0]);
        assert_eq!(ops.cancelled, vec![1]);
        assert_eq!(report.sim_round_s, 5.0, "round closes at the deadline");
    }

    #[test]
    fn async_deadline_everyone_drops_when_too_tight() {
        let mut ops = MockOps::uniform(3, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::DeadlineDrop { deadline_s: 1e-6 },
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.completed, vec![false; 3]);
        assert_eq!(report.server_steps, 0);
        assert_eq!(ops.cancelled, vec![0, 1, 2]);
    }

    #[test]
    fn async_quorum_closes_on_kth_completion_with_seq_ties() {
        // identical devices: completions tie at the same instant; the
        // deterministic seq order makes devices 0 and 1 the quorum
        let mut ops = MockOps::uniform(4, 1, 1.0, 1.0, 1.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::Quorum { k: 2 },
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(report.completed, vec![true, true, false, false]);
        assert_eq!(ops.cancelled, vec![2, 3]);
        // done at fanout 1 + up 1 + down 1 + fanin 1 = 4
        assert_eq!(report.sim_round_s, 4.0);
    }

    #[test]
    fn async_quorum_equal_to_n_is_wait_all() {
        let mk = || MockOps::uniform(3, 2, 0.5, 1.0, 1.0);
        let mut a = mk();
        let ra = AsyncEventScheduler {
            policy: StragglerPolicy::Quorum { k: 3 },
        }
        .run_round(&mut a)
        .unwrap();
        let mut b = mk();
        let rb = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut b)
        .unwrap();
        assert_eq!(ra.completed, rb.completed);
        assert_eq!(ra.server_steps, rb.server_steps);
        assert_eq!(ra.sim_round_s.to_bits(), rb.sim_round_s.to_bits());
        assert_eq!(a.log, b.log);
    }

    #[test]
    fn async_homogeneous_ties_batch_but_keep_server_id_order() {
        // homogeneous fleet: every uplink of a step lands at the same
        // instant, so the server sees device-id order — the property that
        // makes async wait-all match sync byte-for-byte
        let mut ops = MockOps::uniform(3, 2, 1.0, 2.0, 3.0);
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.server_order(), vec![0, 1, 2, 0, 1, 2]);
        // tie-batched fan-in/fan-out: one dispatch for all three devices
        assert!(ops.log.contains(&"fanin:[0, 1, 2]".to_string()));
        assert_eq!(report.completed, vec![true; 3]);
    }

    #[test]
    fn async_is_deterministic_across_runs() {
        let mk = || MockOps {
            compute: vec![0.25, 1.0, 0.5, 2.0],
            up_s: vec![0.125, 0.5, 2.0, 0.0625],
            down_s: vec![0.5, 0.25, 1.0, 0.125],
            ..MockOps::uniform(4, 3, 0.0, 0.0, 0.0)
        };
        let run = |policy: StragglerPolicy| {
            let mut ops = mk();
            let r = AsyncEventScheduler { policy }.run_round(&mut ops).unwrap();
            (
                ops.log.clone(),
                ops.cancelled.clone(),
                r.completed.clone(),
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.server_steps,
            )
        };
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 6.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            assert_eq!(run(policy), run(policy), "{}", policy.name());
        }
    }

    #[test]
    fn server_service_serializes_tied_arrivals_in_seq_order() {
        // homogeneous fleet, async: all three uplinks land at t=2 (tie),
        // seq order = device order; the 1 s server service then fans in
        // back-to-back at 2, 3, 4 — and queue wait is 0 + 1 + 2 = 3 s
        let mut ops = MockOps {
            service_s: 1.0,
            ..MockOps::uniform(3, 1, 1.0, 1.0, 0.5)
        };
        let report = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.server_order(), vec![0, 1, 2], "FIFO under ties");
        assert_eq!(report.queue_wait_s, 3.0);
        // dev2: service ends 5.0, downlink 0.5, fanin compute 1.0 => 6.5
        assert_eq!(report.sim_round_s, 6.5);
        assert_eq!(report.completed, vec![true; 3]);
    }

    #[test]
    fn sync_server_service_queues_after_barrier() {
        // sync, 2 devices, 1 step: barrier at 3.0, service 2 s each =>
        // dev0 waits 0, dev1 waits 2; downlinks at 5+4, 7+4
        let mut ops = MockOps {
            service_s: 2.0,
            ..MockOps::uniform(2, 1, 1.0, 2.0, 4.0)
        };
        let report = SyncEventScheduler.run_round(&mut ops).unwrap();
        assert_eq!(report.queue_wait_s, 2.0);
        // dev1 gradient lands at 7 + 4 = 11, fanin compute 1 => 12
        assert_eq!(report.sim_round_s, 12.0);
    }

    #[test]
    fn zero_service_time_reports_zero_queue_wait() {
        for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mut ops = MockOps::uniform(3, 2, 1.0, 2.0, 3.0);
            let report = build_scheduler(scheduler, StragglerPolicy::WaitAll)
                .run_round(&mut ops)
                .unwrap();
            assert_eq!(
                report.queue_wait_s.to_bits(),
                0.0f64.to_bits(),
                "{}: instantaneous server never queues",
                scheduler.name()
            );
        }
    }

    #[test]
    fn shared_uplink_single_device_is_bitwise_private() {
        // one device on the shared pipe: fair share of 1 is the whole
        // pipe, so timings must be bit-for-bit the private-link run
        let capacity = 8e6;
        let latency = 0.013;
        let bytes = 750_000usize;
        let private_cost = latency + (bytes as f64 * 8.0) / capacity;
        let run = |shared: bool| {
            let mut ops = MockOps {
                bytes: vec![bytes],
                latency: vec![latency],
                up_s: vec![if shared { 0.0 } else { private_cost }],
                shared_bps: if shared { Some(capacity) } else { None },
                ..MockOps::uniform(1, 2, 0.5, 0.0, 0.25)
            };
            let r = AsyncEventScheduler {
                policy: StragglerPolicy::WaitAll,
            }
            .run_round(&mut ops)
            .unwrap();
            (r.sim_round_s.to_bits(), r.loss_sum.to_bits(), ops.server_order())
        };
        assert_eq!(run(true), run(false), "single shared flow == private cost");
    }

    #[test]
    fn shared_uplink_concurrent_transfers_contend() {
        // two identical devices, shared pipe the size of one private
        // link: both uplinks serialize in 2x the solo time (fair share),
        // and the round is correspondingly longer than private mode
        let capacity = 8e6;
        let bytes = 1_000_000usize; // 1 s solo at 8 Mbit/s
        let solo = (bytes as f64 * 8.0) / capacity;
        let mk = |shared: bool| MockOps {
            bytes: vec![bytes; 2],
            up_s: vec![if shared { 0.0 } else { solo }; 2],
            shared_bps: if shared { Some(capacity) } else { None },
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let shared = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut mk(true))
        .unwrap();
        let private = AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut mk(false))
        .unwrap();
        assert!((private.sim_round_s - 1.0).abs() < 1e-9, "private: both in 1 s");
        assert!(
            (shared.sim_round_s - 2.0).abs() < 1e-9,
            "shared: fair-share halves the rate, got {}",
            shared.sim_round_s
        );
        assert_eq!(shared.server_steps, 2);
        assert_eq!(shared.completed, vec![true; 2]);
    }

    #[test]
    fn shared_uplink_charges_occupancy_at_drain() {
        // bytes are charged at fan-out (trainer side, charge-at-send);
        // the scheduler's hook carries only drained occupancy seconds
        let mut ops = MockOps {
            bytes: vec![1_000_000; 2],
            shared_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        AsyncEventScheduler {
            policy: StragglerPolicy::WaitAll,
        }
        .run_round(&mut ops)
        .unwrap();
        assert_eq!(ops.charges.len(), 2, "one occupancy charge per drained flow");
        for &(_, t) in &ops.charges {
            assert!((f64::from_bits(t) - 2.0).abs() < 1e-9, "each flow took 2 s fair-share");
        }
    }

    #[test]
    fn shared_uplink_works_under_sync_scheduler() {
        // sync + shared: the barrier is the last fair-share drain
        let mut ops = MockOps {
            bytes: vec![1_000_000; 2],
            shared_bps: Some(8e6),
            ..MockOps::uniform(2, 1, 0.0, 0.0, 0.0)
        };
        let report = SyncEventScheduler.run_round(&mut ops).unwrap();
        assert_eq!(ops.server_order(), vec![0, 1], "lockstep stays device-id order");
        assert!((report.sim_round_s - 2.0).abs() < 1e-9, "barrier at the 2 s drain");
        assert_eq!(report.server_steps, 2);
    }

    #[test]
    fn shared_uplink_async_deterministic_across_runs() {
        let mk = || MockOps {
            compute: vec![0.25, 1.0, 0.5, 2.0],
            down_s: vec![0.5, 0.25, 1.0, 0.125],
            bytes: vec![300_000, 1_000_000, 650_000, 125_000],
            latency: vec![0.005, 0.04, 0.005, 0.04],
            shared_bps: Some(10e6),
            service_s: 0.01,
            ..MockOps::uniform(4, 3, 0.0, 0.0, 0.0)
        };
        let run = |policy: StragglerPolicy| {
            let mut ops = mk();
            let r = AsyncEventScheduler { policy }.run_round(&mut ops).unwrap();
            (
                ops.log.clone(),
                ops.charges.clone(),
                r.completed.clone(),
                r.sim_round_s.to_bits(),
                r.queue_wait_s.to_bits(),
                r.server_steps,
            )
        };
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 4.0 },
            StragglerPolicy::Quorum { k: 2 },
        ] {
            assert_eq!(run(policy), run(policy), "{}", policy.name());
        }
    }

    #[test]
    fn build_scheduler_routes_kinds() {
        assert_eq!(
            build_scheduler(SchedulerKind::Sync, StragglerPolicy::WaitAll).name(),
            "sync"
        );
        assert_eq!(
            build_scheduler(SchedulerKind::Async, StragglerPolicy::Quorum { k: 1 }).name(),
            "async"
        );
    }
}
