//! Deterministic simulated-time event scheduler.
//!
//! The transport layer orders everything that happens "on the network" —
//! uplink starts and arrivals, shared-pipe drains, downlink arrivals,
//! device completions — through one
//! [`EventQueue`]: a binary min-heap of [`Scheduled`] entries keyed by
//! `(sim_time, seq)`. The sequence number is assigned at push time, so ties
//! at the same simulated instant resolve in **push order** — a pure
//! function of the program's deterministic control flow, never of thread
//! scheduling. This is the determinism backbone of the async round
//! scheduler: event *order* (and therefore server processing order, loss
//! fold order, and straggler decisions) is identical for every worker
//! count and every host.
//!
//! Simulated time is an `f64` in seconds. Times must be finite and are
//! compared with `f64::total_cmp`, so the ordering is total even in the
//! presence of `-0.0`. The queue clock (`now`) is monotone: it advances to
//! each popped event's time and never runs backwards.
//!
//! Besides the queue itself, this module hosts [`ServerResource`] — the
//! server modeled as a serial busy resource with a per-batch
//! `server_service_s` cost, so uplink fan-in queues deterministically
//! instead of completing instantaneously.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Index of a device in the trainer's device table.
pub type DeviceId = usize;

/// What happened at a simulated instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A device begins transmitting `bytes` of compressed activations on
    /// the **shared** uplink (for local step `step`). Only emitted in
    /// `uplink = "shared"` mode; the scheduler folds the new flow into the
    /// fair-share model ([`super::link::SharedUplink`]) when this pops.
    UplinkStart {
        /// 0-based local step within the round.
        step: usize,
        /// Exact wire bytes of the payload entering the shared pipe.
        bytes: usize,
    },
    /// The shared uplink's earliest in-flight transfer is predicted to
    /// drain at this instant, assuming the active-flow set as of
    /// `generation`. Stale generations (a flow started or finished in the
    /// meantime) are skipped on pop — the lazy-invalidation pattern that
    /// keeps fair-share recomputation inside the deterministic
    /// `(sim_time, seq)` order.
    SharedDrain {
        /// [`super::link::SharedUplink`] generation this prediction was
        /// made under.
        generation: u64,
    },
    /// A device's compressed activations finished arriving at the server
    /// (for local step `step` of the round).
    UplinkArrived {
        /// 0-based local step within the round.
        step: usize,
    },
    /// The server's (possibly compressed) gradient finished arriving at the
    /// device for local step `step`.
    DownlinkArrived {
        /// 0-based local step within the round.
        step: usize,
    },
    /// The server begins transmitting `bytes` of gradient on the **shared**
    /// downlink pipe toward this event's device. Only emitted in
    /// `downlink = "shared"` mode — the egress twin of
    /// [`Event::UplinkStart`].
    DownlinkStart {
        /// 0-based local step within the round.
        step: usize,
        /// Exact wire bytes of the gradient payload entering the pipe.
        bytes: usize,
    },
    /// Shared-downlink drain prediction — the egress twin of
    /// [`Event::SharedDrain`], with the same lazy generation invalidation.
    DownDrain {
        /// Downlink-pipe generation this prediction was made under.
        generation: u64,
    },
    /// The device finished the client-backward of its last local step —
    /// its round participation is complete.
    DeviceDone,
    /// Ack timeout fired for a lost or corrupted **uplink** copy of local
    /// step `step`: the device retransmits (with exponential backoff and
    /// seeded jitter) or, with retries exhausted, counts as dropped for
    /// the round. Only emitted by the fault-injection paths
    /// ([`super::fault::FaultPlan`]); fault-free rounds never see it.
    UplinkRetry {
        /// 0-based local step within the round.
        step: usize,
    },
    /// Ack timeout for a lost **downlink** copy — the egress twin of
    /// [`Event::UplinkRetry`], re-sent by the server.
    DownlinkRetry {
        /// 0-based local step within the round.
        step: usize,
    },
    /// Cohort-compressed uplink arrival: `len` devices' uplinks landed at
    /// this same instant. Members live at `arena[off .. off + len]` in the
    /// scheduler's round arena, **in push order** — replaying them in that
    /// order reproduces the per-device event sequence exactly (same-time
    /// per-device pushes are consecutive in seq, so no foreign event can
    /// interleave). The arena entry carries `(device, step)`.
    UplinkBatch {
        /// Start offset into the scheduler's member arena.
        off: u32,
        /// Member count.
        len: u32,
    },
    /// Cohort-compressed downlink arrival — the grouped twin of
    /// [`Event::DownlinkArrived`], same arena contract as
    /// [`Event::UplinkBatch`].
    DownlinkBatch {
        /// Start offset into the scheduler's member arena.
        off: u32,
        /// Member count.
        len: u32,
    },
    /// Cohort-compressed device completion — the grouped twin of
    /// [`Event::DeviceDone`], same arena contract as
    /// [`Event::UplinkBatch`].
    DoneBatch {
        /// Start offset into the scheduler's member arena.
        off: u32,
        /// Member count.
        len: u32,
    },
}

/// One scheduled event: `(time, seq)` is the total order.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    /// Simulated time in seconds.
    pub time: f64,
    /// Push sequence number — the deterministic tie-breaker.
    pub seq: u64,
    /// Device the event concerns.
    pub device: DeviceId,
    /// Event payload.
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic simulated-time event queue (min-heap on `(time, seq)`).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Scheduled>>,
    next_seq: u64,
    now: f64,
}

impl EventQueue {
    /// Empty queue at simulated time 0.
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Schedule `event` for `device` at absolute simulated `time`.
    /// Returns the assigned sequence number. Panics on non-finite times —
    /// a NaN deadline would silently scramble the ordering contract.
    pub fn push(&mut self, time: f64, device: DeviceId, event: Event) -> u64 {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(std::cmp::Reverse(Scheduled {
            time,
            seq,
            device,
            event,
        }));
        seq
    }

    /// Pop the earliest event (ties in push order) and advance the clock.
    pub fn pop(&mut self) -> Option<Scheduled> {
        let ev = self.heap.pop()?.0;
        if ev.time > self.now {
            self.now = ev.time;
        }
        Some(ev)
    }

    /// Earliest pending event without popping it.
    pub fn peek(&self) -> Option<&Scheduled> {
        self.heap.peek().map(|r| &r.0)
    }

    /// Current simulated time (time of the latest popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Discard all pending events (straggler policies close a round by
    /// abandoning in-flight work). The clock and seq counter keep going.
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

/// The server as a busy resource: uplinks queue for a serial, per-batch
/// service of `service_s` simulated seconds.
///
/// Service is strict FIFO in *offer order* — the order `acquire` is
/// called, which for both schedulers is the deterministic event-pop order
/// (arrival time, then push seq). A batch offered at `ready_t` starts at
/// `max(ready_t, free_t)` (the server may still be busy with an earlier
/// batch) and occupies the server for `service_s`; the difference between
/// start and `ready_t` is the **queue wait**, the congestion signal
/// surfaced as `RoundMetrics::queue_wait_s`.
///
/// With `service_s = 0` every acquire starts exactly at `ready_t` and
/// waits zero seconds — the pre-contention "infinitely fast server"
/// behavior, bit-for-bit (`x + 0.0 == x` for every non-negative time).
///
/// # Round-boundary semantics
///
/// Server busy time does **not** carry across rounds. When a straggler
/// policy closes a round early, `EventQueue::clear` abandons the in-flight
/// events — but batches already `acquire`d pushed `free_t` forward, and
/// letting that busy window leak into the next round would charge round
/// `r + 1` queue wait for work round `r` abandoned. The pinned semantics
/// are *fresh server per round*: schedulers call [`ServerResource::reset`]
/// (or construct a new resource) at every round start, so `free_t` starts
/// at 0 alongside the round's event clock. See ARCHITECTURE.md, "Fleet
/// scale".
#[derive(Debug, Default)]
pub struct ServerResource {
    /// Per-batch service cost in simulated seconds (≥ 0, finite).
    service_s: f64,
    /// Instant the server finishes its last accepted batch.
    free_t: f64,
    /// Outage window `[start, end)` during which the server accepts no
    /// work: a batch offered inside it waits until `end` (fault
    /// injection; `None` in fault-free rounds, where `acquire` is
    /// bit-identical to the pre-outage behavior).
    outage: Option<(f64, f64)>,
    /// Total time batches spent waiting out the outage window this round
    /// — surfaced as `RoundMetrics::recovery_wait_s`.
    recovery_wait_s: f64,
}

impl ServerResource {
    /// New idle server with the given per-batch service cost.
    pub fn new(service_s: f64) -> Self {
        assert!(
            service_s.is_finite() && service_s >= 0.0,
            "server service time must be finite and >= 0, got {service_s}"
        );
        ServerResource {
            service_s,
            free_t: 0.0,
            outage: None,
            recovery_wait_s: 0.0,
        }
    }

    /// Install an outage window `[start, end)` for this round: batches
    /// offered inside it pause until `end` (service resumes and the FIFO
    /// drains in offer order). `None` clears the window. Fault injection
    /// only — with no window installed `acquire` is unchanged.
    pub fn set_outage(&mut self, window: Option<(f64, f64)>) {
        if let Some((start, end)) = window {
            assert!(
                start.is_finite() && end.is_finite() && start <= end,
                "outage window must be finite and ordered, got [{start}, {end})"
            );
        }
        self.outage = window;
    }

    /// Offer one batch that became ready at `ready_t`; returns
    /// `(start_t, end_t)` of its service slot and marks the server busy
    /// until `end_t`. If the would-be start falls inside an installed
    /// outage window, service pauses until the window ends and the pause
    /// accrues to [`ServerResource::recovery_wait_s`].
    pub fn acquire(&mut self, ready_t: f64) -> (f64, f64) {
        let mut start = ready_t.max(self.free_t);
        if let Some((o_start, o_end)) = self.outage {
            if start >= o_start && start < o_end {
                self.recovery_wait_s += o_end - start;
                start = o_end;
            }
        }
        let end = start + self.service_s;
        self.free_t = end;
        (start, end)
    }

    /// Instant the server next becomes idle.
    pub fn free_t(&self) -> f64 {
        self.free_t
    }

    /// Time batches have spent paused on the outage window since the last
    /// reset.
    pub fn recovery_wait_s(&self) -> f64 {
        self.recovery_wait_s
    }

    /// Forget all accepted work: the server is idle again at t = 0, with
    /// no outage window and zeroed recovery wait. Called at round start so
    /// busy time from batches a straggler policy abandoned
    /// (`EventQueue::clear`) never leaks into the next round — the
    /// round-boundary semantics pinned in the type-level docs.
    pub fn reset(&mut self) {
        self.free_t = 0.0;
        self.outage = None;
        self.recovery_wait_s = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, 0, Event::DeviceDone);
        q.push(1.0, 1, Event::DeviceDone);
        q.push(2.0, 2, Event::DeviceDone);
        let order: Vec<DeviceId> = std::iter::from_fn(|| q.pop()).map(|e| e.device).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn ties_resolve_in_push_order() {
        let mut q = EventQueue::new();
        for d in 0..8 {
            q.push(0.5, d, Event::UplinkArrived { step: 0 });
        }
        let order: Vec<DeviceId> = std::iter::from_fn(|| q.pop()).map(|e| e.device).collect();
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_pushes_keep_total_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 0, Event::DeviceDone);
        assert_eq!(q.pop().unwrap().device, 0);
        // push an event earlier than one already consumed: clock still
        // monotone, ordering among *pending* events intact
        q.push(0.5, 1, Event::DeviceDone);
        q.push(0.5, 2, Event::DeviceDone);
        assert_eq!(q.pop().unwrap().device, 1);
        assert_eq!(q.pop().unwrap().device, 2);
        assert_eq!(q.now(), 1.0, "clock never runs backwards");
    }

    #[test]
    fn clock_advances_with_pops() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0.0);
        q.push(2.5, 0, Event::DeviceDone);
        q.push(4.0, 1, Event::DeviceDone);
        q.pop();
        assert_eq!(q.now(), 2.5);
        q.pop();
        assert_eq!(q.now(), 4.0);
    }

    #[test]
    fn peek_len_clear() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, 3, Event::DownlinkArrived { step: 2 });
        q.push(0.25, 7, Event::UplinkArrived { step: 1 });
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek().unwrap().device, 7);
        q.clear();
        assert!(q.is_empty() && q.pop().is_none());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_time_rejected() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, 0, Event::DeviceDone);
    }

    #[test]
    fn server_resource_serializes_in_offer_order() {
        let mut s = ServerResource::new(2.0);
        // three batches ready at the same instant: strict FIFO back-off
        assert_eq!(s.acquire(1.0), (1.0, 3.0));
        assert_eq!(s.acquire(1.0), (3.0, 5.0));
        assert_eq!(s.acquire(1.0), (5.0, 7.0));
        // a late batch past the busy window starts immediately
        assert_eq!(s.acquire(10.0), (10.0, 12.0));
        assert_eq!(s.free_t(), 12.0);
    }

    #[test]
    fn server_resource_zero_service_is_transparent() {
        let mut s = ServerResource::new(0.0);
        for &t in &[0.0, 0.5, 0.5, 3.25] {
            let (start, end) = s.acquire(t);
            assert_eq!(start.to_bits(), t.to_bits(), "no queue wait at zero service");
            assert_eq!(end.to_bits(), t.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "service time")]
    fn server_resource_rejects_nan_service() {
        ServerResource::new(f64::NAN);
    }

    #[test]
    fn server_outage_pauses_service_and_drains_fifo_on_recovery() {
        let mut s = ServerResource::new(1.0);
        s.set_outage(Some((2.0, 5.0)));
        // before the window: untouched
        assert_eq!(s.acquire(0.5), (0.5, 1.5));
        // lands inside the window: waits for recovery
        assert_eq!(s.acquire(3.0), (5.0, 6.0));
        // queued behind the drained batch, past the window: plain FIFO
        assert_eq!(s.acquire(3.0), (6.0, 7.0));
        assert_eq!(s.recovery_wait_s(), 2.0, "only the paused batch accrues");
        // reset clears window and counter
        s.reset();
        assert_eq!(s.recovery_wait_s(), 0.0);
        assert_eq!(s.acquire(3.0), (3.0, 4.0));
    }

    #[test]
    fn server_without_outage_is_bit_identical() {
        let offers = [0.0, 0.5, 0.5, 3.25, 2.0];
        let run = |with_clear: bool| {
            let mut s = ServerResource::new(0.25);
            if with_clear {
                s.set_outage(None);
            }
            offers
                .iter()
                .map(|&t| {
                    let (a, b) = s.acquire(t);
                    (a.to_bits(), b.to_bits())
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn server_busy_time_does_not_leak_across_rounds() {
        // Regression for the abandoned-batch leak: a round the straggler
        // policy closes early clears the event queue, but batches already
        // acquired pushed free_t far into the future. Without the
        // round-start reset, the *next* round's first batch would queue
        // behind work that was abandoned — here, 99 s of phantom wait.
        let mut q = EventQueue::new();
        let mut s = ServerResource::new(100.0);
        let (start, end) = s.acquire(1.0);
        assert_eq!((start, end), (1.0, 101.0));
        q.push(end, 0, Event::DownlinkArrived { step: 0 });
        // deadline closes the round: events abandoned, server state stale
        q.clear();
        assert_eq!(s.free_t(), 101.0, "free_t still holds the abandoned batch");
        // pinned semantics: fresh server per round
        s.reset();
        assert_eq!(s.free_t(), 0.0);
        let (start, end) = s.acquire(2.0);
        assert_eq!((start, end), (2.0, 102.0), "no phantom queue wait in round r+1");
    }

    #[test]
    fn identical_push_sequences_give_identical_pop_sequences() {
        // determinism is the whole point: the pop order is a pure function
        // of the push sequence
        let run = || {
            let mut q = EventQueue::new();
            let times = [0.5, 0.125, 0.5, 2.0, 0.125, 0.5];
            for (d, &t) in times.iter().enumerate() {
                q.push(t, d, Event::UplinkArrived { step: d });
            }
            std::iter::from_fn(move || q.pop())
                .map(|e| (e.time.to_bits(), e.seq, e.device))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
