//! Seeded fault injection: crashes, message loss, payload corruption,
//! retry backoff, and server outages — all bit-reproducible.
//!
//! Every fault decision is a **pure function of the message identity**
//! `(seed, round, device, step, attempt)` plus a draw-kind tag, derived
//! through [`crate::rng::stream::FAULT`]. Nothing is sampled from
//! scheduler control flow, thread timing, or worker count, so:
//!
//! * the same config produces the same fault pattern at `workers = 1`
//!   and `workers = N`;
//! * sync and async schedulers see the same per-message loss/corruption
//!   verdicts (their *reaction* may differ only where the schedulers'
//!   semantics differ, e.g. when downlinks are anchored at a barrier);
//! * a fault-free config ([`FaultConfig::is_active`] `== false`) draws
//!   nothing at all and leaves every legacy code path bit-identical.
//!
//! The plan object is tiny and `Copy`: schedulers grab one per round via
//! [`crate::transport::RoundOps::fault_plan`] and query it statelessly.

use crate::rng::{derive_seed, mix64, stream};
use crate::transport::DeviceId;
use anyhow::{bail, Result};

/// Draw-kind tags folded into the derive index so each decision about
/// the same message uses an independent stream.
const K_CRASH: u64 = 1;
const K_UP_LOSS: u64 = 2;
const K_DOWN_LOSS: u64 = 3;
const K_CORRUPT: u64 = 4;
const K_JITTER: u64 = 5;
const K_OUTAGE: u64 = 6;
const K_FLIP: u64 = 7;

/// Number of seeded bit flips injected into a corrupted payload body.
pub const CORRUPT_FLIPS: usize = 8;

/// Cap on the exponential-backoff shift so `retry_base_s << attempt`
/// cannot overflow; also the validation ceiling for `max_retries`.
pub const MAX_RETRIES_CAP: u32 = 32;

/// User-facing fault knobs (config/CLI keys of the same names).
///
/// All-defaults means "fault layer off": no RNG draws, no extra events,
/// no allocations — pinned by the differential tests and the transport
/// counting-allocator bench.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Per-transmission loss probability, applied independently to each
    /// uplink and downlink copy (including retransmissions).
    pub loss_prob: f64,
    /// Per-delivery probability that an uplink payload arrives with
    /// flipped bits (detected by the transport checksum on receipt).
    pub corrupt_prob: f64,
    /// Per-round probability that a device is crashed for the whole
    /// round (no compute, no bytes); it rejoins automatically the next
    /// round through the existing zero-weight FedAvg path.
    pub crash_rate: f64,
    /// Retransmissions allowed per message before the device counts as
    /// dropped for the round.
    pub max_retries: u32,
    /// Base ack-timeout; attempt `a` retries after
    /// `retry_base_s * 2^a * (1 + 0.5 * jitter)` with seeded jitter.
    pub retry_base_s: f64,
    /// Length of the per-round server outage window (0 = none). The
    /// window start is drawn uniformly in `[0, server_outage_s)`;
    /// arrivals inside it queue until recovery and the waiting time is
    /// reported as `recovery_wait_s`.
    pub server_outage_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            loss_prob: 0.0,
            corrupt_prob: 0.0,
            crash_rate: 0.0,
            max_retries: 3,
            retry_base_s: 0.05,
            server_outage_s: 0.0,
        }
    }
}

impl FaultConfig {
    /// Whether any fault mechanism is enabled. Inactive configs take the
    /// legacy scheduler paths untouched (bit-identical, draw-free).
    pub fn is_active(&self) -> bool {
        self.loss_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.crash_rate > 0.0
            || self.server_outage_s > 0.0
    }

    /// Validate ranges; errors name the offending key.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.loss_prob) {
            bail!("loss_prob must be in [0, 1], got {}", self.loss_prob);
        }
        if !(0.0..=1.0).contains(&self.corrupt_prob) {
            bail!("corrupt_prob must be in [0, 1], got {}", self.corrupt_prob);
        }
        if !(0.0..1.0).contains(&self.crash_rate) {
            bail!("crash_rate must be in [0, 1), got {}", self.crash_rate);
        }
        if self.max_retries > MAX_RETRIES_CAP {
            bail!(
                "max_retries must be <= {MAX_RETRIES_CAP}, got {}",
                self.max_retries
            );
        }
        if !self.retry_base_s.is_finite() || self.retry_base_s < 0.0 {
            bail!(
                "retry_base_s must be finite and >= 0, got {}",
                self.retry_base_s
            );
        }
        if self.is_active() && self.loss_prob > 0.0 && self.retry_base_s == 0.0 {
            bail!("retry_base_s must be > 0 when loss_prob > 0");
        }
        if !self.server_outage_s.is_finite() || self.server_outage_s < 0.0 {
            bail!(
                "server_outage_s must be finite and >= 0, got {}",
                self.server_outage_s
            );
        }
        Ok(())
    }
}

/// One round's fault plan: the config plus a round-derived seed. `Copy`
/// so `RoundOps::fault_plan()` can hand it out without borrow conflicts.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    cfg: FaultConfig,
    round_seed: u64,
}

impl FaultPlan {
    /// Plan for `round` of the experiment seeded with `seed`.
    pub fn new(cfg: FaultConfig, seed: u64, round: u64) -> FaultPlan {
        FaultPlan {
            cfg,
            round_seed: derive_seed(seed, stream::FAULT, round),
        }
    }

    /// The knobs this plan was built from.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Retransmissions allowed per message.
    pub fn max_retries(&self) -> u32 {
        self.cfg.max_retries
    }

    /// Raw 64-bit draw for `(kind, device, step, attempt)` — stateless.
    fn draw(&self, kind: u64, device: u64, step: u64, attempt: u64) -> u64 {
        let idx = mix64(device ^ mix64(step ^ mix64(attempt ^ mix64(kind))));
        derive_seed(self.round_seed, stream::FAULT, idx)
    }

    /// Uniform in [0, 1) from the top 53 bits of a draw.
    fn draw_unit(&self, kind: u64, device: u64, step: u64, attempt: u64) -> f64 {
        (self.draw(kind, device, step, attempt) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Whether `device` is crashed for this entire round.
    pub fn device_crashed(&self, device: DeviceId) -> bool {
        self.cfg.crash_rate > 0.0
            && self.draw_unit(K_CRASH, device as u64, 0, 0) < self.cfg.crash_rate
    }

    /// Whether uplink copy `attempt` of `(device, step)` is lost in flight.
    pub fn uplink_lost(&self, device: DeviceId, step: usize, attempt: u32) -> bool {
        self.cfg.loss_prob > 0.0
            && self.draw_unit(K_UP_LOSS, device as u64, step as u64, attempt as u64)
                < self.cfg.loss_prob
    }

    /// Whether downlink copy `attempt` of `(device, step)` is lost in flight.
    pub fn downlink_lost(&self, device: DeviceId, step: usize, attempt: u32) -> bool {
        self.cfg.loss_prob > 0.0
            && self.draw_unit(K_DOWN_LOSS, device as u64, step as u64, attempt as u64)
                < self.cfg.loss_prob
    }

    /// Whether uplink copy `attempt` of `(device, step)` arrives corrupted.
    pub fn uplink_corrupt(&self, device: DeviceId, step: usize, attempt: u32) -> bool {
        self.cfg.corrupt_prob > 0.0
            && self.draw_unit(K_CORRUPT, device as u64, step as u64, attempt as u64)
                < self.cfg.corrupt_prob
    }

    /// Ack-timeout before retransmitting copy `attempt`: exponential
    /// backoff with seeded jitter in [1.0, 1.5).
    pub fn backoff_s(&self, device: DeviceId, step: usize, attempt: u32) -> f64 {
        let shift = attempt.min(MAX_RETRIES_CAP);
        let base = self.cfg.retry_base_s * (1u64 << shift) as f64;
        base * (1.0 + 0.5 * self.draw_unit(K_JITTER, device as u64, step as u64, attempt as u64))
    }

    /// The server outage window for this round, if any: start drawn
    /// uniformly in `[0, server_outage_s)`, duration `server_outage_s`.
    pub fn outage_window(&self) -> Option<(f64, f64)> {
        if self.cfg.server_outage_s > 0.0 {
            let start = self.draw_unit(K_OUTAGE, 0, 0, 0) * self.cfg.server_outage_s;
            Some((start, start + self.cfg.server_outage_s))
        } else {
            None
        }
    }

    /// Bit position (within a body of `n_bits` bits) of the `i`-th seeded
    /// flip injected into corrupted copy `attempt` of `(device, step)`.
    pub fn flip_bit(
        &self,
        device: DeviceId,
        step: usize,
        attempt: u32,
        i: usize,
        n_bits: usize,
    ) -> usize {
        debug_assert!(n_bits > 0);
        (self.draw(
            K_FLIP,
            device as u64,
            step as u64,
            (attempt as u64) * (CORRUPT_FLIPS as u64) + i as u64,
        ) % n_bits as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active_cfg() -> FaultConfig {
        FaultConfig {
            loss_prob: 0.3,
            corrupt_prob: 0.2,
            crash_rate: 0.1,
            server_outage_s: 0.5,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn default_config_is_inactive_and_valid() {
        let cfg = FaultConfig::default();
        assert!(!cfg.is_active());
        cfg.validate().unwrap();
        // max_retries / retry_base_s alone do not activate the layer
        let cfg = FaultConfig {
            max_retries: 9,
            retry_base_s: 1.0,
            ..FaultConfig::default()
        };
        assert!(!cfg.is_active());
    }

    #[test]
    fn validation_errors_name_the_offending_key() {
        let cases: &[(FaultConfig, &str)] = &[
            (
                FaultConfig {
                    loss_prob: 1.5,
                    ..FaultConfig::default()
                },
                "loss_prob",
            ),
            (
                FaultConfig {
                    corrupt_prob: -0.1,
                    ..FaultConfig::default()
                },
                "corrupt_prob",
            ),
            (
                FaultConfig {
                    crash_rate: 1.0,
                    ..FaultConfig::default()
                },
                "crash_rate",
            ),
            (
                FaultConfig {
                    max_retries: 33,
                    ..FaultConfig::default()
                },
                "max_retries",
            ),
            (
                FaultConfig {
                    retry_base_s: f64::NAN,
                    ..FaultConfig::default()
                },
                "retry_base_s",
            ),
            (
                FaultConfig {
                    loss_prob: 0.1,
                    retry_base_s: 0.0,
                    ..FaultConfig::default()
                },
                "retry_base_s",
            ),
            (
                FaultConfig {
                    server_outage_s: -1.0,
                    ..FaultConfig::default()
                },
                "server_outage_s",
            ),
        ];
        for (cfg, key) in cases {
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(key), "error {err:?} should name {key}");
        }
    }

    #[test]
    fn draws_are_pure_functions_of_identity() {
        let plan = FaultPlan::new(active_cfg(), 42, 3);
        let again = FaultPlan::new(active_cfg(), 42, 3);
        for dev in 0..64 {
            for step in 0..3 {
                for attempt in 0..4 {
                    assert_eq!(
                        plan.uplink_lost(dev, step, attempt),
                        again.uplink_lost(dev, step, attempt)
                    );
                    assert_eq!(
                        plan.uplink_corrupt(dev, step, attempt),
                        again.uplink_corrupt(dev, step, attempt)
                    );
                    assert_eq!(
                        plan.backoff_s(dev, step, attempt).to_bits(),
                        again.backoff_s(dev, step, attempt).to_bits()
                    );
                }
            }
            assert_eq!(plan.device_crashed(dev), again.device_crashed(dev));
        }
        assert_eq!(
            plan.outage_window().map(|(a, b)| (a.to_bits(), b.to_bits())),
            again.outage_window().map(|(a, b)| (a.to_bits(), b.to_bits()))
        );
    }

    #[test]
    fn draw_kinds_and_identities_are_independent() {
        let plan = FaultPlan::new(active_cfg(), 7, 0);
        // Same identity, different kinds → different raw draws.
        assert_ne!(plan.draw(K_UP_LOSS, 5, 1, 2), plan.draw(K_DOWN_LOSS, 5, 1, 2));
        assert_ne!(plan.draw(K_UP_LOSS, 5, 1, 2), plan.draw(K_CORRUPT, 5, 1, 2));
        // Attempt changes the verdict stream.
        assert_ne!(plan.draw(K_UP_LOSS, 5, 1, 0), plan.draw(K_UP_LOSS, 5, 1, 1));
        // Rounds decorrelate.
        let other = FaultPlan::new(active_cfg(), 7, 1);
        assert_ne!(plan.draw(K_UP_LOSS, 5, 1, 0), other.draw(K_UP_LOSS, 5, 1, 0));
    }

    #[test]
    fn loss_rate_tracks_probability() {
        let cfg = FaultConfig {
            loss_prob: 0.25,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::new(cfg, 11, 0);
        let lost = (0..10_000).filter(|&d| plan.uplink_lost(d, 0, 0)).count();
        let rate = lost as f64 / 10_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn backoff_grows_exponentially_with_bounded_jitter() {
        let plan = FaultPlan::new(active_cfg(), 9, 2);
        let base = plan.config().retry_base_s;
        for attempt in 0..6u32 {
            let b = plan.backoff_s(3, 0, attempt);
            let nominal = base * (1u64 << attempt) as f64;
            assert!(b >= nominal && b < nominal * 1.5, "attempt={attempt} b={b}");
        }
        // The shift saturates instead of overflowing.
        assert!(plan.backoff_s(3, 0, MAX_RETRIES_CAP).is_finite());
    }

    #[test]
    fn outage_window_sits_inside_twice_its_length() {
        let plan = FaultPlan::new(active_cfg(), 13, 5);
        let (start, end) = plan.outage_window().unwrap();
        let len = plan.config().server_outage_s;
        assert!((0.0..len).contains(&start));
        assert!((end - start - len).abs() < 1e-12);
        let calm = FaultPlan::new(FaultConfig::default(), 13, 5);
        assert!(calm.outage_window().is_none());
    }

    #[test]
    fn flip_bits_stay_in_range_and_vary() {
        let plan = FaultPlan::new(active_cfg(), 21, 0);
        let n_bits = 333 * 8;
        let flips: Vec<usize> = (0..CORRUPT_FLIPS)
            .map(|i| plan.flip_bit(4, 0, 1, i, n_bits))
            .collect();
        assert!(flips.iter().all(|&p| p < n_bits));
        let distinct: std::collections::BTreeSet<_> = flips.iter().collect();
        assert!(distinct.len() > 1, "flips should not collapse: {flips:?}");
    }

    #[test]
    fn inactive_plan_never_faults() {
        let plan = FaultPlan::new(FaultConfig::default(), 99, 0);
        for dev in 0..256 {
            assert!(!plan.device_crashed(dev));
            assert!(!plan.uplink_lost(dev, 0, 0));
            assert!(!plan.downlink_lost(dev, 0, 0));
            assert!(!plan.uplink_corrupt(dev, 0, 0));
        }
        assert!(plan.outage_window().is_none());
    }
}
