//! Low-level link cost model: per-link bandwidth/latency/jitter + byte
//! accounting. This is the charge model underneath the [`super`] transport
//! API — schedulers decide *when* transfers happen (simulated-time event
//! ordering); the link decides *how long* each transfer takes and keeps
//! the books.
//!
//! The paper's testbed moves smashed data between GPUs over real links;
//! here the transfer is a function call, so communication cost is
//! *modeled*: each device↔server link has a bandwidth (bits/s), a
//! propagation latency, and optional jitter. The simulator charges every
//! payload's exact wire bytes and accumulates per-device and global
//! statistics — these numbers are what Fig. 2's x-axis ("communication
//! rounds" at a fixed per-round budget) and the comm-volume tables in
//! EXPERIMENTS.md come from.
//!
//! Time is simulated (a deterministic clock), independent of wall time, so
//! experiments reproduce exactly regardless of host load.
//!
//! # Round accounting
//!
//! Besides lifetime totals, every link tracks `round_busy_s` — transfer
//! seconds accrued since the last [`Link::begin_round`]. Per-round
//! communication makespans must come from this counter: deriving them from
//! the cumulative `busy_s` makes multi-round runs report the lifetime
//! maximum instead of the per-round critical path (the historical
//! `CommStats::makespan_s` bug).

use crate::rng::Pcg32;

/// Direction of a transfer (device→server or server→device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Device → server (activations).
    Uplink,
    /// Server → device (gradients).
    Downlink,
}

/// Configuration of one device↔server link.
#[derive(Debug, Clone, Copy)]
pub struct LinkConfig {
    /// Uplink bandwidth in bits per second.
    pub uplink_bps: f64,
    /// Downlink bandwidth in bits per second.
    pub downlink_bps: f64,
    /// One-way propagation latency in seconds.
    pub latency_s: f64,
    /// Multiplicative jitter amplitude (0 = deterministic; 0.1 ⇒ ±10%).
    pub jitter: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        // A WiFi-class edge link: 100 Mbit/s symmetric, 5 ms.
        LinkConfig {
            uplink_bps: 100e6,
            downlink_bps: 100e6,
            latency_s: 0.005,
            jitter: 0.0,
        }
    }
}

/// One simulated link with cumulative and per-round accounting.
#[derive(Debug)]
pub struct Link {
    /// Configuration.
    pub cfg: LinkConfig,
    rng: Pcg32,
    /// Total bytes sent device→server.
    pub uplink_bytes: u64,
    /// Total bytes sent server→device.
    pub downlink_bytes: u64,
    /// Total simulated transfer seconds (both directions, lifetime).
    pub busy_s: f64,
    /// Simulated transfer seconds since the last [`Link::begin_round`].
    pub round_busy_s: f64,
    /// Number of transfers.
    pub transfers: u64,
}

impl Link {
    /// New link with deterministic per-link jitter stream.
    pub fn new(cfg: LinkConfig, seed: u64) -> Self {
        Link {
            cfg,
            rng: Pcg32::new(seed, 911),
            uplink_bytes: 0,
            downlink_bytes: 0,
            busy_s: 0.0,
            round_busy_s: 0.0,
            transfers: 0,
        }
    }

    /// Start a new accounting round: resets `round_busy_s` (lifetime
    /// totals are untouched). The trainer calls this at every round start
    /// so per-round makespans come from a clean counter.
    pub fn begin_round(&mut self) {
        self.round_busy_s = 0.0;
    }

    /// Charge a transfer of `bytes` in `dir`; returns the simulated transfer
    /// time in seconds (latency + serialization, with jitter applied).
    pub fn transfer(&mut self, dir: Direction, bytes: usize) -> f64 {
        let bps = match dir {
            Direction::Uplink => self.cfg.uplink_bps,
            Direction::Downlink => self.cfg.downlink_bps,
        };
        let mut t = self.cfg.latency_s + (bytes as f64 * 8.0) / bps;
        if self.cfg.jitter > 0.0 {
            let j = 1.0 + self.cfg.jitter * (2.0 * self.rng.uniform_f64() - 1.0);
            t *= j.max(0.0);
        }
        match dir {
            Direction::Uplink => self.uplink_bytes += bytes as u64,
            Direction::Downlink => self.downlink_bytes += bytes as u64,
        }
        self.busy_s += t;
        self.round_busy_s += t;
        self.transfers += 1;
        t
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }
}

/// Aggregated communication statistics for a set of links (one per device).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    /// Sum of uplink bytes across devices.
    pub uplink_bytes: u64,
    /// Sum of downlink bytes across devices.
    pub downlink_bytes: u64,
    /// Communication makespan. Built per-round by the trainer: the sum over
    /// rounds of each round's max per-device `round_busy_s` (rounds are
    /// barriered, so the run-level makespan is the *sum* of per-round
    /// makespans — not the lifetime max of any single link, which is what
    /// this field used to report). [`CommStats::from_links`] fills it with
    /// the lifetime-max view, correct only for single-round snapshots.
    pub makespan_s: f64,
    /// Sum of busy times — total network occupancy.
    pub total_busy_s: f64,
}

impl CommStats {
    /// Gather stats from links, with `makespan_s` set to the max lifetime
    /// busy time — a **single-round snapshot** view (for multi-round runs
    /// use per-round accounting: [`CommStats::add_round_makespan`]).
    /// Accumulation is in slice order — callers that need bit-reproducible
    /// `total_busy_s` across runs must pass links in device-id order (the
    /// trainer does), never in thread completion order.
    pub fn from_links(links: &[Link]) -> Self {
        let mut s = CommStats::default();
        for l in links {
            s.accumulate(l);
            if l.busy_s > s.makespan_s {
                s.makespan_s = l.busy_s;
            }
        }
        s
    }

    /// Fold one link's byte and occupancy totals into the aggregate
    /// (order-stable f64 summation: the caller fixes the fold order, so
    /// the round engine reduces in device-id order and gets bytes *and*
    /// times bit-identical to a sequential run). Does **not** touch
    /// `makespan_s` — makespan is per-round accounting, see
    /// [`CommStats::add_round_makespan`].
    pub fn accumulate(&mut self, l: &Link) {
        self.uplink_bytes += l.uplink_bytes;
        self.downlink_bytes += l.downlink_bytes;
        self.total_busy_s += l.busy_s;
    }

    /// Fold one finished round's communication makespan (max per-device
    /// `round_busy_s` over that round) into the run-level makespan.
    pub fn add_round_makespan(&mut self, round_makespan_s: f64) {
        self.makespan_s += round_makespan_s;
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Bit-exact equality (f64 fields compared by bit pattern, so `-0.0 !=
    /// 0.0` and NaNs compare by payload — exactly what the differential
    /// determinism tests need).
    pub fn bit_eq(&self, other: &CommStats) -> bool {
        self.uplink_bytes == other.uplink_bytes
            && self.downlink_bytes == other.downlink_bytes
            && self.makespan_s.to_bits() == other.makespan_s.to_bits()
            && self.total_busy_s.to_bits() == other.total_busy_s.to_bits()
    }
}

/// Compile-time guard: links (and their RNG streams) migrate into the
/// round engine's worker threads.
#[allow(dead_code)]
fn assert_link_is_send() {
    fn is_send<T: Send>() {}
    is_send::<Link>();
    is_send::<CommStats>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 8e6, // 1 MB/s
                downlink_bps: 8e6,
                latency_s: 0.01,
                jitter: 0.0,
            },
            1,
        );
        let t = l.transfer(Direction::Uplink, 1_000_000);
        assert!((t - 1.01).abs() < 1e-9, "t={t}");
        assert_eq!(l.uplink_bytes, 1_000_000);
        assert_eq!(l.downlink_bytes, 0);
    }

    #[test]
    fn deterministic_without_jitter() {
        let mk = || Link::new(LinkConfig::default(), 42);
        let (mut a, mut b) = (mk(), mk());
        for i in 0..10 {
            assert_eq!(
                a.transfer(Direction::Uplink, 1000 * i),
                b.transfer(Direction::Uplink, 1000 * i)
            );
        }
    }

    #[test]
    fn jitter_bounded() {
        let cfg = LinkConfig {
            jitter: 0.1,
            ..Default::default()
        };
        let mut l = Link::new(cfg, 7);
        let base = cfg.latency_s + 8.0 * 1e6 / cfg.uplink_bps;
        for _ in 0..100 {
            let t = l.transfer(Direction::Uplink, 1_000_000);
            assert!(t >= base * 0.89 && t <= base * 1.11, "t={t} base={base}");
        }
    }

    #[test]
    fn round_busy_resets_but_lifetime_accumulates() {
        let mut l = Link::new(LinkConfig::default(), 5);
        l.begin_round();
        let t1 = l.transfer(Direction::Uplink, 1_000_000);
        assert_eq!(l.round_busy_s.to_bits(), t1.to_bits());
        l.begin_round();
        assert_eq!(l.round_busy_s, 0.0, "round counter must reset");
        let t2 = l.transfer(Direction::Downlink, 2_000_000);
        assert_eq!(l.round_busy_s.to_bits(), t2.to_bits());
        assert_eq!(l.busy_s.to_bits(), (t1 + t2).to_bits(), "lifetime keeps summing");
    }

    #[test]
    fn stats_aggregate_and_snapshot_makespan() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 10_000_000);
        l2.transfer(Direction::Uplink, 1_000);
        l2.transfer(Direction::Downlink, 2_000);
        let s = CommStats::from_links(&[l1, l2]);
        assert_eq!(s.uplink_bytes, 10_001_000);
        assert_eq!(s.downlink_bytes, 2_000);
        assert!(s.makespan_s < s.total_busy_s);
    }

    #[test]
    fn per_round_makespan_sums_across_rounds() {
        // the satellite fix: two rounds of (0.3s, 0.2s) round maxes must
        // report 0.5s total makespan, not the 0.5s-vs-0.4s lifetime max of
        // any one link
        let mut s = CommStats::default();
        s.add_round_makespan(0.3);
        s.add_round_makespan(0.2);
        assert!((s.makespan_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accumulate_matches_from_links_and_bit_eq() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 5_000);
        l2.transfer(Direction::Downlink, 7_000);
        let batch = CommStats::from_links(&[l1, l2]);
        // re-create the same traffic and fold incrementally
        let mut a = Link::new(LinkConfig::default(), 1);
        let mut b = Link::new(LinkConfig::default(), 2);
        a.transfer(Direction::Uplink, 5_000);
        b.transfer(Direction::Downlink, 7_000);
        let mut inc = CommStats::default();
        inc.accumulate(&a);
        inc.accumulate(&b);
        inc.makespan_s = a.busy_s.max(b.busy_s);
        assert!(batch.bit_eq(&inc));
        // any field difference breaks bit equality
        let mut other = inc.clone();
        other.total_busy_s += 1e-12;
        assert!(!inc.bit_eq(&other));
    }

    #[test]
    fn asymmetric_links() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 1e6,
                downlink_bps: 10e6,
                latency_s: 0.0,
                jitter: 0.0,
            },
            3,
        );
        let up = l.transfer(Direction::Uplink, 125_000); // 1 s at 1 Mb/s
        let down = l.transfer(Direction::Downlink, 125_000); // 0.1 s
        assert!((up - 1.0).abs() < 1e-9);
        assert!((down - 0.1).abs() < 1e-9);
    }
}
