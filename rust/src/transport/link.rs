//! Low-level link cost model: per-link bandwidth/latency/jitter + byte
//! accounting. This is the charge model underneath the [`super`] transport
//! API — schedulers decide *when* transfers happen (simulated-time event
//! ordering); the link decides *how long* each transfer takes and keeps
//! the books.
//!
//! The paper's testbed moves smashed data between GPUs over real links;
//! here the transfer is a function call, so communication cost is
//! *modeled*: each device↔server link has a bandwidth (bits/s), a
//! propagation latency, and optional jitter. The simulator charges every
//! payload's exact wire bytes and accumulates per-device and global
//! statistics — these numbers are what Fig. 2's x-axis ("communication
//! rounds" at a fixed per-round budget) and the comm-volume tables in
//! EXPERIMENTS.md come from.
//!
//! Time is simulated (a deterministic clock), independent of wall time, so
//! experiments reproduce exactly regardless of host load.
//!
//! # Uplink contention
//!
//! Links are private pipes by default ([`UplinkMode::Private`]). In
//! [`UplinkMode::Shared`] every device's uplink contends for one
//! [`SharedUplink`] pipe whose capacity concurrent transfers split fairly
//! — the fluid model the round schedulers drive through start/drain
//! events. Per-device accounting stays on the [`Link`] (via
//! [`Link::charge`]); only the *duration* computation moves to the shared
//! model.
//!
//! # Downlink contention
//!
//! Downlinks are private pipes by default ([`DownlinkMode::Private`]). In
//! [`DownlinkMode::Shared`] the server's egress is one more
//! [`SharedUplink`] instance (the fluid model is direction-agnostic: it
//! models "n flows splitting one capacity" and never inspects which way
//! the bytes move) with capacity `shared_downlink_mbps`, driven by the
//! schedulers through `DownlinkStart`/`DownDrain` events exactly as the
//! uplink pipe is driven through `UplinkStart`/`SharedDrain`. The
//! single-flow == private-cost bit-identity guarantee carries over
//! unchanged, because it is a property of the model, not of the
//! direction the bytes move.
//!
//! # Round accounting
//!
//! Besides lifetime totals, every link tracks `round_busy_s` — transfer
//! seconds accrued since the last [`Link::begin_round`]. Per-round
//! communication makespans must come from this counter: deriving them from
//! the cumulative `busy_s` makes multi-round runs report the lifetime
//! maximum instead of the per-round critical path (the historical
//! `CommStats::makespan_s` bug).

use crate::rng::Pcg32;
use anyhow::{bail, Result};

/// Direction of a transfer (device→server or server→device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Device → server (activations).
    Uplink,
    /// Server → device (gradients).
    Downlink,
}

/// Uplink contention model: does every device get its own pipe, or do
/// concurrent uplinks contend for one shared medium?
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum UplinkMode {
    /// Each device↔server uplink is an independent pipe at the device's
    /// profile bandwidth (the pre-contention behavior; default).
    #[default]
    Private,
    /// All uplinks share one pipe of `shared_uplink_mbps` capacity;
    /// concurrent transfers split it fairly ([`SharedUplink`]). Per-device
    /// propagation latency still applies per flow; per-device uplink
    /// bandwidth is ignored.
    Shared,
}

impl UplinkMode {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "private" | "per-device" => UplinkMode::Private,
            "shared" | "contended" => UplinkMode::Shared,
            other => bail!("unknown uplink mode '{other}' (private | shared)"),
        })
    }

    /// Stable display name (config key value).
    pub fn name(&self) -> &'static str {
        match self {
            UplinkMode::Private => "private",
            UplinkMode::Shared => "shared",
        }
    }
}

/// Downlink contention model: the server-egress mirror of [`UplinkMode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DownlinkMode {
    /// Each server→device downlink is an independent pipe at the device's
    /// profile bandwidth (the pre-contention behavior; default).
    #[default]
    Private,
    /// All downlinks share one server-egress pipe of
    /// `shared_downlink_mbps` capacity; concurrent transfers split it
    /// fairly (the same [`SharedUplink`] fluid model, pointed the other
    /// way). Per-device propagation latency still applies per flow;
    /// per-device downlink bandwidth is ignored.
    Shared,
}

impl DownlinkMode {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "private" | "per-device" => DownlinkMode::Private,
            "shared" | "contended" => DownlinkMode::Shared,
            other => bail!("unknown downlink mode '{other}' (private | shared)"),
        })
    }

    /// Stable display name (config key value).
    pub fn name(&self) -> &'static str {
        match self {
            DownlinkMode::Private => "private",
            DownlinkMode::Shared => "shared",
        }
    }
}

/// Configuration of one device↔server link.
#[derive(Debug, Clone, Copy)]
pub struct LinkConfig {
    /// Uplink bandwidth in bits per second.
    pub uplink_bps: f64,
    /// Downlink bandwidth in bits per second.
    pub downlink_bps: f64,
    /// One-way propagation latency in seconds.
    pub latency_s: f64,
    /// Multiplicative jitter amplitude (0 = deterministic; 0.1 ⇒ ±10%).
    pub jitter: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        // A WiFi-class edge link: 100 Mbit/s symmetric, 5 ms.
        LinkConfig {
            uplink_bps: 100e6,
            downlink_bps: 100e6,
            latency_s: 0.005,
            jitter: 0.0,
        }
    }
}

/// One simulated link with cumulative and per-round accounting.
#[derive(Debug)]
pub struct Link {
    /// Configuration.
    pub cfg: LinkConfig,
    rng: Pcg32,
    /// Total bytes sent device→server.
    pub uplink_bytes: u64,
    /// Total bytes sent server→device.
    pub downlink_bytes: u64,
    /// Total simulated transfer seconds (both directions, lifetime).
    pub busy_s: f64,
    /// Simulated transfer seconds since the last [`Link::begin_round`].
    pub round_busy_s: f64,
    /// Number of transfers.
    pub transfers: u64,
}

impl Link {
    /// New link with deterministic per-link jitter stream.
    pub fn new(cfg: LinkConfig, seed: u64) -> Self {
        Link {
            cfg,
            rng: Pcg32::new(seed, 911),
            uplink_bytes: 0,
            downlink_bytes: 0,
            busy_s: 0.0,
            round_busy_s: 0.0,
            transfers: 0,
        }
    }

    /// Start a new accounting round: resets `round_busy_s` (lifetime
    /// totals are untouched). The trainer calls this at every round start
    /// so per-round makespans come from a clean counter.
    pub fn begin_round(&mut self) {
        self.round_busy_s = 0.0;
    }

    /// Charge a transfer of `bytes` in `dir`; returns the simulated transfer
    /// time in seconds (latency + serialization, with jitter applied).
    pub fn transfer(&mut self, dir: Direction, bytes: usize) -> f64 {
        let bps = match dir {
            Direction::Uplink => self.cfg.uplink_bps,
            Direction::Downlink => self.cfg.downlink_bps,
        };
        let mut t = self.cfg.latency_s + (bytes as f64 * 8.0) / bps;
        if self.cfg.jitter > 0.0 {
            let j = 1.0 + self.cfg.jitter * (2.0 * self.rng.uniform_f64() - 1.0);
            t *= j.max(0.0);
        }
        self.charge(dir, bytes, t);
        t
    }

    /// Record a transfer whose duration was decided elsewhere (the shared
    /// uplink's fair-share model): `bytes` into the byte counters, `busy_s`
    /// into the occupancy counters. The shared-mode wire path calls this
    /// twice per transfer — `(bytes, 0.0)` at fan-out (charge-at-send,
    /// identical to the private path, so bytes count even if a deadline
    /// later abandons the flow mid-pipe) and `(0, seconds)` when the flow
    /// drains — so `busy_s` adds are exact no-ops until the duration is
    /// known.
    pub fn charge(&mut self, dir: Direction, bytes: usize, busy_s: f64) {
        match dir {
            Direction::Uplink => self.uplink_bytes += bytes as u64,
            Direction::Downlink => self.downlink_bytes += bytes as u64,
        }
        self.busy_s += busy_s;
        self.round_busy_s += busy_s;
        if bytes > 0 {
            self.transfers += 1;
        }
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Snapshot the link's mutable state for a checkpoint (taken at a
    /// round boundary, so `round_busy_s` is not captured — the next
    /// [`Link::begin_round`] resets it anyway).
    pub fn snapshot(&self) -> LinkState {
        LinkState {
            rng: self.rng.state_parts(),
            uplink_bytes: self.uplink_bytes,
            downlink_bytes: self.downlink_bytes,
            busy_s: self.busy_s,
            transfers: self.transfers,
        }
    }

    /// Restore a round-boundary snapshot taken by [`Link::snapshot`]: the
    /// jitter stream continues bit-identically and lifetime counters pick
    /// up where they left off. `round_busy_s` starts at zero, exactly as
    /// after a `begin_round` at the same boundary.
    pub fn restore(&mut self, state: &LinkState) {
        self.rng = Pcg32::from_state_parts(state.rng.0, state.rng.1);
        self.uplink_bytes = state.uplink_bytes;
        self.downlink_bytes = state.downlink_bytes;
        self.busy_s = state.busy_s;
        self.round_busy_s = 0.0;
        self.transfers = state.transfers;
    }
}

/// Serializable round-boundary snapshot of a [`Link`]'s mutable state
/// (checkpoint/resume contract; the [`LinkConfig`] itself is rebuilt from
/// the experiment config, not stored).
#[derive(Debug, Clone)]
pub struct LinkState {
    /// Jitter RNG `(state, inc)` parts.
    pub rng: (u64, u64),
    /// Lifetime uplink bytes.
    pub uplink_bytes: u64,
    /// Lifetime downlink bytes.
    pub downlink_bytes: u64,
    /// Lifetime transfer seconds.
    pub busy_s: f64,
    /// Lifetime transfer count.
    pub transfers: u64,
}

/// One in-flight transfer on the shared uplink.
#[derive(Debug, Clone)]
struct SharedFlow {
    device: usize,
    step: usize,
    bytes: usize,
    /// Per-flow propagation latency, added once on delivery.
    latency_s: f64,
    /// Instant the flow began transmitting.
    start_t: f64,
    /// Bits still to drain.
    remaining_bits: f64,
    /// Serialization seconds accumulated over past fair-share segments.
    ser_s: f64,
    /// Insertion order — the deterministic tie-break when several flows
    /// would drain at the same instant.
    seq: u64,
}

/// A transfer that finished draining from the shared uplink.
#[derive(Debug, Clone, Copy)]
pub struct CompletedFlow {
    /// Device whose uplink finished.
    pub device: usize,
    /// 0-based local step the payload belongs to.
    pub step: usize,
    /// Wire bytes transferred.
    pub bytes: usize,
    /// Instant the payload is available at the server
    /// (`start + latency + serialization`).
    pub arrival_t: f64,
    /// Total transfer seconds (latency + fair-share serialization) — what
    /// the private path's [`Link::transfer`] would have returned, under
    /// contention.
    pub busy_s: f64,
}

/// Fair-share fluid model of one shared pipe.
///
/// Named for its original (uplink) use, but direction-agnostic: the model
/// is "n concurrent flows split `capacity_bps` fairly" and never inspects
/// which way the bytes move, so the schedulers instantiate a second one
/// as the server-egress pipe in `downlink = "shared"` mode
/// ([`DownlinkMode::Shared`]).
///
/// At any instant, each of the `n` active flows drains at
/// `capacity_bps / n` bits per second. The active-flow set only changes at
/// transfer **start** and **finish** instants, which the round scheduler
/// totally orders through the event queue's `(sim_time, seq)`; between two
/// consecutive such instants every drain is linear, so each flow's
/// remaining bits — and therefore every completion time — is a pure
/// function of the event order. No wall clock, no thread scheduling.
///
/// # Protocol
///
/// The scheduler drives the model with two calls, both keyed to popped
/// events:
///
/// * [`SharedUplink::start`] — a flow begins transmitting; returns the new
///   predicted `(drain_t, generation)` to schedule as an
///   [`super::event::Event::SharedDrain`].
/// * [`SharedUplink::complete`] — a `SharedDrain` event fired; if its
///   generation is stale (the flow set changed since the prediction) it
///   returns `None` and the event is discarded. Otherwise the earliest
///   flow (minimum remaining bits, ties by insertion order) completes, the
///   survivors' remaining bits advance, and a fresh prediction is returned
///   for rescheduling.
///
/// Every mutation bumps `generation`, so at most one scheduled drain
/// prediction is ever live — the lazy-invalidation pattern that keeps the
/// heap free of retractions.
///
/// # Single-flow exactness
///
/// A flow that never shares the pipe drains in one segment of
/// `bits / capacity` seconds and is delivered at
/// `start + (latency + bits / capacity)` — operation-for-operation the
/// same f64 arithmetic as the private path (`Link::transfer` followed by
/// the scheduler's `start + cost` push), so a single device on a shared
/// uplink costs bit-for-bit what a private link does. The contention test
/// suite pins this.
#[derive(Debug)]
pub struct SharedUplink {
    capacity_bps: f64,
    flows: Vec<SharedFlow>,
    /// Fluid-state timestamp: all `remaining_bits` are exact as of this
    /// instant.
    last_t: f64,
    generation: u64,
    next_seq: u64,
}

impl SharedUplink {
    /// New idle pipe. Panics on a non-finite or non-positive capacity (the
    /// config layer validates first; this is the last line of defense
    /// against a NaN poisoning every completion time).
    pub fn new(capacity_bps: f64) -> Self {
        assert!(
            capacity_bps.is_finite() && capacity_bps > 0.0,
            "shared uplink capacity must be finite and > 0, got {capacity_bps}"
        );
        SharedUplink {
            capacity_bps,
            flows: Vec::new(),
            last_t: 0.0,
            generation: 0,
            next_seq: 0,
        }
    }

    /// Currently active flows.
    pub fn active(&self) -> usize {
        self.flows.len()
    }

    /// Current generation: a scheduled drain prediction carrying any other
    /// value is stale (the flow set changed since it was made).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Advance every active flow's drained bits to instant `t`. Instants
    /// at or before `last_t` are no-ops (an ulp-early prediction must not
    /// rewind the fluid state and double-drain a segment).
    fn advance(&mut self, t: f64) {
        if t <= self.last_t {
            return;
        }
        let dt = t - self.last_t;
        if !self.flows.is_empty() {
            let share = self.capacity_bps / self.flows.len() as f64;
            for f in &mut self.flows {
                f.remaining_bits -= dt * share;
                f.ser_s += dt;
            }
        }
        self.last_t = t;
    }

    /// Index of the flow that drains next: minimum remaining bits, ties by
    /// insertion seq (total order via `total_cmp`, mirroring the queue).
    fn next_idx(&self) -> Option<usize> {
        self.flows
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.remaining_bits
                    .total_cmp(&b.remaining_bits)
                    .then(a.seq.cmp(&b.seq))
            })
            .map(|(i, _)| i)
    }

    /// Predicted instant the next flow drains, assuming no further starts.
    fn predict(&self) -> Option<f64> {
        let i = self.next_idx()?;
        let n = self.flows.len() as f64;
        Some(self.last_t + self.flows[i].remaining_bits * n / self.capacity_bps)
    }

    /// A flow begins transmitting `bytes` at instant `t`. Returns the new
    /// `(drain_t, generation)` prediction to schedule (always `Some`: the
    /// pipe now has at least this flow).
    pub fn start(
        &mut self,
        t: f64,
        device: usize,
        step: usize,
        bytes: usize,
        latency_s: f64,
    ) -> (f64, u64) {
        self.advance(t);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.flows.push(SharedFlow {
            device,
            step,
            bytes,
            latency_s,
            start_t: t,
            remaining_bits: bytes as f64 * 8.0,
            ser_s: 0.0,
            seq,
        });
        self.generation += 1;
        (self.predict().expect("just pushed a flow"), self.generation)
    }

    /// A scheduled drain prediction fired. Stale generation ⇒ `None`
    /// (discard the event). Otherwise returns the completed flow plus, if
    /// flows remain, the next `(drain_t, generation)` to schedule.
    pub fn complete(&mut self, generation: u64) -> Option<(CompletedFlow, Option<(f64, u64)>)> {
        if generation != self.generation {
            return None;
        }
        let i = self.next_idx().expect("live generation implies a flow");
        let n = self.flows.len() as f64;
        // The final segment's length, recomputed with the exact expression
        // the prediction used — never `event_time - last_t`, whose f64
        // rounding would leak into the delivered duration. Clamped at zero
        // for the ulp-negative residue a same-instant start can leave on
        // an already-drained flow (`max` returns the positive value
        // unchanged, so the normal path is bit-exact).
        let dt = (self.flows[i].remaining_bits * n / self.capacity_bps).max(0.0);
        let share = self.capacity_bps / n;
        for f in &mut self.flows {
            f.remaining_bits -= dt * share;
            f.ser_s += dt;
        }
        self.last_t += dt;
        let f = self.flows.remove(i);
        self.generation += 1;
        let busy_s = f.latency_s + f.ser_s;
        let done = CompletedFlow {
            device: f.device,
            step: f.step,
            bytes: f.bytes,
            arrival_t: f.start_t + busy_s,
            busy_s,
        };
        let next = self.predict().map(|t| (t, self.generation));
        Some((done, next))
    }
}

/// Aggregated communication statistics for a set of links (one per device).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    /// Sum of uplink bytes across devices.
    pub uplink_bytes: u64,
    /// Sum of downlink bytes across devices.
    pub downlink_bytes: u64,
    /// Communication makespan. Built per-round by the trainer: the sum over
    /// rounds of each round's max per-device `round_busy_s` (rounds are
    /// barriered, so the run-level makespan is the *sum* of per-round
    /// makespans — not the lifetime max of any single link, which is what
    /// this field used to report). [`CommStats::from_links`] fills it with
    /// the lifetime-max view, correct only for single-round snapshots.
    pub makespan_s: f64,
    /// Sum of busy times — total network occupancy.
    pub total_busy_s: f64,
}

impl CommStats {
    /// Gather stats from links, with `makespan_s` set to the max lifetime
    /// busy time — a **single-round snapshot** view (for multi-round runs
    /// use per-round accounting: [`CommStats::add_round_makespan`]).
    /// Accumulation is in slice order — callers that need bit-reproducible
    /// `total_busy_s` across runs must pass links in device-id order (the
    /// trainer does), never in thread completion order.
    pub fn from_links(links: &[Link]) -> Self {
        let mut s = CommStats::default();
        for l in links {
            s.accumulate(l);
            if l.busy_s > s.makespan_s {
                s.makespan_s = l.busy_s;
            }
        }
        s
    }

    /// Fold one link's byte and occupancy totals into the aggregate
    /// (order-stable f64 summation: the caller fixes the fold order, so
    /// the round engine reduces in device-id order and gets bytes *and*
    /// times bit-identical to a sequential run). Does **not** touch
    /// `makespan_s` — makespan is per-round accounting, see
    /// [`CommStats::add_round_makespan`].
    pub fn accumulate(&mut self, l: &Link) {
        self.uplink_bytes += l.uplink_bytes;
        self.downlink_bytes += l.downlink_bytes;
        self.total_busy_s += l.busy_s;
    }

    /// Fold one finished round's communication makespan (max per-device
    /// `round_busy_s` over that round) into the run-level makespan.
    pub fn add_round_makespan(&mut self, round_makespan_s: f64) {
        self.makespan_s += round_makespan_s;
    }

    /// Total bytes both directions.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Bit-exact equality (f64 fields compared by bit pattern, so `-0.0 !=
    /// 0.0` and NaNs compare by payload — exactly what the differential
    /// determinism tests need).
    pub fn bit_eq(&self, other: &CommStats) -> bool {
        self.uplink_bytes == other.uplink_bytes
            && self.downlink_bytes == other.downlink_bytes
            && self.makespan_s.to_bits() == other.makespan_s.to_bits()
            && self.total_busy_s.to_bits() == other.total_busy_s.to_bits()
    }
}

/// Compile-time guard: links (and their RNG streams) migrate into the
/// round engine's worker threads.
#[allow(dead_code)]
fn assert_link_is_send() {
    fn is_send<T: Send>() {}
    is_send::<Link>();
    is_send::<CommStats>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 8e6, // 1 MB/s
                downlink_bps: 8e6,
                latency_s: 0.01,
                jitter: 0.0,
            },
            1,
        );
        let t = l.transfer(Direction::Uplink, 1_000_000);
        assert!((t - 1.01).abs() < 1e-9, "t={t}");
        assert_eq!(l.uplink_bytes, 1_000_000);
        assert_eq!(l.downlink_bytes, 0);
    }

    #[test]
    fn deterministic_without_jitter() {
        let mk = || Link::new(LinkConfig::default(), 42);
        let (mut a, mut b) = (mk(), mk());
        for i in 0..10 {
            assert_eq!(
                a.transfer(Direction::Uplink, 1000 * i),
                b.transfer(Direction::Uplink, 1000 * i)
            );
        }
    }

    #[test]
    fn jitter_bounded() {
        let cfg = LinkConfig {
            jitter: 0.1,
            ..Default::default()
        };
        let mut l = Link::new(cfg, 7);
        let base = cfg.latency_s + 8.0 * 1e6 / cfg.uplink_bps;
        for _ in 0..100 {
            let t = l.transfer(Direction::Uplink, 1_000_000);
            assert!(t >= base * 0.89 && t <= base * 1.11, "t={t} base={base}");
        }
    }

    #[test]
    fn round_busy_resets_but_lifetime_accumulates() {
        let mut l = Link::new(LinkConfig::default(), 5);
        l.begin_round();
        let t1 = l.transfer(Direction::Uplink, 1_000_000);
        assert_eq!(l.round_busy_s.to_bits(), t1.to_bits());
        l.begin_round();
        assert_eq!(l.round_busy_s, 0.0, "round counter must reset");
        let t2 = l.transfer(Direction::Downlink, 2_000_000);
        assert_eq!(l.round_busy_s.to_bits(), t2.to_bits());
        assert_eq!(l.busy_s.to_bits(), (t1 + t2).to_bits(), "lifetime keeps summing");
    }

    #[test]
    fn snapshot_restore_continues_jitter_and_counters_bit_identically() {
        let cfg = LinkConfig {
            jitter: 0.2,
            ..Default::default()
        };
        let mut a = Link::new(cfg, 33);
        a.begin_round();
        a.transfer(Direction::Uplink, 1_000_000);
        a.transfer(Direction::Downlink, 500_000);
        // round boundary: snapshot a, restore into a fresh link
        let snap = a.snapshot();
        let mut b = Link::new(cfg, 33);
        b.restore(&snap);
        assert_eq!(b.uplink_bytes, a.uplink_bytes);
        assert_eq!(b.downlink_bytes, a.downlink_bytes);
        assert_eq!(b.busy_s.to_bits(), a.busy_s.to_bits());
        assert_eq!(b.transfers, a.transfers);
        assert_eq!(b.round_busy_s, 0.0, "round counter starts clean");
        a.begin_round();
        for i in 1..20 {
            let ta = a.transfer(Direction::Uplink, 10_000 * i);
            let tb = b.transfer(Direction::Uplink, 10_000 * i);
            assert_eq!(ta.to_bits(), tb.to_bits(), "jitter stream continues");
        }
    }

    #[test]
    fn stats_aggregate_and_snapshot_makespan() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 10_000_000);
        l2.transfer(Direction::Uplink, 1_000);
        l2.transfer(Direction::Downlink, 2_000);
        let s = CommStats::from_links(&[l1, l2]);
        assert_eq!(s.uplink_bytes, 10_001_000);
        assert_eq!(s.downlink_bytes, 2_000);
        assert!(s.makespan_s < s.total_busy_s);
    }

    #[test]
    fn per_round_makespan_sums_across_rounds() {
        // the satellite fix: two rounds of (0.3s, 0.2s) round maxes must
        // report 0.5s total makespan, not the 0.5s-vs-0.4s lifetime max of
        // any one link
        let mut s = CommStats::default();
        s.add_round_makespan(0.3);
        s.add_round_makespan(0.2);
        assert!((s.makespan_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accumulate_matches_from_links_and_bit_eq() {
        let mut l1 = Link::new(LinkConfig::default(), 1);
        let mut l2 = Link::new(LinkConfig::default(), 2);
        l1.transfer(Direction::Uplink, 5_000);
        l2.transfer(Direction::Downlink, 7_000);
        let batch = CommStats::from_links(&[l1, l2]);
        // re-create the same traffic and fold incrementally
        let mut a = Link::new(LinkConfig::default(), 1);
        let mut b = Link::new(LinkConfig::default(), 2);
        a.transfer(Direction::Uplink, 5_000);
        b.transfer(Direction::Downlink, 7_000);
        let mut inc = CommStats::default();
        inc.accumulate(&a);
        inc.accumulate(&b);
        inc.makespan_s = a.busy_s.max(b.busy_s);
        assert!(batch.bit_eq(&inc));
        // any field difference breaks bit equality
        let mut other = inc.clone();
        other.total_busy_s += 1e-12;
        assert!(!inc.bit_eq(&other));
    }

    #[test]
    fn uplink_mode_parses_and_names() {
        assert_eq!(UplinkMode::parse("private").unwrap(), UplinkMode::Private);
        assert_eq!(UplinkMode::parse("SHARED").unwrap(), UplinkMode::Shared);
        assert_eq!(UplinkMode::parse("contended").unwrap(), UplinkMode::Shared);
        assert!(UplinkMode::parse("token-ring").is_err());
        assert_eq!(UplinkMode::default(), UplinkMode::Private);
        for m in [UplinkMode::Private, UplinkMode::Shared] {
            assert_eq!(UplinkMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn downlink_mode_parses_and_names() {
        assert_eq!(DownlinkMode::parse("private").unwrap(), DownlinkMode::Private);
        assert_eq!(DownlinkMode::parse("SHARED").unwrap(), DownlinkMode::Shared);
        assert_eq!(DownlinkMode::parse("per-device").unwrap(), DownlinkMode::Private);
        assert_eq!(DownlinkMode::parse("contended").unwrap(), DownlinkMode::Shared);
        assert!(DownlinkMode::parse("broadcast-tree").is_err());
        assert_eq!(DownlinkMode::default(), DownlinkMode::Private);
        for m in [DownlinkMode::Private, DownlinkMode::Shared] {
            assert_eq!(DownlinkMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn shared_pipe_as_downlink_single_flow_is_bitwise_private_cost() {
        // the same fluid model serves as the server-egress pipe; a lone
        // downlink flow must cost bit-for-bit what Link::transfer charges
        // in the Downlink direction
        let cfg = LinkConfig {
            uplink_bps: 40e6,
            downlink_bps: 16e6,
            latency_s: 0.007,
            jitter: 0.0,
        };
        let mut private = Link::new(cfg, 9);
        let want = private.transfer(Direction::Downlink, 321_017);
        let mut pipe = SharedUplink::new(cfg.downlink_bps);
        let (_t_drain, gen) = pipe.start(1.5, 5, 2, 321_017, cfg.latency_s);
        let (done, next) = pipe.complete(gen).expect("live generation");
        assert!(next.is_none(), "pipe drained");
        assert_eq!(done.busy_s.to_bits(), want.to_bits(), "single flow == private cost");
        assert_eq!(done.arrival_t.to_bits(), (1.5 + want).to_bits());
    }

    #[test]
    fn charge_matches_transfer_accounting() {
        let cfg = LinkConfig {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
            latency_s: 0.01,
            jitter: 0.0,
        };
        let mut via_transfer = Link::new(cfg, 1);
        let t = via_transfer.transfer(Direction::Uplink, 1_000_000);
        let mut via_charge = Link::new(cfg, 1);
        via_charge.charge(Direction::Uplink, 1_000_000, 0.0);
        via_charge.charge(Direction::Uplink, 0, t);
        assert_eq!(via_charge.uplink_bytes, via_transfer.uplink_bytes);
        assert_eq!(via_charge.transfers, via_transfer.transfers, "split charge counts once");
        assert_eq!(via_charge.busy_s.to_bits(), via_transfer.busy_s.to_bits());
        assert_eq!(
            via_charge.round_busy_s.to_bits(),
            via_transfer.round_busy_s.to_bits()
        );
    }

    #[test]
    fn shared_single_flow_is_bitwise_private_cost() {
        // one flow never shares the pipe: its delivered cost must be the
        // exact f64 arithmetic of Link::transfer (latency + bits/capacity)
        let cfg = LinkConfig {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
            latency_s: 0.013,
            jitter: 0.0,
        };
        let mut private = Link::new(cfg, 1);
        let want = private.transfer(Direction::Uplink, 777_001);
        let mut pipe = SharedUplink::new(cfg.uplink_bps);
        let (t_drain, gen) = pipe.start(0.25, 3, 0, 777_001, cfg.latency_s);
        let (done, next) = pipe.complete(gen).expect("live generation");
        assert!(next.is_none(), "pipe drained");
        assert_eq!(done.device, 3);
        assert_eq!(done.bytes, 777_001);
        assert_eq!(done.busy_s.to_bits(), want.to_bits(), "single flow == private cost");
        assert_eq!(done.arrival_t.to_bits(), (0.25 + want).to_bits());
        assert!(t_drain <= done.arrival_t, "drain precedes delivery (latency)");
    }

    #[test]
    fn shared_concurrent_flows_split_capacity_fairly() {
        // two equal flows from t=0 on a 1 MB/s pipe: each serializes in
        // 2 s (half capacity), not the 1 s a private pipe would take
        let mut pipe = SharedUplink::new(8e6);
        let (_stale, _g1) = pipe.start(0.0, 0, 0, 1_000_000, 0.0);
        let (t2, g2) = pipe.start(0.0, 1, 0, 1_000_000, 0.0);
        assert_eq!(pipe.active(), 2);
        assert!((t2 - 2.0).abs() < 1e-12, "both finish at 2 s, got {t2}");
        assert!(pipe.complete(_g1).is_none(), "stale generation discarded");
        let (first, next) = pipe.complete(g2).expect("live");
        assert_eq!(first.device, 0, "equal remaining ties resolve by insertion order");
        assert!((first.busy_s - 2.0).abs() < 1e-12);
        let (t3, g3) = next.expect("one flow left");
        assert!((t3 - 2.0).abs() < 1e-9, "second drains at the same instant");
        let (second, none) = pipe.complete(g3).expect("live");
        assert_eq!(second.device, 1);
        assert!((second.busy_s - 2.0).abs() < 1e-9);
        assert!(none.is_none());
    }

    #[test]
    fn shared_unequal_flows_release_capacity_on_finish() {
        // A: 1 MB, B: 2 MB, both from t=0 on 1 MB/s. Fair share: A done at
        // 2 s; B then gets the full pipe and finishes at 3 s.
        let mut pipe = SharedUplink::new(8e6);
        pipe.start(0.0, 0, 0, 1_000_000, 0.0);
        let (ta, ga) = pipe.start(0.0, 1, 0, 2_000_000, 0.0);
        assert!((ta - 2.0).abs() < 1e-12);
        let (a, next) = pipe.complete(ga).expect("live");
        assert_eq!(a.device, 0);
        let (tb, gb) = next.expect("B still draining");
        assert!((tb - 3.0).abs() < 1e-9, "B finishes at 3 s, got {tb}");
        let (b, _) = pipe.complete(gb).expect("live");
        assert_eq!(b.device, 1);
        assert!((b.busy_s - 3.0).abs() < 1e-9, "B occupied the pipe 3 s total");
    }

    #[test]
    fn shared_late_joiner_slows_the_leader() {
        // A (1 MB) starts at 0; B (1 MB) joins at 0.5 s. A drained 0.5 MB
        // alone, shares the rest: done at 0.5 + 1.0/1 ... fair share from
        // 0.5 with 0.5 MB left at 0.5 MB/s => +1.0 s => 1.5 s total.
        let mut pipe = SharedUplink::new(8e6);
        pipe.start(0.0, 0, 0, 1_000_000, 0.0);
        let (ta, ga) = pipe.start(0.5, 1, 0, 1_000_000, 0.0);
        assert!((ta - 1.5).abs() < 1e-9, "leader at 1.5 s, got {ta}");
        let (a, next) = pipe.complete(ga).expect("live");
        assert_eq!(a.device, 0);
        assert!((a.busy_s - 1.5).abs() < 1e-9);
        // B: 0.5 MB drained while sharing, 0.5 MB at full rate => 2.0 s
        let (tb, gb) = next.expect("B remains");
        assert!((tb - 2.0).abs() < 1e-9, "B done at 2.0 s, got {tb}");
        let (b, _) = pipe.complete(gb).expect("live");
        assert!((b.busy_s - 1.5).abs() < 1e-9, "B transmitted from 0.5 to 2.0");
        assert!((b.arrival_t - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn shared_rejects_zero_capacity() {
        SharedUplink::new(0.0);
    }

    #[test]
    fn asymmetric_links() {
        let mut l = Link::new(
            LinkConfig {
                uplink_bps: 1e6,
                downlink_bps: 10e6,
                latency_s: 0.0,
                jitter: 0.0,
            },
            3,
        );
        let up = l.transfer(Direction::Uplink, 125_000); // 1 s at 1 Mb/s
        let down = l.transfer(Direction::Downlink, 125_000); // 0.1 s
        assert!((up - 1.0).abs() < 1e-9);
        assert!((down - 0.1).abs() < 1e-9);
    }
}
