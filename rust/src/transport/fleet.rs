//! Training-free round harness for fleet-scale simulation.
//!
//! [`FleetOps`] implements [`RoundOps`] over compact per-cohort cost
//! tables instead of real devices: device `d` takes its compute time,
//! transfer costs, and wire sizes from cohort `d % k`. No model state, no
//! codec, no links — just the exact numbers the schedulers consume. That
//! makes it the harness of choice for
//!
//! * the `SLFAC_BENCH_ONLY=fleet` bench, which drives rounds at 10k /
//!   100k / 1M devices (a [`FleetOps`] is a few vectors, so a
//!   million-device fleet costs megabytes, not gigabytes), and
//! * the fleet equivalence tests, which run the *same* ops instance
//!   through the cohort-compressed and per-device scheduler paths and
//!   demand bit-identical [`RoundReport`]s and byte counters.
//!
//! Losses are a pure function of the device id, so the report's
//! `loss_sum` — an order-dependent f64 fold — pins the server processing
//! *order*, not just the set of processed steps.
//!
//! [`RoundReport`]: super::scheduler::RoundReport

use super::fault::FaultPlan;
use super::scheduler::{RoundOps, ServerOut, UplinkMsg};
use super::DeviceId;
use anyhow::Result;

/// Per-cohort simulation costs (everything [`RoundOps`] reports about a
/// device, keyed by `device % cohorts`).
#[derive(Debug, Clone, Copy)]
pub struct FleetCohort {
    /// Simulated seconds per fan-out / fan-in compute phase.
    pub compute_s: f64,
    /// Private-uplink transfer seconds per step.
    pub uplink_cost_s: f64,
    /// Private-downlink transfer seconds per step.
    pub downlink_s: f64,
    /// Uplink payload wire bytes per step.
    pub uplink_bytes: usize,
    /// Downlink payload wire bytes per step.
    pub downlink_bytes: usize,
}

impl Default for FleetCohort {
    fn default() -> Self {
        FleetCohort {
            compute_s: 0.002,
            uplink_cost_s: 0.010,
            downlink_s: 0.005,
            uplink_bytes: 12_000,
            downlink_bytes: 6_000,
        }
    }
}

/// A synthetic fleet: `devices` devices cycling through a short table of
/// [`FleetCohort`] cost profiles (the same round-robin assignment
/// [`super::profile::assign_profiles`] uses, so `cohorts` matches the
/// number of distinct profiles exactly).
#[derive(Debug, Clone)]
pub struct FleetOps {
    devices: usize,
    steps: usize,
    server_service_s: f64,
    /// What [`RoundOps::cohorts`] reports: `0` keeps the schedulers on
    /// their per-device paths, any positive value switches them to the
    /// cohort-compressed paths (bit-identical either way).
    cohorts: usize,
    profiles: Vec<FleetCohort>,
    /// Optional fault plan the schedulers pick up via
    /// [`RoundOps::fault_plan`] (faulty rounds always run per-device).
    fault: Option<FaultPlan>,
    /// Fan-out messages produced (one per device per step dispatched).
    pub fanout_msgs: u64,
    /// Server steps executed.
    pub server_steps: u64,
    /// Fan-in completions.
    pub fanin_msgs: u64,
    /// Devices cancelled by the straggler policy.
    pub cancelled: u64,
    /// Total uplink payload bytes put on the wire.
    pub uplink_bytes_total: u64,
    /// Total downlink payload bytes put on the wire.
    pub downlink_bytes_total: u64,
}

impl FleetOps {
    /// A fleet cycling through the given cost profiles (`device %
    /// profiles.len()`). Starts on the per-device scheduler paths; opt
    /// into cohort compression with [`FleetOps::set_cohorts`].
    pub fn new(devices: usize, steps: usize, profiles: Vec<FleetCohort>) -> Self {
        assert!(!profiles.is_empty(), "a fleet needs at least one cohort profile");
        FleetOps {
            devices,
            steps,
            server_service_s: 0.0,
            cohorts: 0,
            profiles,
            fault: None,
            fanout_msgs: 0,
            server_steps: 0,
            fanin_msgs: 0,
            cancelled: 0,
            uplink_bytes_total: 0,
            downlink_bytes_total: 0,
        }
    }

    /// A single-profile (homogeneous) fleet with the default costs.
    pub fn homogeneous(devices: usize, steps: usize) -> Self {
        FleetOps::new(devices, steps, vec![FleetCohort::default()])
    }

    /// Select the scheduler path: `0` = per-device, `> 0` = cohort-compressed
    /// (the value sizes the event-grouping table; the natural choice is
    /// the profile count).
    pub fn set_cohorts(&mut self, cohorts: usize) {
        self.cohorts = cohorts;
    }

    /// Serial server occupancy per batch (default `0.0`).
    pub fn set_server_service_s(&mut self, s: f64) {
        self.server_service_s = s;
    }

    /// Arm (or disarm) seeded fault injection for subsequent rounds.
    pub fn set_fault(&mut self, plan: Option<FaultPlan>) {
        self.fault = plan;
    }

    /// Zero the dispatch/byte counters (reports stay comparable across
    /// repeated rounds on one instance).
    pub fn reset_counters(&mut self) {
        self.fanout_msgs = 0;
        self.server_steps = 0;
        self.fanin_msgs = 0;
        self.cancelled = 0;
        self.uplink_bytes_total = 0;
        self.downlink_bytes_total = 0;
    }

    /// The counter snapshot the equivalence tests compare.
    pub fn counters(&self) -> (u64, u64, u64, u64, u64, u64) {
        (
            self.fanout_msgs,
            self.server_steps,
            self.fanin_msgs,
            self.cancelled,
            self.uplink_bytes_total,
            self.downlink_bytes_total,
        )
    }

    fn profile(&self, dev: DeviceId) -> &FleetCohort {
        &self.profiles[dev % self.profiles.len()]
    }
}

impl RoundOps for FleetOps {
    fn n_devices(&self) -> usize {
        self.devices
    }

    fn steps(&self) -> usize {
        self.steps
    }

    fn compute_s(&self, dev: DeviceId) -> f64 {
        self.profile(dev).compute_s
    }

    fn server_service_s(&self) -> f64 {
        self.server_service_s
    }

    fn cohorts(&self) -> usize {
        self.cohorts
    }

    fn fanout(&mut self, devs: &[DeviceId], out: &mut Vec<UplinkMsg>) -> Result<()> {
        out.clear();
        for &d in devs {
            let p = self.profiles[d % self.profiles.len()];
            out.push(UplinkMsg {
                wire_bytes: p.uplink_bytes,
                cost_s: p.uplink_cost_s,
            });
            self.uplink_bytes_total += p.uplink_bytes as u64;
        }
        self.fanout_msgs += devs.len() as u64;
        Ok(())
    }

    fn server_step(&mut self, dev: DeviceId) -> Result<ServerOut> {
        let p = *self.profile(dev);
        self.server_steps += 1;
        self.downlink_bytes_total += p.downlink_bytes as u64;
        Ok(ServerOut {
            downlink_s: p.downlink_s,
            wire_bytes: p.downlink_bytes,
            // device-dependent loss: the report's f64 fold pins the
            // server processing order
            loss: 1.0 + (dev % 1021) as f64 * 1e-3,
            correct: (dev % 3 == 0) as u64,
            samples: 1,
        })
    }

    fn fanin(&mut self, devs: &[DeviceId]) -> Result<()> {
        self.fanin_msgs += devs.len() as u64;
        Ok(())
    }

    fn cancel(&mut self, _dev: DeviceId) {
        self.cancelled += 1;
    }

    fn fault_plan(&self) -> Option<FaultPlan> {
        self.fault
    }

    fn charge_retransmit_uplink(&mut self, dev: DeviceId, _bytes: usize, _busy_s: f64) {
        self.uplink_bytes_total += self.profile(dev).uplink_bytes as u64;
    }

    fn charge_retransmit_downlink(&mut self, dev: DeviceId, _bytes: usize, _busy_s: f64) {
        self.downlink_bytes_total += self.profile(dev).downlink_bytes as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::super::scheduler::{AsyncEventScheduler, RoundScheduler, SyncEventScheduler};
    use super::super::StragglerPolicy;
    use super::*;

    fn het(devices: usize, steps: usize) -> FleetOps {
        FleetOps::new(
            devices,
            steps,
            vec![
                FleetCohort {
                    compute_s: 0.001,
                    uplink_cost_s: 0.008,
                    downlink_s: 0.004,
                    uplink_bytes: 10_000,
                    downlink_bytes: 5_000,
                },
                FleetCohort {
                    compute_s: 0.004,
                    uplink_cost_s: 0.030,
                    downlink_s: 0.015,
                    uplink_bytes: 40_000,
                    downlink_bytes: 20_000,
                },
            ],
        )
    }

    #[test]
    fn cohort_and_per_device_paths_agree_bitwise() {
        let run = |sched: &dyn RoundScheduler, cohorts: usize| {
            let mut ops = het(48, 3);
            ops.set_cohorts(cohorts);
            ops.set_server_service_s(0.0005);
            let r = sched.run_round(&mut ops).unwrap();
            (
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.queue_wait_s.to_bits(),
                r.server_steps,
                r.completed,
                r.n_devices,
                ops.counters(),
            )
        };
        let sync = SyncEventScheduler::new();
        assert_eq!(run(&sync, 2), run(&sync, 0));
        for policy in [
            StragglerPolicy::WaitAll,
            StragglerPolicy::DeadlineDrop { deadline_s: 0.08 },
            StragglerPolicy::Quorum { k: 30 },
        ] {
            let a = AsyncEventScheduler::new(policy);
            assert_eq!(run(&a, 2), run(&a, 0), "policy {policy:?}");
        }
    }

    #[test]
    fn faulty_fleet_rounds_are_deterministic_and_charge_retransmits() {
        use super::super::fault::FaultConfig;
        let fc = FaultConfig {
            loss_prob: 0.2,
            corrupt_prob: 0.1,
            crash_rate: 0.1,
            ..Default::default()
        };
        // a seed whose plan loses at least one surviving device's first
        // uplink, so the round must retransmit
        let seed = (0..1000u64)
            .find(|&s| {
                let p = FaultPlan::new(fc, s, 0);
                (0..48).any(|d| !p.device_crashed(d) && p.uplink_lost(d, 0, 0))
            })
            .expect("no lossy seed in 1000 candidates");
        let run = |sched: &dyn RoundScheduler| {
            let mut ops = het(48, 2);
            ops.set_fault(Some(FaultPlan::new(fc, seed, 0)));
            let r = sched.run_round(&mut ops).unwrap();
            (
                r.loss_sum.to_bits(),
                r.sim_round_s.to_bits(),
                r.retransmits,
                r.lost_bytes,
                r.corrupt_payloads,
                r.completed,
                ops.counters(),
            )
        };
        let sync = SyncEventScheduler::new();
        let asy = AsyncEventScheduler::new(StragglerPolicy::WaitAll);
        for sched in [&sync as &dyn RoundScheduler, &asy] {
            let a = run(sched);
            assert_eq!(a, run(sched), "faulty fleet round must be reproducible");
            assert!(a.2 > 0, "seed {seed} must force a retransmission");
            assert!(a.3 > 0, "lost bytes accounted");
        }
    }

    #[test]
    fn counters_track_a_full_round() {
        let mut ops = FleetOps::homogeneous(10, 2);
        ops.set_cohorts(1);
        let sched = SyncEventScheduler::new();
        let r = sched.run_round(&mut ops).unwrap();
        assert_eq!(r.completed, 10);
        assert_eq!(ops.fanout_msgs, 20);
        assert_eq!(ops.server_steps, 20);
        assert_eq!(ops.fanin_msgs, 20);
        assert_eq!(ops.cancelled, 0);
        assert_eq!(ops.uplink_bytes_total, 20 * 12_000);
        assert_eq!(ops.downlink_bytes_total, 20 * 6_000);
    }
}
