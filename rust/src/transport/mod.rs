//! The coordinator↔device transport API.
//!
//! This module replaces the old direct `Link::transfer()` call-and-charge
//! model (`net.rs`) with a layered design (see `ARCHITECTURE.md` for the
//! full picture):
//!
//! * [`link`] — the low-level **cost model**: per-link bandwidth / latency
//!   / jitter, exact wire-byte accounting, per-round busy snapshots, and
//!   the shared-uplink fair-share fluid model ([`SharedUplink`]) used when
//!   `uplink = "shared"`.
//! * [`event`] — the deterministic **simulated-time event scheduler**: a
//!   binary heap of `(sim_time, seq, device, event)` with sequence-number
//!   tie-breaking, so event order is a pure function of the seed — never
//!   of thread scheduling. Also hosts [`ServerResource`], the server as a
//!   serial busy resource (`server_service_s` per batch).
//! * [`profile`] — per-device heterogeneity: link classes
//!   (`wifi`/`lte`/`5g`/`ethernet`), compute-speed multipliers, and
//!   config/CLI-selectable mix specs (`"wifi/lte"`).
//! * [`policy`] — straggler policies for async rounds (`wait-all`,
//!   `deadline-drop`, `k`-of-`n` `quorum`) and per-round client sampling
//!   ([`ClientSampling`]: `sample_fraction` / `sample_k`).
//! * [`fault`] — seeded fault injection ([`FaultPlan`]): per-round
//!   crash windows, per-message loss/corruption verdicts, retry backoff
//!   with jitter, and server outage windows, all pure functions of
//!   `(seed, round, device, step, attempt)`.
//! * [`fleet`] — [`FleetOps`], a training-free [`RoundOps`] over compact
//!   per-cohort cost tables: the harness the fleet-scale benches and
//!   equivalence tests use to drive million-device rounds without any
//!   model state.
//! * [`scheduler`] — the [`RoundScheduler`] trait plus both
//!   implementations: barriered lockstep re-expressed as events
//!   ([`SyncEventScheduler`], bit-identical to the pre-transport engine
//!   when the contention model is off) and event-driven async
//!   ([`AsyncEventScheduler`], the server consumes uplinks as they land).
//!
//! The old `crate::net` path re-exports [`link`]'s types for backward
//! compatibility.

pub mod event;
pub mod fault;
pub mod fleet;
pub mod link;
pub mod policy;
pub mod profile;
pub mod scheduler;

pub use event::{DeviceId, Event, EventQueue, Scheduled, ServerResource};
pub use fault::{FaultConfig, FaultPlan};
pub use fleet::FleetOps;
pub use link::{
    CommStats, CompletedFlow, Direction, DownlinkMode, Link, LinkConfig, LinkState,
    SharedUplink, UplinkMode,
};
pub use policy::{ClientSampling, StragglerPolicy};
pub use profile::{assign_profiles, DeviceProfile, LinkClass};
pub use scheduler::{
    build_scheduler, AsyncEventScheduler, RoundOps, RoundReport, RoundScheduler, SchedulerKind,
    ServerOut, ServerStep, SyncEventScheduler, UplinkMsg,
};
