//! # SL-FAC — Communication-Efficient Split Learning with Frequency-Aware Compression
//!
//! Reproduction of *"SL-FAC: A Communication-Efficient Split Learning Framework
//! with Frequency-Aware Compression"* (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the split-learning coordinator: device manager,
//!   thread-parallel round engine, the AFD+FQC codec on the wire path,
//!   baseline codecs, network simulator, metrics, config and CLI. Python
//!   never runs here.
//! * **L2** — the split ResNet written in JAX (`python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * **L1** — the batched 2-D DCT Pallas kernel
//!   (`python/compile/kernels/dct_kernel.py`) lowered inside the L2 graphs.
//!
//! # The parallel round engine
//!
//! Rounds are **device-parallel**: the fan-out phase (client forward +
//! codec encode + uplink) and the fan-in phase (gradient decode + client
//! backward) run concurrently across devices on a sharded worker pool
//! ([`coordinator::engine`]), while the server step and aggregation stay
//! explicit barriers. The pool width is the `workers` config key /
//! `--workers` CLI flag (`0` = one worker per CPU). Parallelism is
//! **bit-transparent**: at a fixed seed, `workers = N` produces the exact
//! same `TrainingHistory`, `CommStats`, and parameters as `workers = 1` —
//! every random draw comes from a per-device stream derived from the root
//! seed ([`rng::derive_seed`]) and every floating-point reduction folds in
//! device-id order. The `parallel_determinism` integration test enforces
//! this differentially.
//!
//! # The transport layer
//!
//! Communication runs through the [`transport`] API: a deterministic
//! simulated-time event scheduler (`transport::EventQueue`, ordered by
//! `(sim_time, seq)` so event order is a pure function of the seed), a
//! per-link cost model (`transport::link`, the old `net.rs`), per-device
//! heterogeneous profiles (`wifi`/`lte`/`5g`/`ethernet` mixes via the
//! `profile` config key), and two round schedulers behind the
//! `RoundScheduler` trait: barriered **sync** (bit-identical to the
//! legacy lockstep engine) and event-driven **async**, where the server
//! consumes uplinks as they land and a straggler policy (`wait-all`,
//! `deadline-drop`, `quorum`) decides when the round closes. On top sits
//! the **contention model**: a serial server busy resource
//! (`server_service_s` — uplinks queue, reported as `queue_wait_s`), a
//! fair-share **shared uplink** (`uplink = "shared"`: concurrent
//! transfers split one pipe's capacity), and per-round **client
//! sampling** (`sample_fraction` / `sample_k`). See `ARCHITECTURE.md`
//! and `CONFIGS.md`.
//!
//! # Executor backends
//!
//! The model executor ([`runtime`]) serves two backends behind one actor:
//! **xla** (PJRT over AOT HLO artifacts — requires `make artifacts` and a
//! real `xla` crate in place of the vendored stub) and **sim** (a small
//! deterministic pure-Rust split model driven by `manifest.json` alone),
//! so the full coordinator stack runs and tests offline. On the sim
//! backend the trainer defaults to the **device-resident compute fast
//! path** ([`runtime::compute`], `compute_fast_path` config key): blocked
//! GEMM kernels and in-place model state, bit-identical to the artifact
//! `execute` path with zero steady-state heap allocations.
//!
//! # Sweeps
//!
//! Figure-scale experiment grids run through the [`sweep`] orchestrator:
//! a declarative JSON [`sweep::SweepSpec`] (`configs/sweeps/`) expands
//! into validated runs, executes across a worker pool with each
//! completed run checkpointed to an append-only journal, and resumes
//! mid-grid byte-identically (`slfac sweep run | status | report`).
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod experiments;
pub mod freq;
pub mod json;
pub mod logging;
pub mod net;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sweep;
pub mod tensor;
pub mod testing;
pub mod transport;

/// Crate version string (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

#[cfg(test)]
mod tests {
    #[test]
    fn version_is_semver_like() {
        let v = super::version();
        assert_eq!(v.split('.').count(), 3);
    }
}
