//! # SL-FAC — Communication-Efficient Split Learning with Frequency-Aware Compression
//!
//! Reproduction of *"SL-FAC: A Communication-Efficient Split Learning Framework
//! with Frequency-Aware Compression"* (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the split-learning coordinator: device manager,
//!   round scheduler, the AFD+FQC codec on the wire path, baseline codecs,
//!   network simulator, metrics, config and CLI. Python never runs here.
//! * **L2** — the split ResNet written in JAX (`python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * **L1** — the batched 2-D DCT Pallas kernel
//!   (`python/compile/kernels/dct_kernel.py`) lowered inside the L2 graphs.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod experiments;
pub mod freq;
pub mod json;
pub mod logging;
pub mod net;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod testing;

/// Crate version string (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

#[cfg(test)]
mod tests {
    #[test]
    fn version_is_semver_like() {
        let v = super::version();
        assert_eq!(v.split('.').count(), 3);
    }
}
