//! Mini-batch iteration over a device's shard.
//!
//! Fixed batch size (HLO artifacts are shape-specialized), per-epoch
//! reshuffling, and wrap-around so every round can draw a full batch even
//! from small non-IID shards (sampling with replacement across epoch
//! boundaries, standard for SL/FL simulators).

use super::Dataset;
use crate::rng::Pcg32;
use anyhow::Result;

/// Serializable snapshot of a [`BatchLoader`] mid-run — the shuffled index
/// order, the cursor, the epoch counter, and the raw RNG state. Restoring
/// through [`BatchLoader::from_state`] continues the draw sequence
/// bit-identically (checkpoint/resume contract).
#[derive(Debug, Clone)]
pub struct LoaderState {
    /// Index order as currently shuffled.
    pub indices: Vec<usize>,
    /// Position of the next draw within `indices`.
    pub cursor: usize,
    /// Epochs completed at snapshot time.
    pub epochs: usize,
    /// Batch size the loader was built with.
    pub batch_size: usize,
    /// Reshuffle RNG `(state, inc)` parts.
    pub rng: (u64, u64),
}

/// Cycling, reshuffling batch iterator over a subset of a dataset.
#[derive(Debug)]
pub struct BatchLoader {
    indices: Vec<usize>,
    cursor: usize,
    rng: Pcg32,
    /// Batch size every `next_batch` returns.
    pub batch_size: usize,
    /// Epochs completed (full passes over the shard).
    pub epochs: usize,
}

impl BatchLoader {
    /// Loader over `indices` into some dataset.
    pub fn new(indices: Vec<usize>, batch_size: usize, seed: u64) -> Self {
        assert!(batch_size > 0);
        assert!(!indices.is_empty(), "empty shard");
        let mut rng = Pcg32::new(seed, 211);
        let mut indices = indices;
        rng.shuffle(&mut indices);
        BatchLoader {
            indices,
            cursor: 0,
            rng,
            batch_size,
            epochs: 0,
        }
    }

    /// Snapshot the full loader state for a checkpoint.
    pub fn snapshot(&self) -> LoaderState {
        LoaderState {
            indices: self.indices.clone(),
            cursor: self.cursor,
            epochs: self.epochs,
            batch_size: self.batch_size,
            rng: self.rng.state_parts(),
        }
    }

    /// Rebuild a loader from a [`LoaderState`]. Fails closed on
    /// structurally impossible state (empty shard, zero batch size, cursor
    /// past the shard) rather than trusting checkpoint bytes blindly.
    pub fn from_state(state: LoaderState) -> Result<Self> {
        anyhow::ensure!(state.batch_size > 0, "loader state: batch_size is 0");
        anyhow::ensure!(!state.indices.is_empty(), "loader state: empty shard");
        anyhow::ensure!(
            state.cursor <= state.indices.len(),
            "loader state: cursor {} past shard of {}",
            state.cursor,
            state.indices.len()
        );
        Ok(BatchLoader {
            indices: state.indices,
            cursor: state.cursor,
            rng: Pcg32::from_state_parts(state.rng.0, state.rng.1),
            batch_size: state.batch_size,
            epochs: state.epochs,
        })
    }

    /// Number of batches per full pass (rounded up).
    pub fn batches_per_epoch(&self) -> usize {
        (self.indices.len() + self.batch_size - 1) / self.batch_size
    }

    /// Advance the cursor (wrapping + reshuffling at epoch boundaries) and
    /// return the next sample index. The draw sequence is identical for
    /// both batch APIs below.
    fn next_index(&mut self) -> usize {
        if self.cursor >= self.indices.len() {
            self.cursor = 0;
            self.epochs += 1;
            self.rng.shuffle(&mut self.indices);
        }
        let i = self.indices[self.cursor];
        self.cursor += 1;
        i
    }

    /// Next batch of `(images, labels)` copied out of `dataset`.
    /// Images are a flat `[batch, C, H, W]` buffer; labels are u32.
    pub fn next_batch(&mut self, dataset: &Dataset) -> (Vec<f32>, Vec<u32>) {
        let sz = dataset.sample_size();
        let mut images = Vec::with_capacity(self.batch_size * sz);
        let mut labels = Vec::with_capacity(self.batch_size);
        for _ in 0..self.batch_size {
            let i = self.next_index();
            images.extend_from_slice(dataset.image(i));
            labels.push(dataset.labels[i]);
        }
        (images, labels)
    }

    /// [`BatchLoader::next_batch`] into caller-owned buffers (cleared,
    /// capacity reused — zero allocations once warm), with labels cast to
    /// the executor's i32 dtype. Same index-draw sequence as `next_batch`,
    /// so the two APIs are interchangeable mid-run.
    pub fn next_batch_into(
        &mut self,
        dataset: &Dataset,
        images: &mut Vec<f32>,
        labels: &mut Vec<i32>,
    ) {
        images.clear();
        labels.clear();
        images.reserve(self.batch_size * dataset.sample_size());
        labels.reserve(self.batch_size);
        for _ in 0..self.batch_size {
            let i = self.next_index();
            images.extend_from_slice(dataset.image(i));
            labels.push(dataset.labels[i] as i32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{mnist_like, DatasetSpec};

    fn dataset() -> Dataset {
        let (train, _) = mnist_like(&DatasetSpec {
            train_samples: 50,
            test_samples: 0,
            ..Default::default()
        });
        train
    }

    #[test]
    fn batches_have_right_shape() {
        let d = dataset();
        let mut l = BatchLoader::new((0..d.len()).collect(), 8, 1);
        let (x, y) = l.next_batch(&d);
        assert_eq!(x.len(), 8 * 28 * 28);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn wraps_and_counts_epochs() {
        let d = dataset();
        let mut l = BatchLoader::new((0..10).collect(), 8, 2);
        assert_eq!(l.batches_per_epoch(), 2);
        for _ in 0..4 {
            l.next_batch(&d);
        }
        assert!(l.epochs >= 2);
    }

    #[test]
    fn covers_shard_within_epoch() {
        let d = dataset();
        let shard: Vec<usize> = (5..15).collect();
        let mut l = BatchLoader::new(shard.clone(), 5, 3);
        let mut seen = std::collections::HashSet::new();
        // first two batches = one epoch = all 10 distinct indices' labels
        for _ in 0..2 {
            let (_, labels) = l.next_batch(&d);
            for lab in labels {
                seen.insert(lab);
            }
        }
        // can't check indices directly (loader hides them), so check volume:
        // 10 samples drawn, epoch counter still <= 1
        assert!(l.epochs <= 1);
        assert!(!seen.is_empty());
    }

    #[test]
    fn into_api_matches_allocating_api() {
        let d = dataset();
        let mut a = BatchLoader::new((0..d.len()).collect(), 4, 9);
        let mut b = BatchLoader::new((0..d.len()).collect(), 4, 9);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        for _ in 0..6 {
            let (xa, ya) = a.next_batch(&d);
            b.next_batch_into(&d, &mut xs, &mut ys);
            assert_eq!(xa, xs);
            let ya_i32: Vec<i32> = ya.iter().map(|&l| l as i32).collect();
            assert_eq!(ya_i32, ys);
        }
        assert_eq!(a.epochs, b.epochs, "same wrap/reshuffle sequence");
    }

    #[test]
    fn snapshot_restore_continues_draws_bit_identically() {
        let d = dataset();
        let mut a = BatchLoader::new((0..d.len()).collect(), 4, 9);
        // advance mid-epoch so cursor, epochs, and RNG are all non-trivial
        for _ in 0..7 {
            a.next_batch(&d);
        }
        let mut b = BatchLoader::from_state(a.snapshot()).unwrap();
        for _ in 0..20 {
            assert_eq!(a.next_batch(&d), b.next_batch(&d));
        }
        assert_eq!(a.epochs, b.epochs);
    }

    #[test]
    fn from_state_rejects_impossible_state() {
        let good = BatchLoader::new((0..10).collect(), 4, 1).snapshot();
        let mut s = good.clone();
        s.batch_size = 0;
        assert!(BatchLoader::from_state(s).unwrap_err().to_string().contains("batch_size"));
        let mut s = good.clone();
        s.indices.clear();
        assert!(BatchLoader::from_state(s).unwrap_err().to_string().contains("empty shard"));
        let mut s = good;
        s.cursor = 11;
        assert!(BatchLoader::from_state(s).unwrap_err().to_string().contains("cursor"));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = dataset();
        let mut a = BatchLoader::new((0..d.len()).collect(), 4, 9);
        let mut b = BatchLoader::new((0..d.len()).collect(), 4, 9);
        for _ in 0..5 {
            assert_eq!(a.next_batch(&d).1, b.next_batch(&d).1);
        }
    }
}
