//! Device data partitioning: IID and Dirichlet non-IID (paper §III-A.2).
//!
//! * IID — shuffle all samples, split evenly across devices.
//! * non-IID — the standard Dirichlet partition: for each class, draw class
//!   proportions `p ~ Dir(β·1)` over devices (β = 0.5 in the paper) and
//!   deal that class's samples accordingly. Smaller β ⇒ more skew.

use super::Dataset;
use crate::rng::Pcg32;

/// Evenly split shuffled indices across `devices`. Every device receives
/// `⌊n/devices⌋` or `⌈n/devices⌉` samples.
pub fn partition_iid(dataset: &Dataset, devices: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(devices > 0);
    let mut rng = Pcg32::new(seed, 101);
    let mut idx: Vec<usize> = (0..dataset.len()).collect();
    rng.shuffle(&mut idx);
    let mut parts = vec![Vec::new(); devices];
    for (i, sample) in idx.into_iter().enumerate() {
        parts[i % devices].push(sample);
    }
    parts
}

/// Dirichlet non-IID partition with concentration `beta` (paper: 0.5).
///
/// Guarantees every device ends up non-empty by rebalancing from the
/// largest shard if the draw starved anyone (rare at realistic sizes, but
/// the trainer must never see an empty device).
pub fn partition_dirichlet(
    dataset: &Dataset,
    devices: usize,
    beta: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    assert!(devices > 0);
    assert!(beta > 0.0);
    let mut rng = Pcg32::new(seed, 103);
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); devices];

    // per-class index pools
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); dataset.num_classes];
    for (i, &l) in dataset.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }

    for pool in by_class.iter_mut() {
        if pool.is_empty() {
            continue;
        }
        rng.shuffle(pool);
        let props = rng.dirichlet(beta, devices);
        // convert proportions to integer cut points
        let n = pool.len();
        let mut cuts = Vec::with_capacity(devices);
        let mut acc = 0.0f64;
        for &p in &props[..devices - 1] {
            acc += p;
            cuts.push((acc * n as f64).round() as usize);
        }
        cuts.push(n);
        let mut start = 0;
        for (d, &end) in cuts.iter().enumerate() {
            let end = end.clamp(start, n);
            parts[d].extend_from_slice(&pool[start..end]);
            start = end;
        }
    }

    // rebalance empties
    loop {
        let empty = parts.iter().position(|p| p.is_empty());
        let Some(e) = empty else { break };
        let largest = {
            let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            (0..devices).max_by_key(|&d| lens[d]).expect("devices > 0")
        };
        if parts[largest].len() <= 1 {
            break; // dataset smaller than device count; leave as-is
        }
        let half = parts[largest].len() / 2;
        let moved = parts[largest].split_off(half);
        parts[e] = moved;
    }

    for p in parts.iter_mut() {
        rng.shuffle(p);
    }
    parts
}

/// Skew diagnostic: mean total-variation distance between each device's
/// class distribution and the global one (0 = perfectly IID).
pub fn label_skew(dataset: &Dataset, parts: &[Vec<usize>]) -> f64 {
    let global = dataset.class_counts();
    let total: usize = global.iter().sum();
    let gdist: Vec<f64> = global.iter().map(|&c| c as f64 / total as f64).collect();
    let mut skew = 0.0;
    for p in parts {
        let mut counts = vec![0usize; dataset.num_classes];
        for &i in p {
            counts[dataset.labels[i] as usize] += 1;
        }
        let n: usize = counts.iter().sum();
        if n == 0 {
            continue;
        }
        let tv: f64 = counts
            .iter()
            .zip(&gdist)
            .map(|(&c, &g)| (c as f64 / n as f64 - g).abs())
            .sum::<f64>()
            / 2.0;
        skew += tv;
    }
    skew / parts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{mnist_like, DatasetSpec};

    fn dataset() -> Dataset {
        let (train, _) = mnist_like(&DatasetSpec {
            train_samples: 1000,
            test_samples: 0,
            ..Default::default()
        });
        train
    }

    #[test]
    fn iid_covers_everything_once() {
        let d = dataset();
        let parts = partition_iid(&d, 5, 42);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..d.len()).collect::<Vec<_>>());
        for p in &parts {
            assert_eq!(p.len(), 200);
        }
    }

    #[test]
    fn dirichlet_covers_everything_once() {
        let d = dataset();
        let parts = partition_dirichlet(&d, 5, 0.5, 42);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), d.len());
        assert!(parts.iter().all(|p| !p.is_empty()));
    }

    #[test]
    fn dirichlet_is_more_skewed_than_iid() {
        let d = dataset();
        let iid = partition_iid(&d, 5, 7);
        let noniid = partition_dirichlet(&d, 5, 0.5, 7);
        let s_iid = label_skew(&d, &iid);
        let s_non = label_skew(&d, &noniid);
        assert!(
            s_non > s_iid + 0.05,
            "non-IID skew {s_non} vs IID {s_iid}"
        );
    }

    #[test]
    fn smaller_beta_more_skew() {
        let d = dataset();
        let mild = partition_dirichlet(&d, 5, 10.0, 11);
        let harsh = partition_dirichlet(&d, 5, 0.1, 11);
        assert!(label_skew(&d, &harsh) > label_skew(&d, &mild));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = dataset();
        let a = partition_dirichlet(&d, 5, 0.5, 33);
        let b = partition_dirichlet(&d, 5, 0.5, 33);
        assert_eq!(a, b);
        let c = partition_dirichlet(&d, 5, 0.5, 34);
        assert_ne!(a, c);
    }
}
