//! Datasets, partitioning, batching.
//!
//! The paper trains on MNIST and HAM10000; this environment has no network
//! access, so [`synthetic`] provides procedurally generated stand-ins with
//! genuinely learnable class structure (documented in DESIGN.md §3). The
//! partitioners reproduce the paper's IID (shuffle + even split) and
//! non-IID (Dirichlet β = 0.5) device distributions.

pub mod loader;
pub mod partition;
pub mod synthetic;

pub use loader::{BatchLoader, LoaderState};
pub use partition::{partition_dirichlet, partition_iid};
pub use synthetic::{ham_like, mnist_like, DatasetSpec};

/// An in-memory labeled image dataset (NCHW f32 images).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Flat image buffer, `len = n * c * h * w`.
    pub images: Vec<f32>,
    /// One label per image.
    pub labels: Vec<u32>,
    /// Channels per image.
    pub channels: usize,
    /// Image height.
    pub height: usize,
    /// Image width.
    pub width: usize,
    /// Number of classes.
    pub num_classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Elements per image.
    pub fn sample_size(&self) -> usize {
        self.channels * self.height * self.width
    }

    /// Borrow image `i` as a flat slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.sample_size();
        &self.images[i * sz..(i + 1) * sz]
    }

    /// Subset by indices (copies).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let sz = self.sample_size();
        let mut images = Vec::with_capacity(indices.len() * sz);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            images,
            labels,
            ..*self
        }
    }

    /// Class histogram (for partition diagnostics).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            images: (0..4 * 2 * 3 * 3).map(|i| i as f32).collect(),
            labels: vec![0, 1, 0, 1],
            channels: 2,
            height: 3,
            width: 3,
            num_classes: 2,
        }
    }

    #[test]
    fn image_slices() {
        let d = tiny();
        assert_eq!(d.sample_size(), 18);
        assert_eq!(d.image(1)[0], 18.0);
    }

    #[test]
    fn subset_copies_right_samples() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.labels, vec![0, 0]);
        assert_eq!(s.image(0), d.image(2));
    }

    #[test]
    fn class_counts_sum() {
        let d = tiny();
        assert_eq!(d.class_counts(), vec![2, 2]);
    }
}
