//! Procedural stand-ins for MNIST and HAM10000 (no dataset downloads in
//! this environment — see DESIGN.md §3 substitutions).
//!
//! Both generators build a fixed per-class *prototype* (seeded by class id
//! only, so it is identical across devices and runs) and derive each sample
//! from its class prototype with random geometric jitter, amplitude jitter,
//! and pixel noise. This yields datasets that
//!
//! * are genuinely learnable (classes are linearly separable only after
//!   some nonlinear feature extraction, like the real datasets),
//! * have the spatial-smoothness structure AFD exploits (prototypes are
//!   low-frequency), and
//! * controllably vary in difficulty (noise levels chosen so a small CNN
//!   converges in tens of rounds, matching the paper's round counts).
//!
//! `mnist_like`: 1×28×28, 10 classes — stroke-like glyph prototypes.
//! `ham_like`: 3×32×32, 7 classes — lesion-like textured ellipse prototypes
//! on skin-toned backgrounds.

use super::Dataset;
use crate::rng::Pcg32;

/// Generation parameters shared by both datasets.
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    /// Samples in the train split.
    pub train_samples: usize,
    /// Samples in the test split.
    pub test_samples: usize,
    /// Pixel noise std added to every sample.
    pub noise: f32,
    /// Master seed (prototypes use class-derived seeds independent of this).
    pub seed: u64,
}

impl Default for DatasetSpec {
    fn default() -> Self {
        DatasetSpec {
            train_samples: 4000,
            test_samples: 800,
            noise: 0.20,
            seed: 1234,
        }
    }
}

/// Draw an anti-aliased line segment into `img` (single channel, h×w).
fn draw_segment(img: &mut [f32], h: usize, w: usize, x0: f32, y0: f32, x1: f32, y1: f32, thick: f32) {
    let steps = (((x1 - x0).abs() + (y1 - y0).abs()) * 2.0) as usize + 2;
    for s in 0..=steps {
        let t = s as f32 / steps as f32;
        let cx = x0 + (x1 - x0) * t;
        let cy = y0 + (y1 - y0) * t;
        let r = thick.ceil() as i64 + 1;
        for dy in -r..=r {
            for dx in -r..=r {
                let px = cx + dx as f32;
                let py = cy + dy as f32;
                if px < 0.0 || py < 0.0 {
                    continue;
                }
                let (xi, yi) = (px as usize, py as usize);
                if xi >= w || yi >= h {
                    continue;
                }
                let d2 = (px - cx).powi(2) + (py - cy).powi(2);
                let v = (-d2 / (thick * thick)).exp();
                let cell = &mut img[yi * w + xi];
                *cell = cell.max(v);
            }
        }
    }
}

/// A glyph prototype: a set of connected stroke segments in [0,1]² space.
fn glyph_prototype(class: u32, h: usize, w: usize) -> Vec<f32> {
    // Class-only seed ⇒ identical prototypes everywhere.
    let mut rng = Pcg32::new(0xD161_7000 + class as u64, 17);
    let mut img = vec![0.0f32; h * w];
    // 3–5 strokes through random waypoints biased to stay centered.
    let n_strokes = 3 + (class % 3) as usize;
    let mut px = 0.3 + 0.4 * rng.uniform();
    let mut py = 0.2 + 0.3 * rng.uniform();
    for _ in 0..n_strokes {
        let nx = (px + rng.uniform_in(-0.45, 0.45)).clamp(0.12, 0.88);
        let ny = (py + rng.uniform_in(-0.45, 0.45)).clamp(0.12, 0.88);
        draw_segment(
            &mut img,
            h,
            w,
            px * w as f32,
            py * h as f32,
            nx * w as f32,
            ny * h as f32,
            1.3,
        );
        px = nx;
        py = ny;
    }
    img
}

/// Shift a single-channel image by integer (dy, dx), zero-filled.
fn shift(img: &[f32], h: usize, w: usize, dy: i64, dx: i64) -> Vec<f32> {
    let mut out = vec![0.0f32; h * w];
    for y in 0..h as i64 {
        for x in 0..w as i64 {
            let sy = y - dy;
            let sx = x - dx;
            if sy >= 0 && sy < h as i64 && sx >= 0 && sx < w as i64 {
                out[(y * w as i64 + x) as usize] = img[(sy * w as i64 + sx) as usize];
            }
        }
    }
    out
}

/// Build the MNIST-like dataset: 1×28×28, 10 classes.
/// Returns (train, test).
pub fn mnist_like(spec: &DatasetSpec) -> (Dataset, Dataset) {
    build_glyph_dataset(spec, 10, 28, 28)
}

fn build_glyph_dataset(
    spec: &DatasetSpec,
    classes: u32,
    h: usize,
    w: usize,
) -> (Dataset, Dataset) {
    let prototypes: Vec<Vec<f32>> = (0..classes).map(|c| glyph_prototype(c, h, w)).collect();
    let make_split = |n: usize, seed: u64| -> Dataset {
        let mut rng = Pcg32::new(seed, 3);
        let mut images = Vec::with_capacity(n * h * w);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(classes);
            let proto = &prototypes[class as usize];
            let dy = rng.below(5) as i64 - 2;
            let dx = rng.below(5) as i64 - 2;
            let amp = 0.8 + 0.4 * rng.uniform();
            let shifted = shift(proto, h, w, dy, dx);
            for &v in &shifted {
                images.push((v * amp + spec.noise * rng.normal()).clamp(-1.0, 2.0));
            }
            labels.push(class);
        }
        Dataset {
            images,
            labels,
            channels: 1,
            height: h,
            width: w,
            num_classes: classes as usize,
        }
    };
    (
        make_split(spec.train_samples, spec.seed),
        make_split(spec.test_samples, spec.seed ^ 0xABCD_EF01),
    )
}

/// Lesion prototype: class-dependent ellipse geometry, RGB tint, and
/// texture frequency on a skin-tone background.
struct LesionProto {
    cx: f32,
    cy: f32,
    rx: f32,
    ry: f32,
    tint: [f32; 3],
    tex_freq: f32,
    tex_amp: f32,
}

fn lesion_prototype(class: u32) -> LesionProto {
    let mut rng = Pcg32::new(0x4A11_5000 + class as u64, 23);
    LesionProto {
        cx: 0.4 + 0.2 * rng.uniform(),
        cy: 0.4 + 0.2 * rng.uniform(),
        rx: 0.15 + 0.12 * rng.uniform(),
        ry: 0.15 + 0.12 * rng.uniform(),
        tint: [
            0.25 + 0.5 * rng.uniform(),
            0.1 + 0.35 * rng.uniform(),
            0.05 + 0.3 * rng.uniform(),
        ],
        tex_freq: 2.0 + 6.0 * rng.uniform(),
        tex_amp: 0.05 + 0.2 * rng.uniform(),
    }
}

/// Build the HAM10000-like dataset: 3×32×32, 7 classes.
/// Returns (train, test).
pub fn ham_like(spec: &DatasetSpec) -> (Dataset, Dataset) {
    let classes = 7u32;
    let (h, w) = (32usize, 32usize);
    let protos: Vec<LesionProto> = (0..classes).map(lesion_prototype).collect();
    let make_split = |n: usize, seed: u64| -> Dataset {
        let mut rng = Pcg32::new(seed, 5);
        let mut images = Vec::with_capacity(n * 3 * h * w);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(classes);
            let p = &protos[class as usize];
            // sample-level jitter
            let jcx = p.cx + rng.uniform_in(-0.06, 0.06);
            let jcy = p.cy + rng.uniform_in(-0.06, 0.06);
            let jrx = p.rx * (0.85 + 0.3 * rng.uniform());
            let jry = p.ry * (0.85 + 0.3 * rng.uniform());
            let phase = rng.uniform() * 6.28;
            // skin background tone
            let skin = [
                0.75 + 0.1 * rng.uniform(),
                0.6 + 0.1 * rng.uniform(),
                0.5 + 0.1 * rng.uniform(),
            ];
            let mut sample = vec![0.0f32; 3 * h * w];
            for y in 0..h {
                for x in 0..w {
                    let fy = y as f32 / h as f32;
                    let fx = x as f32 / w as f32;
                    let d = ((fx - jcx) / jrx).powi(2) + ((fy - jcy) / jry).powi(2);
                    // soft lesion boundary
                    let mask = 1.0 / (1.0 + ((d - 1.0) * 8.0).exp());
                    let tex = p.tex_amp
                        * ((p.tex_freq * fx * 6.28 + phase).sin()
                            * (p.tex_freq * fy * 6.28).cos());
                    for ch in 0..3 {
                        let lesion = p.tint[ch] + tex;
                        let v = skin[ch] * (1.0 - mask) + lesion * mask
                            + spec.noise * 0.5 * rng.normal();
                        sample[ch * h * w + y * w + x] = v.clamp(-0.5, 1.5);
                    }
                }
            }
            images.extend_from_slice(&sample);
            labels.push(class);
        }
        Dataset {
            images,
            labels,
            channels: 3,
            height: h,
            width: w,
            num_classes: classes as usize,
        }
    };
    (
        make_split(spec.train_samples, spec.seed),
        make_split(spec.test_samples, spec.seed ^ 0x1357_9BDF),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_labels() {
        let spec = DatasetSpec {
            train_samples: 100,
            test_samples: 20,
            ..Default::default()
        };
        let (train, test) = mnist_like(&spec);
        assert_eq!(train.len(), 100);
        assert_eq!(test.len(), 20);
        assert_eq!(train.sample_size(), 28 * 28);
        assert!(train.labels.iter().all(|&l| l < 10));
        // every class present in 100 draws (10 classes, overwhelmingly likely)
        let counts = train.class_counts();
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 8);
    }

    #[test]
    fn ham_like_shapes() {
        let spec = DatasetSpec {
            train_samples: 50,
            test_samples: 10,
            ..Default::default()
        };
        let (train, _) = ham_like(&spec);
        assert_eq!(train.sample_size(), 3 * 32 * 32);
        assert!(train.labels.iter().all(|&l| l < 7));
    }

    #[test]
    fn prototypes_are_deterministic() {
        let a = glyph_prototype(3, 28, 28);
        let b = glyph_prototype(3, 28, 28);
        assert_eq!(a, b);
        let c = glyph_prototype(4, 28, 28);
        assert_ne!(a, c);
    }

    #[test]
    fn classes_are_distinguishable_by_nearest_prototype() {
        // Sanity: a trivial nearest-prototype classifier on clean prototypes
        // must beat chance by a wide margin on noisy samples — i.e. the
        // dataset is learnable.
        let spec = DatasetSpec {
            train_samples: 300,
            test_samples: 0,
            noise: 0.2,
            seed: 99,
        };
        let (train, _) = mnist_like(&spec);
        let protos: Vec<Vec<f32>> = (0..10).map(|c| glyph_prototype(c, 28, 28)).collect();
        let mut correct = 0;
        for i in 0..train.len() {
            let img = train.image(i);
            let mut best = (f32::INFINITY, 0u32);
            for (c, p) in protos.iter().enumerate() {
                // translation-tolerant: min distance over small shifts
                let mut dmin = f32::INFINITY;
                for dy in -2..=2i64 {
                    for dx in -2..=2i64 {
                        let s = shift(p, 28, 28, dy, dx);
                        let d: f32 = img
                            .iter()
                            .zip(&s)
                            .map(|(&a, &b)| (a - b) * (a - b))
                            .sum();
                        dmin = dmin.min(d);
                    }
                }
                if dmin < best.0 {
                    best = (dmin, c as u32);
                }
            }
            if best.1 == train.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / train.len() as f64;
        assert!(acc > 0.6, "nearest-prototype acc {acc} (chance = 0.1)");
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let spec = DatasetSpec {
            train_samples: 50,
            test_samples: 50,
            ..Default::default()
        };
        let (train, test) = mnist_like(&spec);
        // different seeds ⇒ different pixel streams
        assert_ne!(train.images[..100], test.images[..100]);
    }

    #[test]
    fn noise_increases_pixel_variance() {
        let lo = DatasetSpec {
            train_samples: 50,
            test_samples: 0,
            noise: 0.01,
            seed: 5,
        };
        let hi = DatasetSpec {
            noise: 0.5,
            ..lo
        };
        let (a, _) = mnist_like(&lo);
        let (b, _) = mnist_like(&hi);
        let var = |d: &Dataset| crate::tensor::std_dev(&d.images);
        assert!(var(&b) > var(&a));
    }
}
