//! Dense f32 tensors in row-major (NCHW) layout.
//!
//! The coordinator moves cut-layer activations and gradients between the
//! PJRT runtime and the codecs as plain contiguous buffers; this module is
//! the shared container: shape bookkeeping, per-channel views, and the
//! simple statistics (mean/std/min/max per channel) the baseline codecs
//! (FC-SL, magnitude/STD selection) need.

use std::fmt;

/// Shape of a dense tensor (up to rank 4 in practice: N,C,H,W).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Rank.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Dimensions slice.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

impl fmt::Debug for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

impl From<&[usize]> for Shape {
    fn from(d: &[usize]) -> Self {
        Shape(d.to_vec())
    }
}

/// A dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

impl Tensor {
    /// Build from parts; panics if `data.len() != product(shape)`.
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape(shape.to_vec());
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor::new(shape, vec![v; n])
    }

    /// Tensor with elements drawn from N(0, std) using the given RNG.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Pcg32) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor::new(shape, data)
    }

    /// Shape dims.
    pub fn shape(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    /// Immutable data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing vec.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape **in place** to `shape`, zero-filling the data and reusing
    /// the existing allocation when capacity allows — the decompress hot
    /// path resets one output tensor per call instead of allocating
    /// (`ActivationCodec::decompress_into`). Sparse decoders rely on the
    /// zero fill; dense decoders use [`Tensor::reset_dense`].
    pub fn reset(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        self.data.clear();
        self.data.resize(n, 0.0);
        self.shape.0.clear();
        self.shape.0.extend_from_slice(shape);
    }

    /// Like [`Tensor::reset`] but **without** the zero fill: retained
    /// elements keep their stale values (only growth is zeroed). Only for
    /// callers that overwrite every element before the tensor is read —
    /// skips a redundant full memset on the dense decode hot path.
    pub fn reset_dense(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        self.data.resize(n, 0.0);
        self.shape.0.clear();
        self.shape.0.extend_from_slice(shape);
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape element count mismatch");
        self.shape = Shape(shape.to_vec());
        self
    }

    /// Interpret as (B, C, M, N); panics unless rank is 3 (C,M,N → B=1) or 4.
    pub fn as_bchw(&self) -> (usize, usize, usize, usize) {
        match self.shape.dims() {
            [c, m, n] => (1, *c, *m, *n),
            [b, c, m, n] => (*b, *c, *m, *n),
            other => panic!("expected rank 3/4 tensor, got {other:?}"),
        }
    }

    /// Borrow channel (b, c) as a contiguous `M*N` slice (NCHW layout).
    pub fn channel(&self, b: usize, c: usize) -> &[f32] {
        let (bs, cs, m, n) = self.as_bchw();
        assert!(b < bs && c < cs);
        let sz = m * n;
        let off = (b * cs + c) * sz;
        &self.data[off..off + sz]
    }

    /// Mutable channel slice.
    pub fn channel_mut(&mut self, b: usize, c: usize) -> &mut [f32] {
        let (bs, cs, m, n) = self.as_bchw();
        assert!(b < bs && c < cs);
        let sz = m * n;
        let off = (b * cs + c) * sz;
        &mut self.data[off..off + sz]
    }

    /// Min and max over all elements (NaNs ignored; empty → (0,0)).
    pub fn min_max(&self) -> (f32, f32) {
        min_max(&self.data)
    }

    /// Mean over all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32 / self.data.len() as f32
    }

    /// Population standard deviation over all elements.
    pub fn std(&self) -> f32 {
        std_dev(&self.data)
    }

    /// Sum of squared elements (spectral energy of the whole tensor).
    pub fn energy(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max |x| over all elements.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Elementwise maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()))
    }

    /// Relative L2 error `||a-b|| / (||b|| + eps)` — the codec fidelity metric.
    pub fn rel_l2_error(&self, reference: &Tensor) -> f64 {
        assert_eq!(self.shape(), reference.shape());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in self.data.iter().zip(&reference.data) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        (num.sqrt()) / (den.sqrt() + 1e-12)
    }
}

/// Min/max of a slice, NaN-tolerant. Empty → (0, 0).
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        if x.is_nan() {
            continue;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn new_checks_len() {
        let t = Tensor::new(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn new_rejects_bad_len() {
        Tensor::new(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn channel_views_are_disjoint_and_ordered() {
        // NCHW layout: channel (b,c) starts at (b*C+c)*H*W.
        let data: Vec<f32> = (0..2 * 3 * 2 * 2).map(|i| i as f32).collect();
        let t = Tensor::new(&[2, 3, 2, 2], data);
        assert_eq!(t.channel(0, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(t.channel(0, 2), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(t.channel(1, 0), &[12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn rank3_is_batch_one() {
        let t = Tensor::zeros(&[3, 4, 5]);
        assert_eq!(t.as_bchw(), (1, 3, 4, 5));
    }

    #[test]
    fn stats() {
        let t = Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.min_max(), (1.0, 4.0));
        assert!((t.mean() - 2.5).abs() < 1e-6);
        assert!((t.std() - (1.25f32).sqrt()).abs() < 1e-6);
        assert!((t.energy() - 30.0).abs() < 1e-9);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    fn min_max_handles_nan_and_empty() {
        assert_eq!(min_max(&[]), (0.0, 0.0));
        assert_eq!(min_max(&[f32::NAN, 2.0, -1.0]), (-1.0, 2.0));
    }

    #[test]
    fn rel_l2_error_zero_for_identical() {
        let mut rng = Pcg32::seeded(5);
        let t = Tensor::randn(&[2, 3, 4, 4], 1.0, &mut rng);
        assert!(t.rel_l2_error(&t) < 1e-12);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::zeros(&[2, 8]).reshape(&[4, 4]);
        assert_eq!(t.shape(), &[4, 4]);
    }
}
