//! `slfac` — the SL-FAC coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! * `train`       — run one split-learning experiment from a config file
//!                   (plus CLI overrides), writing a metrics CSV.
//! * `sweep`       — declarative experiment grids: `sweep run` executes (or
//!                   resumes) a `configs/sweeps/*.json` spec with journaled
//!                   checkpoints, `sweep status` shows grid progress,
//!                   `sweep report` emits paginated `slfac-sweep/1` JSON.
//! * `inspect`     — print the artifact manifest and codec wire diagnostics.
//! * `bench-codec` — quick codec throughput/ratio table (the full harness
//!                   is `cargo bench`).
//!
//! Examples:
//!
//! ```text
//! slfac train --config configs/mnist_iid.json --codec slfac --rounds 15
//! slfac train --codec tk-sl --partition non-iid --out results/tk_noniid.csv
//! slfac train --scheduler async --profile wifi/lte --straggler deadline-drop \
//!     --deadline-s 0.5 --devices 64
//! slfac train --scheduler async --devices 128 --uplink shared \
//!     --shared-uplink-mbps 100 --server-service-s 0.002 --sample-fraction 0.25
//! slfac train --scheduler async --devices 100000 --cohorts 2 --profile wifi/lte
//! slfac train --devices 64 --downlink shared --shared-downlink-mbps 200
//! slfac train --scheduler async --loss-prob 0.05 --corrupt-prob 0.02 --max-retries 3
//! slfac sweep run --spec configs/sweeps/fig2_convergence.json --workers 4
//! slfac sweep status --spec configs/sweeps/fig2_convergence.json
//! slfac sweep report --spec configs/sweeps/fig2_convergence.json \
//!     --page-size 8 --cursor run:7
//! slfac inspect --artifacts artifacts
//! slfac bench-codec --shape 32x16x14x14
//! ```

use anyhow::{Context, Result};
use slfac::cli::{CliError, Command, Matches};
use slfac::codec;
use slfac::config::{DatasetKind, ExperimentConfig, Partition, SyncMode};
use slfac::transport::{ClientSampling, DownlinkMode, SchedulerKind, StragglerPolicy, UplinkMode};

fn cli() -> Command {
    Command::new("slfac", "SL-FAC: communication-efficient split learning")
        .subcommand(
            Command::new("train", "run a split-learning experiment")
                .opt("config", "PATH", "JSON experiment config", None)
                .opt("codec", "NAME", "codec override (slfac, pq-sl, tk-sl, fc-sl, ...)", None)
                .opt("dataset", "NAME", "dataset override (mnist, ham)", None)
                .opt("partition", "KIND", "iid | non-iid", None)
                .opt("rounds", "N", "communication rounds", None)
                .opt("theta", "F", "AFD energy threshold", None)
                .opt(
                    "drop-threshold",
                    "F",
                    "feature-wise codec: drop channels below this fraction of \
                     the max channel std",
                    None,
                )
                .opt(
                    "subspace-fraction",
                    "F",
                    "nsc-sl codec: subspace rank as a fraction of the plane size",
                    None,
                )
                .opt(
                    "codec-fast-path",
                    "BOOL",
                    "fused codec kernels (true, default) or reference kernels \
                     (false); wire bytes are bit-identical either way",
                    None,
                )
                .opt(
                    "compute-fast-path",
                    "BOOL",
                    "blocked GEMM kernels + device-resident model state (true, \
                     default) or the artifact execute path with reference \
                     kernels (false); results are bit-identical either way",
                    None,
                )
                .opt("devices", "N", "edge devices", None)
                .opt("workers", "N", "round-engine worker threads (0 = auto)", None)
                .opt("seed", "N", "master seed", None)
                .opt("sync", "MODE", "parallel | sequential", None)
                .opt("scheduler", "KIND", "round scheduler: sync | async", None)
                .opt(
                    "profile",
                    "SPEC",
                    "device profiles: config | wifi | lte | 5g | ethernet | mixes (wifi/lte)",
                    None,
                )
                .opt("straggler", "POLICY", "async policy: wait-all | deadline-drop | quorum", None)
                .opt("deadline-s", "SECS", "simulated round deadline (deadline-drop)", None)
                .opt("quorum-k", "N", "devices that must finish (quorum)", None)
                .opt("base-compute-s", "SECS", "simulated client compute per phase", None)
                .opt("uplink", "MODE", "uplink contention: private | shared", None)
                .opt(
                    "shared-uplink-mbps",
                    "MBPS",
                    "shared pipe capacity (default: uplink_mbps)",
                    None,
                )
                .opt("downlink", "MODE", "downlink contention: private | shared", None)
                .opt(
                    "shared-downlink-mbps",
                    "MBPS",
                    "shared server-egress capacity (default: downlink_mbps)",
                    None,
                )
                .opt(
                    "cohorts",
                    "N",
                    "cohort-compressed rounds for fleet scale (0 = per-device; \
                     results are bit-identical either way)",
                    None,
                )
                .opt("server-service-s", "SECS", "simulated server time per batch", None)
                .opt("loss-prob", "P", "per-message seeded loss probability, [0, 1]", None)
                .opt(
                    "corrupt-prob",
                    "P",
                    "per-message seeded payload bit-corruption probability, [0, 1]",
                    None,
                )
                .opt("crash-rate", "P", "per-round device crash probability, [0, 1)", None)
                .opt("max-retries", "N", "retransmissions before a device is dropped", None)
                .opt("retry-base-s", "SECS", "retransmission backoff base (doubles per attempt)", None)
                .opt(
                    "server-outage-s",
                    "SECS",
                    "seeded per-round server outage window duration",
                    None,
                )
                .opt("sample-fraction", "F", "fraction of devices per round, (0, 1]", None)
                .opt("sample-k", "N", "devices sampled per round", None)
                .opt("backend", "KIND", "executor backend: xla | sim", Some("xla"))
                .opt("artifacts", "DIR", "artifacts directory", None)
                .opt("out", "PATH", "metrics CSV output path", None)
                .opt(
                    "checkpoint-every",
                    "N",
                    "write a crash-durable checkpoint every N rounds (0 = off; \
                     needs --checkpoint-dir)",
                    None,
                )
                .opt("checkpoint-dir", "DIR", "checkpoint directory", None)
                .opt(
                    "stop-after-round",
                    "N",
                    "interrupt the run after checkpointing round N (runtime-only \
                     knob for crash-resume testing; config and fingerprint keep \
                     the full --rounds)",
                    None,
                )
                .flag(
                    "resume",
                    "resume from the newest checkpoint in --checkpoint-dir \
                     (fresh start if the directory is empty)",
                )
                .flag("quiet", "suppress per-round logs"),
        )
        .subcommand(
            Command::new("sweep", "declarative experiment grids (run | status | report)")
                .subcommand(
                    Command::new("run", "execute (or resume) a sweep spec")
                        .opt("spec", "PATH", "sweep spec JSON (see configs/sweeps/)", None)
                        .opt("workers", "N", "concurrent runs (0 = auto; overrides spec)", None)
                        .opt(
                            "stop-after",
                            "N",
                            "execute at most N new runs, then stop cleanly (resumable)",
                            None,
                        )
                        .opt("out-dir", "DIR", "results root", Some("results"))
                        .opt("journal", "PATH", "journal path override", None)
                        .opt(
                            "checkpoint-every",
                            "N",
                            "per-run crash-durable checkpoints every N rounds \
                             (0 = off); interrupted runs resume mid-run instead \
                             of restarting",
                            None,
                        )
                        .flag("quiet", "suppress per-round logs"),
                )
                .subcommand(
                    Command::new("status", "show journaled grid progress")
                        .opt("spec", "PATH", "sweep spec JSON", None)
                        .opt("out-dir", "DIR", "results root", Some("results"))
                        .opt("journal", "PATH", "journal path override", None),
                )
                .subcommand(
                    Command::new("report", "emit a paginated slfac-sweep/1 report page")
                        .opt("spec", "PATH", "sweep spec JSON", None)
                        .opt("out-dir", "DIR", "results root", Some("results"))
                        .opt("journal", "PATH", "journal path override", None)
                        .opt("page-size", "N", "runs per page (0 = everything)", Some("0"))
                        .opt("cursor", "CUR", "resume after this cursor (run:<id>)", None)
                        .opt("out", "PATH", "write the page here instead of stdout", None),
                ),
        )
        .subcommand(
            Command::new("inspect", "print manifest + codec diagnostics")
                .opt("artifacts", "DIR", "artifacts directory", Some("artifacts")),
        )
        .subcommand(
            Command::new("bench-codec", "quick codec ratio/fidelity table")
                .opt("shape", "BxCxMxN", "activation shape", Some("32x16x14x14"))
                .opt("theta", "F", "AFD energy threshold", Some("0.9")),
        )
}

fn main() {
    slfac::logging::init_from_env();
    let cmd = cli();
    let matches = match cmd.parse() {
        Ok(m) => m,
        Err(CliError::Help(h)) => {
            println!("{h}");
            return;
        }
        Err(CliError::Bad(msg)) => {
            eprintln!("error: {msg}\n\n{}", cmd.help());
            std::process::exit(2);
        }
    };
    let result = match &matches.subcommand {
        Some((name, sub)) => match name.as_str() {
            "train" => cmd_train(sub),
            "sweep" => cmd_sweep(sub),
            "inspect" => cmd_inspect(sub),
            "bench-codec" => cmd_bench_codec(sub),
            _ => unreachable!(),
        },
        None => {
            println!("{}", cmd.help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Apply CLI overrides on top of a (possibly loaded) config.
fn build_config(m: &Matches) -> Result<ExperimentConfig> {
    let mut cfg = match m.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(c) = m.get("codec") {
        cfg.codec = c.to_string();
    }
    if let Some(d) = m.get("dataset") {
        cfg.dataset = DatasetKind::parse(d)?;
    }
    if let Some(p) = m.get("partition") {
        cfg.partition = match p.to_ascii_lowercase().as_str() {
            "iid" => Partition::Iid,
            "non-iid" | "noniid" | "dirichlet" => Partition::Dirichlet(0.5),
            other => anyhow::bail!("unknown partition '{other}'"),
        };
    }
    if let Some(r) = m.get_parsed::<usize>("rounds").map_err(anyhow::Error::msg)? {
        cfg.rounds = r;
    }
    if let Some(t) = m.get_parsed::<f64>("theta").map_err(anyhow::Error::msg)? {
        cfg.codec_params.theta = t;
    }
    if let Some(t) = m
        .get_parsed::<f64>("drop-threshold")
        .map_err(anyhow::Error::msg)?
    {
        cfg.codec_params.drop_threshold = t;
    }
    if let Some(f) = m
        .get_parsed::<f64>("subspace-fraction")
        .map_err(anyhow::Error::msg)?
    {
        cfg.codec_params.subspace_fraction = f;
    }
    if let Some(f) = m
        .get_parsed::<bool>("codec-fast-path")
        .map_err(anyhow::Error::msg)?
    {
        cfg.codec_params.fast_path = f;
    }
    if let Some(f) = m
        .get_parsed::<bool>("compute-fast-path")
        .map_err(anyhow::Error::msg)?
    {
        cfg.compute_fast_path = f;
    }
    if let Some(d) = m.get_parsed::<usize>("devices").map_err(anyhow::Error::msg)? {
        cfg.devices = d;
    }
    if let Some(w) = m.get_parsed::<usize>("workers").map_err(anyhow::Error::msg)? {
        cfg.workers = w;
    }
    if let Some(s) = m.get_parsed::<u64>("seed").map_err(anyhow::Error::msg)? {
        cfg.seed = s;
        cfg.codec_params.seed = s;
    }
    if let Some(s) = m.get("sync") {
        cfg.sync = match s {
            "parallel" => SyncMode::ParallelFedAvg,
            "sequential" => SyncMode::Sequential,
            other => anyhow::bail!("unknown sync '{other}'"),
        };
    }
    if let Some(s) = m.get("scheduler") {
        cfg.scheduler = SchedulerKind::parse(s)?;
    }
    if let Some(p) = m.get("profile") {
        cfg.profile = p.to_string();
    }
    let deadline_s = m
        .get_parsed::<f64>("deadline-s")
        .map_err(anyhow::Error::msg)?;
    let quorum_k = m
        .get_parsed::<usize>("quorum-k")
        .map_err(anyhow::Error::msg)?;
    if let Some(s) = m.get("straggler") {
        cfg.straggler = StragglerPolicy::from_parts(s, deadline_s, quorum_k)?;
    } else if deadline_s.is_some() || quorum_k.is_some() {
        anyhow::bail!("--deadline-s/--quorum-k need --straggler");
    }
    if let Some(c) = m
        .get_parsed::<f64>("base-compute-s")
        .map_err(anyhow::Error::msg)?
    {
        cfg.base_compute_s = c;
    }
    if let Some(u) = m.get("uplink") {
        cfg.uplink = UplinkMode::parse(u)?;
    }
    if let Some(mbps) = m
        .get_parsed::<f64>("shared-uplink-mbps")
        .map_err(anyhow::Error::msg)?
    {
        cfg.shared_uplink_bps = Some(mbps * 1e6);
    }
    if let Some(d) = m.get("downlink") {
        cfg.downlink = DownlinkMode::parse(d)?;
    }
    if let Some(mbps) = m
        .get_parsed::<f64>("shared-downlink-mbps")
        .map_err(anyhow::Error::msg)?
    {
        cfg.shared_downlink_bps = Some(mbps * 1e6);
    }
    if let Some(c) = m.get_parsed::<usize>("cohorts").map_err(anyhow::Error::msg)? {
        cfg.cohorts = c;
    }
    if let Some(s) = m
        .get_parsed::<f64>("server-service-s")
        .map_err(anyhow::Error::msg)?
    {
        cfg.server_service_s = s;
    }
    if let Some(p) = m
        .get_parsed::<f64>("loss-prob")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.loss_prob = p;
    }
    if let Some(p) = m
        .get_parsed::<f64>("corrupt-prob")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.corrupt_prob = p;
    }
    if let Some(p) = m
        .get_parsed::<f64>("crash-rate")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.crash_rate = p;
    }
    if let Some(n) = m
        .get_parsed::<u32>("max-retries")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.max_retries = n;
    }
    if let Some(s) = m
        .get_parsed::<f64>("retry-base-s")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.retry_base_s = s;
    }
    if let Some(s) = m
        .get_parsed::<f64>("server-outage-s")
        .map_err(anyhow::Error::msg)?
    {
        cfg.fault.server_outage_s = s;
    }
    let sample_fraction = m
        .get_parsed::<f64>("sample-fraction")
        .map_err(anyhow::Error::msg)?;
    let sample_k = m
        .get_parsed::<usize>("sample-k")
        .map_err(anyhow::Error::msg)?;
    if sample_fraction.is_some() || sample_k.is_some() {
        cfg.sampling = ClientSampling::from_parts(sample_fraction, sample_k)?;
    }
    if let Some(a) = m.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(n) = m
        .get_parsed::<usize>("checkpoint-every")
        .map_err(anyhow::Error::msg)?
    {
        cfg.checkpoint_every = n;
    }
    if let Some(d) = m.get("checkpoint-dir") {
        cfg.checkpoint_dir = d.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(m: &Matches) -> Result<()> {
    if m.flag("quiet") {
        slfac::logging::set_level(slfac::logging::Level::Warn);
    }
    let cfg = build_config(m)?;
    let backend = match m.get("backend").unwrap_or("xla") {
        "xla" => slfac::runtime::BackendKind::Xla,
        "sim" => slfac::runtime::BackendKind::Sim,
        other => anyhow::bail!("unknown backend '{other}' (expected xla | sim)"),
    };
    let exec = slfac::runtime::ExecutorHandle::spawn_backend(
        &cfg.artifacts_dir,
        &[cfg.dataset.name().to_string()],
        backend,
    )?;
    let name = cfg.name.clone();
    let codec_name = cfg.codec.clone();
    let resume = m.flag("resume");
    if resume && cfg.checkpoint_dir.is_empty() {
        anyhow::bail!("--resume requires --checkpoint-dir (and --checkpoint-every > 0)");
    }
    let stop_after = m
        .get_parsed::<usize>("stop-after-round")
        .map_err(anyhow::Error::msg)?;
    let mut trainer = slfac::coordinator::Trainer::new(cfg, exec)?;
    if resume {
        let completed = trainer.resume_latest()?;
        if completed > 0 {
            println!("resumed from checkpoint: {completed} rounds already done");
        }
    }
    trainer.set_stop_after(stop_after);
    let outcome = trainer.run()?;
    println!("{}", outcome.history.summary());
    println!(
        "comm: {:.2} MB up, {:.2} MB down, makespan {:.2}s; exec: {} runs, {:.2}s total",
        outcome.comm.uplink_bytes as f64 / 1e6,
        outcome.comm.downlink_bytes as f64 / 1e6,
        outcome.comm.makespan_s,
        outcome.exec_stats.total_execs(),
        outcome.exec_stats.total_time().as_secs_f64(),
    );
    let out_path = m
        .get("out")
        .map(|s| s.to_string())
        .unwrap_or_else(|| format!("results/{name}_{codec_name}.csv"));
    outcome.history.write_csv(&out_path)?;
    println!("metrics -> {out_path}");
    Ok(())
}

/// Load the spec + options shared by every `sweep` subcommand.
fn sweep_common(m: &Matches) -> Result<(slfac::sweep::SweepSpec, slfac::sweep::SweepOptions)> {
    let spec_path = m.req("spec").map_err(anyhow::Error::msg)?;
    let spec = slfac::sweep::SweepSpec::load(spec_path)?;
    let opts = slfac::sweep::SweepOptions {
        workers: m.get_parsed::<usize>("workers").map_err(anyhow::Error::msg)?,
        stop_after: m
            .get_parsed::<usize>("stop-after")
            .map_err(anyhow::Error::msg)?,
        out_dir: m.req("out-dir").map_err(anyhow::Error::msg)?.to_string(),
        journal_path: m.get("journal").map(|s| s.to_string()),
        checkpoint_every: m
            .get_parsed::<usize>("checkpoint-every")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(0),
    };
    Ok((spec, opts))
}

fn cmd_sweep(m: &Matches) -> Result<()> {
    match &m.subcommand {
        Some((name, sub)) => match name.as_str() {
            "run" => cmd_sweep_run(sub),
            "status" => cmd_sweep_status(sub),
            "report" => cmd_sweep_report(sub),
            _ => unreachable!(),
        },
        None => anyhow::bail!("sweep needs a subcommand: run | status | report"),
    }
}

fn cmd_sweep_run(m: &Matches) -> Result<()> {
    if m.flag("quiet") {
        slfac::logging::set_level(slfac::logging::Level::Warn);
    }
    let (spec, opts) = sweep_common(m)?;
    let outcome = slfac::sweep::run_sweep(&spec, &opts)?;
    slfac::experiments::print_sweep_tables(&spec.name, &outcome.results);
    println!(
        "sweep '{}': {} of {} runs journaled ({} skipped as already done, \
         {} executed now)",
        spec.name, outcome.completed, outcome.grid, outcome.skipped, outcome.executed
    );
    println!("journal -> {}", outcome.journal_path);
    println!("report  -> {}", outcome.report_path);
    if outcome.interrupted {
        println!(
            "stopped early (--stop-after): re-run the same command to resume \
             the remaining {} runs",
            outcome.grid - outcome.completed
        );
    }
    Ok(())
}

fn cmd_sweep_status(m: &Matches) -> Result<()> {
    let (spec, opts) = sweep_common(m)?;
    println!("{}", slfac::sweep::sweep_status(&spec, &opts)?.to_string());
    Ok(())
}

fn cmd_sweep_report(m: &Matches) -> Result<()> {
    let (spec, opts) = sweep_common(m)?;
    let runs = spec.expand()?;
    let jpath = slfac::sweep::journal_path(&spec, &opts);
    let journal = slfac::sweep::Journal::open(&jpath)
        .with_context(|| format!("no journal for sweep '{}' yet — run it first", spec.name))?;
    slfac::sweep::verify_journal(&spec, &runs, &journal)?;
    let cursor = match m.get("cursor") {
        Some(c) => Some(slfac::sweep::parse_cursor(c)?),
        None => None,
    };
    let page_size: usize = m
        .get_parsed("page-size")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0);
    let doc = slfac::sweep::page(journal.header(), journal.records(), cursor, page_size);
    match m.get("out") {
        Some(path) => {
            slfac::bench::report::write(path, &doc)?;
            println!("report page -> {path}");
        }
        None => println!("{}", doc.to_string()),
    }
    Ok(())
}

fn cmd_inspect(m: &Matches) -> Result<()> {
    let root = m.req("artifacts").map_err(anyhow::Error::msg)?;
    let manifest = slfac::runtime::ArtifactManifest::load(root)?;
    for (name, p) in &manifest.presets {
        println!(
            "preset {name}: batch {}, act {:?}, {} client + {} server params \
             ({} + {} elems)",
            p.batch_size,
            p.activation_shape,
            p.client_params.len(),
            p.server_params.len(),
            p.client_param_elems(),
            p.server_param_elems(),
        );
        for (aname, a) in &p.artifacts {
            println!(
                "  {aname:<12} {:>3} in {:>3} out  {:>6} HLO lines  ({})",
                a.inputs.len(),
                a.outputs.len(),
                a.hlo_lines,
                a.file
            );
        }
    }
    Ok(())
}

fn cmd_bench_codec(m: &Matches) -> Result<()> {
    let shape: Vec<usize> = m
        .req("shape")
        .map_err(anyhow::Error::msg)?
        .split('x')
        .map(|d| d.parse::<usize>().context("bad shape"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(shape.len() == 4, "shape must be BxCxMxN");
    let theta: f64 = m
        .get_parsed("theta")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0.9);
    let params = codec::CodecParams {
        theta,
        ..Default::default()
    };
    let x = codec::smooth_activations(&shape, 42);
    println!(
        "{:<12} {:>10} {:>8} {:>10}",
        "codec", "wire bytes", "ratio", "rel L2 err"
    );
    for name in codec::ALL_CODECS {
        let c = codec::by_name(name, &params)?;
        let (back, payload) = codec::roundtrip_spatial(c.as_ref(), &x)?;
        println!(
            "{:<12} {:>10} {:>7.1}x {:>10.4}",
            name,
            payload.wire_bytes(),
            payload.compression_ratio(),
            back.rel_l2_error(&x)
        );
    }
    Ok(())
}
