//! In-tree micro/meso benchmark harness (no `criterion` offline).
//!
//! Provides warmup + repeated timed runs with median / p10 / p90 and
//! throughput reporting, and a tiny table printer the `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`) share. Results print in a stable
//! plain-text format that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// 10th percentile.
    pub p10: Duration,
    /// 90th percentile.
    pub p90: Duration,
    /// Optional bytes processed per iteration (enables MB/s reporting).
    pub bytes_per_iter: Option<usize>,
    /// Optional items processed per iteration (enables Mitem/s reporting).
    pub items_per_iter: Option<usize>,
}

impl BenchResult {
    /// Throughput in MB/s if bytes were declared.
    pub fn mb_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| {
            b as f64 / (1024.0 * 1024.0) / self.median.as_secs_f64()
        })
    }

    /// Median-time speedup of `self` over `baseline` (> 1 ⇒ `self` is
    /// faster). Used by the sequential-vs-parallel round benchmarks.
    pub fn speedup_vs(&self, baseline: &BenchResult) -> f64 {
        baseline.median.as_secs_f64() / self.median.as_secs_f64().max(1e-12)
    }

    /// One formatted report line.
    pub fn line(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10} median  [{:>10} .. {:>10}]",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
        );
        if let Some(mbs) = self.mb_per_s() {
            s.push_str(&format!("  {mbs:9.1} MB/s"));
        }
        if let Some(items) = self.items_per_iter {
            let ips = items as f64 / self.median.as_secs_f64();
            s.push_str(&format!("  {:9.2} Mitem/s", ips / 1e6));
        }
        s
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    /// Target wall time spent measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(150),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Default-configured bencher. Honors `SLFAC_BENCH_MS` for CI speedups.
    pub fn new() -> Self {
        let mut b = Bencher::default();
        if let Ok(ms) = std::env::var("SLFAC_BENCH_MS") {
            if let Ok(ms) = ms.parse::<u64>() {
                b.measure_time = Duration::from_millis(ms);
                b.warmup_time = Duration::from_millis(ms / 4);
            }
        }
        b
    }

    /// Time `f` repeatedly; returns the recorded result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_full(name, None, None, &mut f)
    }

    /// Time `f`, declaring bytes processed per iteration (for MB/s).
    pub fn bench_bytes<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: usize,
        mut f: F,
    ) -> &BenchResult {
        self.bench_full(name, Some(bytes), None, &mut f)
    }

    /// Time `f`, declaring items processed per iteration.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: usize,
        mut f: F,
    ) -> &BenchResult {
        self.bench_full(name, None, Some(items), &mut f)
    }

    fn bench_full(
        &mut self,
        name: &str,
        bytes_per_iter: Option<usize>,
        items_per_iter: Option<usize>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup_time {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::with_capacity(256);
        let t1 = Instant::now();
        while t1.elapsed() < self.measure_time || samples.len() < 5 {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
            if samples.len() >= 100_000 {
                break;
            }
        }
        samples.sort_unstable();
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median: pct(0.5),
            p10: pct(0.1),
            p90: pct(0.9),
            bytes_per_iter,
            items_per_iter,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n=== {title} ===");
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Which bench sections to run, driven by an env var
/// (`SLFAC_BENCH_ONLY`). Unset or empty ⇒ every section runs. An unknown
/// section name is an **error** listing the valid names — it used to
/// silently run zero sections, which made a CI typo look like a pass.
#[derive(Debug, Clone)]
pub struct SectionFilter {
    only: Option<String>,
}

impl SectionFilter {
    /// Build from the environment variable `var`, validating the value
    /// against `sections`.
    pub fn from_env(var: &str, sections: &[&str]) -> Result<Self, String> {
        Self::from_value(std::env::var(var).ok().as_deref(), var, sections)
    }

    /// Testable core: `value` is the raw variable value (`None` = unset).
    pub fn from_value(value: Option<&str>, var: &str, sections: &[&str]) -> Result<Self, String> {
        match value {
            None | Some("") => Ok(SectionFilter { only: None }),
            Some(v) if sections.contains(&v) => Ok(SectionFilter {
                only: Some(v.to_string()),
            }),
            Some(v) => Err(format!(
                "{var}='{v}' names no bench section (valid: {})",
                sections.join(", ")
            )),
        }
    }

    /// Whether `section` should run under this filter.
    pub fn wants(&self, section: &str) -> bool {
        match &self.only {
            None => true,
            Some(o) => o == section,
        }
    }
}

pub mod report {
    //! Schema-versioned machine-readable result files.
    //!
    //! Every JSON the harness emits for machines — the bench trajectory
    //! files (`BENCH_codec.json` / `BENCH_compute.json` /
    //! `BENCH_fleet.json`) and the sweep control plane (journal header,
    //! status, paginated report pages) — carries a `schema` key of the
    //! form `slfac-<family>/<version>`, written through this one place so
    //! consumers dispatch on one stable field.

    use crate::json::Json;
    use std::collections::BTreeMap;

    /// Stable schema identifier: `slfac-<family>/<version>`.
    pub fn schema_id(family: &str, version: u32) -> String {
        format!("slfac-{family}/{version}")
    }

    /// Wrap `fields` into a versioned document by inserting the `schema`
    /// key.
    ///
    /// # Panics
    /// If `fields` already contains a `schema` key — the writer owns it.
    pub fn versioned(family: &str, version: u32, mut fields: BTreeMap<String, Json>) -> Json {
        let prev = fields.insert("schema".into(), Json::Str(schema_id(family, version)));
        assert!(prev.is_none(), "'schema' key is owned by bench::report");
        Json::Obj(fields)
    }

    /// Serialize `doc` compactly and write it to `path`, creating parent
    /// directories as needed.
    pub fn write(path: &str, doc: &Json) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, doc.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_sane_stats() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            results: Vec::new(),
        };
        let r = b
            .bench("spin", || {
                black_box((0..100).sum::<u64>());
            })
            .clone();
        assert!(r.iters >= 5);
        assert!(r.p10 <= r.median && r.median <= r.p90);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(1),
            p10: Duration::from_secs(1),
            p90: Duration::from_secs(1),
            bytes_per_iter: Some(2 * 1024 * 1024),
            items_per_iter: None,
        };
        assert!((r.mb_per_s().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_ratio() {
        let mk = |ms: u64| BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_millis(ms),
            p10: Duration::from_millis(ms),
            p90: Duration::from_millis(ms),
            bytes_per_iter: None,
            items_per_iter: None,
        };
        let fast = mk(100);
        let slow = mk(400);
        assert!((fast.speedup_vs(&slow) - 4.0).abs() < 1e-9);
        assert!((slow.speedup_vs(&fast) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
    }

    #[test]
    fn section_filter_accepts_known_rejects_unknown() {
        let sections = ["codec", "compute", "fleet"];
        let all = SectionFilter::from_value(None, "SLFAC_BENCH_ONLY", &sections).unwrap();
        assert!(all.wants("codec") && all.wants("fleet"));
        let empty =
            SectionFilter::from_value(Some(""), "SLFAC_BENCH_ONLY", &sections).unwrap();
        assert!(empty.wants("compute"));
        let one =
            SectionFilter::from_value(Some("codec"), "SLFAC_BENCH_ONLY", &sections).unwrap();
        assert!(one.wants("codec"));
        assert!(!one.wants("compute"));
        // the bugfix: an unknown name errors, listing the valid sections
        let err = SectionFilter::from_value(Some("codex"), "SLFAC_BENCH_ONLY", &sections)
            .unwrap_err();
        assert!(err.contains("codex"), "{err}");
        assert!(err.contains("codec, compute, fleet"), "{err}");
    }

    #[test]
    fn report_writer_stamps_schema() {
        use crate::json::Json;
        assert_eq!(report::schema_id("sweep", 1), "slfac-sweep/1");
        let mut fields = std::collections::BTreeMap::new();
        fields.insert("rows".to_string(), Json::Arr(vec![]));
        let doc = report::versioned("bench-codec", 1, fields);
        assert_eq!(
            doc.get("schema").unwrap().as_str(),
            Some("slfac-bench-codec/1")
        );
        assert_eq!(doc.to_string(), r#"{"rows":[],"schema":"slfac-bench-codec/1"}"#);
    }

    #[test]
    #[should_panic(expected = "schema")]
    fn report_writer_owns_schema_key() {
        use crate::json::Json;
        let mut fields = std::collections::BTreeMap::new();
        fields.insert("schema".to_string(), Json::Str("mine".into()));
        let _ = report::versioned("sweep", 1, fields);
    }
}
