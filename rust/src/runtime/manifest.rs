//! Parse `artifacts/manifest.json` (written by `python/compile/aot.py`).
//!
//! The manifest is the contract between the build-time Python layer and the
//! runtime: artifact file names, input/output signatures (shape + dtype in
//! flattened pytree order), parameter specs, and the activation shape the
//! codec operates on.

use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Shape + dtype of one HLO parameter or result leaf.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Dtype string (`"float32"`, `"int32"`).
    pub dtype: String,
}

impl TensorSig {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("sig.shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

/// One named parameter tensor (e.g. `stem.conv`).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Stable name.
    pub name: String,
    /// Shape.
    pub shape: Vec<usize>,
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    /// HLO text file, relative to the preset directory.
    pub file: String,
    /// Input signatures in HLO parameter order.
    pub inputs: Vec<TensorSig>,
    /// Output signatures in result-tuple order.
    pub outputs: Vec<TensorSig>,
    /// HLO line count (L2 size diagnostic).
    pub hlo_lines: usize,
}

/// Everything about one dataset preset.
#[derive(Debug, Clone)]
pub struct PresetManifest {
    /// Preset name (`mnist` / `ham`).
    pub name: String,
    /// Batch size the artifacts are specialized for.
    pub batch_size: usize,
    /// Image channels.
    pub in_channels: usize,
    /// Image height/width.
    pub image_hw: usize,
    /// Classes.
    pub num_classes: usize,
    /// Cut-layer activation shape (B, C, M, N).
    pub activation_shape: [usize; 4],
    /// Client-side parameter specs (flat lowering order).
    pub client_params: Vec<ParamSpec>,
    /// Server-side parameter specs.
    pub server_params: Vec<ParamSpec>,
    /// Entry points by name.
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl PresetManifest {
    /// Artifact lookup with a readable error.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .with_context(|| format!("preset '{}' has no artifact '{name}'", self.name))
    }

    /// Total client parameter count (elements).
    pub fn client_param_elems(&self) -> usize {
        self.client_params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }

    /// Total server parameter count (elements).
    pub fn server_param_elems(&self) -> usize {
        self.server_params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

/// The parsed manifest (all presets).
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Root directory the file was loaded from.
    pub root: String,
    /// Presets by name.
    pub presets: BTreeMap<String, PresetManifest>,
}

fn parse_params(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()
        .context("params must be an array")?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .context("param.name")?
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("param.shape")?
                    .iter()
                    .map(|d| d.as_usize().context("param dim"))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: &str) -> Result<Self> {
        let path = format!("{root}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        Self::from_json(root, &json)
    }

    /// Parse from JSON.
    pub fn from_json(root: &str, json: &Json) -> Result<Self> {
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .context("manifest.version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut presets = BTreeMap::new();
        for (name, p) in json
            .get("presets")
            .and_then(Json::as_obj)
            .context("manifest.presets")?
        {
            let act: Vec<usize> = p
                .get("activation_shape")
                .and_then(Json::as_arr)
                .context("activation_shape")?
                .iter()
                .map(|d| d.as_usize().context("act dim"))
                .collect::<Result<Vec<_>>>()?;
            if act.len() != 4 {
                bail!("activation_shape must be rank 4");
            }
            let mut artifacts = BTreeMap::new();
            for (aname, a) in p
                .get("artifacts")
                .and_then(Json::as_obj)
                .context("artifacts")?
            {
                let sigs = |key: &str| -> Result<Vec<TensorSig>> {
                    a.get(key)
                        .and_then(Json::as_arr)
                        .with_context(|| format!("{aname}.{key}"))?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect()
                };
                artifacts.insert(
                    aname.clone(),
                    ArtifactSig {
                        file: a
                            .get("file")
                            .and_then(Json::as_str)
                            .context("artifact.file")?
                            .to_string(),
                        inputs: sigs("inputs")?,
                        outputs: sigs("outputs")?,
                        hlo_lines: a
                            .get("hlo_lines")
                            .and_then(Json::as_usize)
                            .unwrap_or(0),
                    },
                );
            }
            presets.insert(
                name.clone(),
                PresetManifest {
                    name: name.clone(),
                    batch_size: p
                        .get("batch_size")
                        .and_then(Json::as_usize)
                        .context("batch_size")?,
                    in_channels: p
                        .get("in_channels")
                        .and_then(Json::as_usize)
                        .context("in_channels")?,
                    image_hw: p.get("image_hw").and_then(Json::as_usize).context("image_hw")?,
                    num_classes: p
                        .get("num_classes")
                        .and_then(Json::as_usize)
                        .context("num_classes")?,
                    activation_shape: [act[0], act[1], act[2], act[3]],
                    client_params: parse_params(
                        p.get("client_params").context("client_params")?,
                    )?,
                    server_params: parse_params(
                        p.get("server_params").context("server_params")?,
                    )?,
                    artifacts,
                },
            );
        }
        Ok(ArtifactManifest {
            root: root.to_string(),
            presets,
        })
    }

    /// Preset lookup with a readable error.
    pub fn preset(&self, name: &str) -> Result<&PresetManifest> {
        self.presets
            .get(name)
            .with_context(|| format!("manifest has no preset '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "presets": {
        "mnist": {
          "batch_size": 32, "in_channels": 1, "image_hw": 28, "num_classes": 10,
          "activation_shape": [32, 16, 14, 14],
          "client_params": [{"name": "stem.conv", "shape": [3,3,1,16]}],
          "server_params": [{"name": "fc.w", "shape": [64,10]}],
          "artifacts": {
            "idct": {"file": "idct.hlo.txt",
                     "inputs": [{"shape": [32,16,14,14], "dtype": "float32"}],
                     "outputs": [{"shape": [32,16,14,14], "dtype": "float32"}],
                     "hlo_lines": 83}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let json = Json::parse(SAMPLE).unwrap();
        let m = ArtifactManifest::from_json("artifacts", &json).unwrap();
        let p = m.preset("mnist").unwrap();
        assert_eq!(p.batch_size, 32);
        assert_eq!(p.activation_shape, [32, 16, 14, 14]);
        assert_eq!(p.client_params[0].name, "stem.conv");
        let a = p.artifact("idct").unwrap();
        assert_eq!(a.inputs.len(), 1);
        assert_eq!(a.hlo_lines, 83);
    }

    #[test]
    fn missing_preset_errors() {
        let json = Json::parse(SAMPLE).unwrap();
        let m = ArtifactManifest::from_json("artifacts", &json).unwrap();
        assert!(m.preset("cifar").is_err());
        assert!(m.preset("mnist").unwrap().artifact("nope").is_err());
    }

    #[test]
    fn version_check() {
        let json = Json::parse(r#"{"version": 2, "presets": {}}"#).unwrap();
        assert!(ArtifactManifest::from_json("x", &json).is_err());
    }

    #[test]
    fn param_elem_counts() {
        let json = Json::parse(SAMPLE).unwrap();
        let m = ArtifactManifest::from_json("artifacts", &json).unwrap();
        let p = m.preset("mnist").unwrap();
        assert_eq!(p.client_param_elems(), 3 * 3 * 16);
        assert_eq!(p.server_param_elems(), 640);
    }
}
