//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator's hot path.
//!
//! XLA handles (`PjRtClient`, executables, `Literal`) wrap raw C++ pointers
//! and are not `Send`, so all of them are **confined to one executor actor
//! thread** ([`executor`]). The rest of the system talks to it through a
//! channel protocol carrying [`HostTensor`]s (plain `Vec<f32>`/`Vec<i32>` +
//! dims) — cheap relative to model execution, and it keeps every other
//! thread free of FFI state.
//!
//! Python never runs here: artifacts are produced once by
//! `python/compile/aot.py` (`make artifacts`) and described by
//! `artifacts/manifest.json` ([`manifest`]).

pub mod executor;
pub mod host;
pub mod manifest;

pub use executor::{ExecutorHandle, ExecutorStats};
pub use host::HostTensor;
pub use manifest::{ArtifactManifest, PresetManifest};
