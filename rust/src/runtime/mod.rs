//! Model runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator's hot path.
//!
//! XLA handles (`PjRtClient`, executables, `Literal`) wrap raw C++ pointers
//! and are not `Send`, so all of them are **confined to one executor actor
//! thread** ([`executor`]). The rest of the system talks to it through a
//! channel protocol carrying [`HostTensor`]s (plain `Vec<f32>`/`Vec<i32>` +
//! dims) — cheap relative to model execution, and it keeps every other
//! thread free of FFI state.
//!
//! Python never runs here: artifacts are produced once by
//! `python/compile/aot.py` (`make artifacts`) and described by
//! `artifacts/manifest.json` ([`manifest`]).
//!
//! When no XLA runtime or HLO artifacts are available (offline CI), the
//! [`sim`] backend serves the same artifact names with a small
//! deterministic pure-Rust split model — see
//! [`ExecutorHandle::spawn_sim`]. Being `Send + Sync` and pure, it runs
//! **inline on the calling thread** with mutex-guarded statistics, so the
//! parallel round engine's workers execute client-side model compute
//! genuinely concurrently.
//!
//! On top of the sim backend, [`compute`] provides the planned
//! zero-allocation fast path: blocked GEMM kernels plus device-resident
//! model state behind [`ExecutorHandle::open_resident`]
//! (`compute_fast_path` config key) — bit-identical to the artifact
//! `execute` path, just without the per-step parameter round trips.

pub mod compute;
pub mod executor;
pub mod host;
pub mod manifest;
pub mod sim;

pub use compute::{ModelPlan, ResidentSession};
pub use executor::{BackendKind, ExecutorHandle, ExecutorStats};
pub use host::HostTensor;
pub use manifest::{ArtifactManifest, PresetManifest};
pub use sim::{write_sim_manifest, SimBackend, SimManifestSpec};
