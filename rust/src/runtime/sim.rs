//! Pure-Rust deterministic executor backend ("sim").
//!
//! The PJRT backend needs AOT HLO artifacts and a linked XLA runtime;
//! neither exists in offline CI, which would leave the coordinator's round
//! engine untestable end-to-end. The sim backend fills that gap: it serves
//! the same artifact names (`init`, `client_fwd`, `idct`, `server_step`,
//! `client_step`, `eval_step`) with a tiny real split model —
//!
//! * client: `act = tanh(x_flat · W_c)`, reshaped to the manifest's
//!   cut-layer activation shape, plus its 2-D DCT (via [`crate::dct`], the
//!   same transform the Pallas kernel computes in the HLO graphs);
//! * server: linear softmax classifier `logits = act_flat · W_s` with
//!   cross-entropy loss and SGD+momentum, returning the activation
//!   gradient in both domains exactly like the real `server_step`.
//!
//! Every operation is a pure function of its inputs with fixed loop order,
//! so results are **bit-deterministic and independent of request order** —
//! the property the differential determinism tests lean on. It is a
//! stand-in model (one linear layer per side, momentum fixed at
//! [`SIM_MOMENTUM`]), not the paper's ResNet; fidelity experiments still
//! require real artifacts.
//!
//! This artifact `execute` path runs the **reference kernels**
//! ([`super::compute`]'s `*_ref` family — the bit-frozen definition of the
//! model math) and round-trips full parameter tensors per call. The
//! planned, allocation-free twin is the device-resident fast path
//! ([`super::compute::ResidentSession`], `compute_fast_path` config key),
//! which is bit-identical by construction and pinned differentially in
//! `tests/compute_differential.rs`.
//!
//! Shape contract read from `manifest.json`: exactly one client parameter
//! `[in_dim, act_feat]` and one server parameter `[act_feat, num_classes]`,
//! where `in_dim = in_channels · image_hw²` and `act_feat` is the per-sample
//! activation size. [`write_sim_manifest`] emits a conforming manifest so
//! tests and benches can run from a temp directory.

use super::compute::{
    fwd_gemm_ref, gact_ref, grad_outer_ref, sgd_momentum_ref, softmax_xent_ref,
};
use super::host::HostTensor;
use super::manifest::ArtifactManifest;
use crate::dct::Dct2d;
use crate::json::Json;
use crate::rng::Pcg32;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// SGD momentum baked into the sim model (the real value lives in the HLO
/// graphs at lowering time, so it is likewise not a runtime input).
pub const SIM_MOMENTUM: f32 = 0.9;

/// Root seed for deterministic parameter init (per-preset streams derive
/// from it).
const SIM_INIT_SEED: u64 = 0x51AC_0515;

/// One preset's resolved sim-model dimensions. Shared with the
/// device-resident fast path ([`super::compute::ResidentSession`]), which
/// mirrors this model with planned kernels and in-place state.
#[derive(Debug, Clone)]
pub(crate) struct SimPreset {
    pub(crate) name: String,
    pub(crate) in_dim: usize,
    pub(crate) act_shape: [usize; 4],
    pub(crate) act_feat: usize,
    pub(crate) classes: usize,
    /// Stable per-preset init stream index.
    init_index: u64,
}

/// The sim backend: preset dimensions resolved once from the manifest.
#[derive(Debug, Clone)]
pub struct SimBackend {
    presets: BTreeMap<String, SimPreset>,
}

impl SimBackend {
    /// Resolve and validate the named presets against the sim shape
    /// contract.
    pub fn from_manifest(manifest: &ArtifactManifest, presets: &[String]) -> Result<Self> {
        let mut out = BTreeMap::new();
        for (pi, name) in presets.iter().enumerate() {
            let p = manifest.preset(name)?;
            let in_dim = p.in_channels * p.image_hw * p.image_hw;
            let act_shape = p.activation_shape;
            let act_feat = act_shape[1] * act_shape[2] * act_shape[3];
            ensure!(
                act_shape[0] == p.batch_size,
                "sim preset '{name}': activation batch {} != batch_size {}",
                act_shape[0],
                p.batch_size
            );
            ensure!(
                p.client_params.len() == 1
                    && p.client_params[0].shape == vec![in_dim, act_feat],
                "sim preset '{name}' needs one client param [{in_dim}, {act_feat}], got {:?}",
                p.client_params
                    .iter()
                    .map(|s| s.shape.clone())
                    .collect::<Vec<_>>()
            );
            ensure!(
                p.server_params.len() == 1
                    && p.server_params[0].shape == vec![act_feat, p.num_classes],
                "sim preset '{name}' needs one server param [{act_feat}, {}], got {:?}",
                p.num_classes,
                p.server_params
                    .iter()
                    .map(|s| s.shape.clone())
                    .collect::<Vec<_>>()
            );
            out.insert(
                name.clone(),
                SimPreset {
                    name: name.clone(),
                    in_dim,
                    act_shape,
                    act_feat,
                    classes: p.num_classes,
                    init_index: pi as u64,
                },
            );
        }
        Ok(SimBackend { presets: out })
    }

    /// Resolved preset lookup (shared with the resident fast path).
    pub(crate) fn preset(&self, name: &str) -> Result<&SimPreset> {
        self.presets
            .get(name)
            .with_context(|| format!("sim backend has no preset '{name}'"))
    }

    /// Execute artifact `preset/name` (same key format as the PJRT backend).
    pub fn execute(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (preset, name) = key
            .split_once('/')
            .with_context(|| format!("malformed artifact key '{key}'"))?;
        let p = self.preset(preset)?;
        match name {
            "init" => p.init(),
            "client_fwd" => p.client_fwd(inputs),
            "idct" => idct(inputs),
            "server_step" => p.server_step(inputs),
            "client_step" => p.client_step(inputs),
            "eval_step" => p.eval_step(inputs),
            other => bail!("sim backend has no artifact '{other}'"),
        }
    }
}

fn idct(inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
    ensure!(inputs.len() == 1, "idct takes 1 input, got {}", inputs.len());
    let coeffs = inputs.into_iter().next().unwrap().into_tensor()?;
    Ok(vec![HostTensor::from_tensor(&Dct2d::inverse_tensor(
        &coeffs,
    ))])
}

impl SimPreset {
    /// Flatten an image batch `[B, C, H, W]` and check the per-sample size.
    fn flat_batch<'a>(&self, x: &'a HostTensor) -> Result<(usize, &'a [f32])> {
        let dims = x.dims();
        ensure!(!dims.is_empty(), "sim: rank-0 image batch");
        let b = dims[0];
        ensure!(
            x.numel() == b * self.in_dim,
            "sim: batch numel {} != {} × in_dim {}",
            x.numel(),
            b,
            self.in_dim
        );
        Ok((b, x.as_f32()?))
    }

    /// `act = tanh(x_flat · W_c)` as a `[B, C, M, N]` tensor.
    fn forward_client(&self, w_c: &[f32], x: &HostTensor) -> Result<Tensor> {
        let (b, xf) = self.flat_batch(x)?;
        let mut z = fwd_gemm_ref(xf, w_c, b, self.in_dim, self.act_feat);
        for v in &mut z {
            *v = v.tanh();
        }
        let shape = [
            b,
            self.act_shape[1],
            self.act_shape[2],
            self.act_shape[3],
        ];
        Ok(Tensor::new(&shape, z))
    }

    /// Deterministic parameter init `(W_c, W_s)` — shared by the `init`
    /// artifact and the device-resident fast path, so both start from
    /// bit-identical parameters.
    pub(crate) fn init_weights(&self) -> (Vec<f32>, Vec<f32>) {
        let mut rng_c = Pcg32::derived(SIM_INIT_SEED, 0xC0DE, self.init_index);
        let mut rng_s = Pcg32::derived(SIM_INIT_SEED, 0x5E0F, self.init_index);
        let sc = 1.0 / (self.in_dim as f32).sqrt();
        let ss = 1.0 / (self.act_feat as f32).sqrt();
        let w_c: Vec<f32> = (0..self.in_dim * self.act_feat)
            .map(|_| rng_c.normal() * sc)
            .collect();
        let w_s: Vec<f32> = (0..self.act_feat * self.classes)
            .map(|_| rng_s.normal() * ss)
            .collect();
        (w_c, w_s)
    }

    fn init(&self) -> Result<Vec<HostTensor>> {
        let (w_c, w_s) = self.init_weights();
        Ok(vec![
            HostTensor::f32(&[self.in_dim, self.act_feat], w_c),
            HostTensor::f32(&[self.act_feat, self.classes], w_s),
        ])
    }

    fn client_fwd(&self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 2, "client_fwd takes [W_c, x]");
        let act = self.forward_client(inputs[0].as_f32()?, &inputs[1])?;
        let act_dct = Dct2d::forward_tensor(&act);
        Ok(vec![
            HostTensor::from_tensor(&act),
            HostTensor::from_tensor(&act_dct),
        ])
    }

    fn server_step(&self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 5, "server_step takes [W_s, M_s, act, y, lr]");
        let w_s = inputs[0].as_f32()?;
        let m_s = inputs[1].as_f32()?;
        let act = &inputs[2];
        let labels = inputs[3].as_i32()?;
        let lr = inputs[4].as_f32()?[0];
        let b = act.dims()[0];
        ensure!(
            act.numel() == b * self.act_feat,
            "server_step: act numel {} != {} × act_feat {}",
            act.numel(),
            b,
            self.act_feat
        );
        ensure!(labels.len() == b, "server_step: labels/batch mismatch");
        let a = act.as_f32()?;

        let logits = fwd_gemm_ref(a, w_s, b, self.act_feat, self.classes);
        let (loss, correct, dlogits) = softmax_xent_ref(&logits, labels, b, self.classes);

        // gW_s[j, k] = sum_b a[b, j] · dlogits[b, k]
        let g_ws = grad_outer_ref(a, &dlogits, b, self.act_feat, self.classes);
        // gact[b, j] = sum_k dlogits[b, k] · W_s[j, k]
        let gact = gact_ref(&dlogits, w_s, b, self.act_feat, self.classes);
        let (new_w, new_m) = sgd_momentum_ref(w_s, m_s, &g_ws, lr);
        let gact_t = Tensor::new(
            &[b, self.act_shape[1], self.act_shape[2], self.act_shape[3]],
            gact,
        );
        let gact_dct = Dct2d::forward_tensor(&gact_t);
        Ok(vec![
            HostTensor::f32(&[self.act_feat, self.classes], new_w),
            HostTensor::f32(&[self.act_feat, self.classes], new_m),
            HostTensor::scalar_f32(loss as f32),
            HostTensor::i32(&[], vec![correct as i32]),
            HostTensor::from_tensor(&gact_t),
            HostTensor::from_tensor(&gact_dct),
        ])
    }

    fn client_step(&self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 5, "client_step takes [W_c, M_c, x, gact, lr]");
        let w_c = inputs[0].as_f32()?;
        let m_c = inputs[1].as_f32()?;
        let x = &inputs[2];
        let gact = &inputs[3];
        let lr = inputs[4].as_f32()?[0];
        let (b, xf) = self.flat_batch(x)?;
        ensure!(
            gact.numel() == b * self.act_feat,
            "client_step: gact numel {} != {} × act_feat {}",
            gact.numel(),
            b,
            self.act_feat
        );

        // recompute act = tanh(z), then dz = gact ⊙ (1 − act²) — the
        // resident fast path skips this recompute by stashing `act` from
        // `client_fwd` (bit-identical: the stash holds the same tanh(z))
        let mut z = fwd_gemm_ref(xf, w_c, b, self.in_dim, self.act_feat);
        for (zv, &gv) in z.iter_mut().zip(gact.as_f32()?) {
            let a = zv.tanh();
            *zv = gv * (1.0 - a * a);
        }
        let dz = z;
        // gW_c[i, j] = sum_b x[b, i] · dz[b, j]
        let g_wc = grad_outer_ref(xf, &dz, b, self.in_dim, self.act_feat);
        let (new_w, new_m) = sgd_momentum_ref(w_c, m_c, &g_wc, lr);
        Ok(vec![
            HostTensor::f32(&[self.in_dim, self.act_feat], new_w),
            HostTensor::f32(&[self.in_dim, self.act_feat], new_m),
        ])
    }

    fn eval_step(&self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 4, "eval_step takes [W_c, W_s, x, y]");
        let w_s = inputs[1].as_f32()?;
        let labels = inputs[3].as_i32()?;
        let act = self.forward_client(inputs[0].as_f32()?, &inputs[2])?;
        let b = act.shape()[0];
        ensure!(labels.len() == b, "eval_step: labels/batch mismatch");
        let logits = fwd_gemm_ref(act.data(), w_s, b, self.act_feat, self.classes);
        let (loss, correct, _) = softmax_xent_ref(&logits, labels, b, self.classes);
        Ok(vec![
            HostTensor::scalar_f32(loss as f32),
            HostTensor::i32(&[], vec![correct as i32]),
        ])
    }
}

/// Dataset geometry per preset name (matches `data::synthetic`).
fn preset_geometry(preset: &str) -> Result<(usize, usize, usize)> {
    match preset {
        "mnist" => Ok((1, 28, 10)),
        "ham" => Ok((3, 32, 7)),
        other => bail!("unknown sim preset '{other}' (expected mnist|ham)"),
    }
}

/// One preset's sim manifest parameters.
#[derive(Debug, Clone)]
pub struct SimManifestSpec {
    /// Preset name (`mnist` / `ham`) — fixes image geometry and classes.
    pub preset: String,
    /// Batch size the run will use.
    pub batch_size: usize,
    /// Cut-layer activation channels.
    pub act_channels: usize,
    /// Cut-layer activation height/width.
    pub act_hw: usize,
}

/// Write a `manifest.json` under `dir` conforming to the sim shape
/// contract, so [`SimBackend`] (and the `Trainer` above it) can run from a
/// scratch directory with no Python/XLA step. Returns the manifest path.
pub fn write_sim_manifest(dir: &str, specs: &[SimManifestSpec]) -> Result<String> {
    let mut presets = BTreeMap::new();
    for s in specs {
        let (in_c, hw, classes) = preset_geometry(&s.preset)?;
        let in_dim = in_c * hw * hw;
        let act_feat = s.act_channels * s.act_hw * s.act_hw;
        let num = |v: usize| Json::Num(v as f64);
        let shape = |dims: &[usize]| Json::Arr(dims.iter().map(|&d| num(d)).collect());
        let param = |name: &str, dims: &[usize]| {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(name.to_string()));
            m.insert("shape".to_string(), shape(dims));
            Json::Obj(m)
        };
        let mut p = BTreeMap::new();
        p.insert("batch_size".to_string(), num(s.batch_size));
        p.insert("in_channels".to_string(), num(in_c));
        p.insert("image_hw".to_string(), num(hw));
        p.insert("num_classes".to_string(), num(classes));
        p.insert(
            "activation_shape".to_string(),
            shape(&[s.batch_size, s.act_channels, s.act_hw, s.act_hw]),
        );
        p.insert(
            "client_params".to_string(),
            Json::Arr(vec![param("sim.w_c", &[in_dim, act_feat])]),
        );
        p.insert(
            "server_params".to_string(),
            Json::Arr(vec![param("sim.w_s", &[act_feat, classes])]),
        );
        p.insert("artifacts".to_string(), Json::Obj(BTreeMap::new()));
        presets.insert(s.preset.clone(), Json::Obj(p));
    }
    let mut root = BTreeMap::new();
    root.insert("version".to_string(), Json::Num(1.0));
    root.insert("presets".to_string(), Json::Obj(presets));
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
    let path = format!("{dir}/manifest.json");
    std::fs::write(&path, Json::Obj(root).to_string())
        .with_context(|| format!("writing {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(label: &str) -> String {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        format!(
            "{}/slfac_sim_{label}_{}_{}",
            std::env::temp_dir().display(),
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        )
    }

    fn backend() -> SimBackend {
        let dir = scratch_dir("unit");
        write_sim_manifest(
            &dir,
            &[SimManifestSpec {
                preset: "mnist".into(),
                batch_size: 4,
                act_channels: 2,
                act_hw: 4,
            }],
        )
        .unwrap();
        let manifest = ArtifactManifest::load(&dir).unwrap();
        let b = SimBackend::from_manifest(&manifest, &["mnist".into()]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        b
    }

    fn batch(seed: u64) -> (HostTensor, HostTensor) {
        let mut rng = Pcg32::seeded(seed);
        let x: Vec<f32> = (0..4 * 784).map(|_| rng.normal() * 0.5).collect();
        let y: Vec<i32> = (0..4).map(|_| rng.below(10) as i32).collect();
        (HostTensor::f32(&[4, 1, 28, 28], x), HostTensor::i32(&[4], y))
    }

    #[test]
    fn init_is_deterministic_and_correctly_shaped() {
        let b = backend();
        let a = b.execute("mnist/init", vec![]).unwrap();
        let c = b.execute("mnist/init", vec![]).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].dims(), &[784, 32]);
        assert_eq!(a[1].dims(), &[32, 10]);
        assert_eq!(a, c);
    }

    #[test]
    fn fwd_dct_idct_roundtrip() {
        let b = backend();
        let params = b.execute("mnist/init", vec![]).unwrap();
        let (x, _) = batch(1);
        let out = b
            .execute("mnist/client_fwd", vec![params[0].clone(), x])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].dims(), &[4, 2, 4, 4]);
        // idct(dct(act)) ≈ act
        let back = b
            .execute("mnist/idct", vec![out[1].clone()])
            .unwrap()
            .remove(0);
        let diff = back
            .as_f32()
            .unwrap()
            .iter()
            .zip(out[0].as_f32().unwrap())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "idct roundtrip diff {diff}");
        // tanh bounds
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn training_steps_reduce_loss() {
        let b = backend();
        let mut params = b.execute("mnist/init", vec![]).unwrap();
        let mut w_c = params.remove(0);
        let mut w_s = params.remove(0);
        let zeros = |t: &HostTensor| HostTensor::f32(t.dims(), vec![0.0; t.numel()]);
        let (mut m_c, mut m_s) = (zeros(&w_c), zeros(&w_s));
        let (x, y) = batch(2);
        let lr = HostTensor::scalar_f32(0.1);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let fwd = b
                .execute("mnist/client_fwd", vec![w_c.clone(), x.clone()])
                .unwrap();
            let out = b
                .execute(
                    "mnist/server_step",
                    vec![w_s, m_s, fwd[0].clone(), y.clone(), lr.clone()],
                )
                .unwrap();
            let mut it = out.into_iter();
            w_s = it.next().unwrap();
            m_s = it.next().unwrap();
            losses.push(it.next().unwrap().first());
            let _correct = it.next().unwrap();
            let gact = it.next().unwrap();
            let back = b
                .execute(
                    "mnist/client_step",
                    vec![w_c, m_c, x.clone(), gact, lr.clone()],
                )
                .unwrap();
            let mut it = back.into_iter();
            w_c = it.next().unwrap();
            m_c = it.next().unwrap();
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(
            last < first * 0.8,
            "loss should drop: first {first} last {last}"
        );
        assert!(last.is_finite());
    }

    #[test]
    fn eval_matches_forward_pass() {
        let b = backend();
        let params = b.execute("mnist/init", vec![]).unwrap();
        let (x, y) = batch(3);
        let out = b
            .execute(
                "mnist/eval_step",
                vec![params[0].clone(), params[1].clone(), x, y],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out[0].first().is_finite());
        let correct = out[1].first();
        assert!((0.0..=4.0).contains(&correct));
    }

    #[test]
    fn rejects_malformed_requests() {
        let b = backend();
        assert!(b.execute("mnist/init", vec![]).is_ok());
        assert!(b.execute("nope/init", vec![]).is_err());
        assert!(b.execute("mnist/unknown", vec![]).is_err());
        assert!(b.execute("bad-key", vec![]).is_err());
        assert!(b.execute("mnist/client_fwd", vec![]).is_err());
    }

    #[test]
    fn manifest_contract_validated() {
        let dir = scratch_dir("bad");
        write_sim_manifest(
            &dir,
            &[SimManifestSpec {
                preset: "mnist".into(),
                batch_size: 4,
                act_channels: 2,
                act_hw: 4,
            }],
        )
        .unwrap();
        let manifest = ArtifactManifest::load(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        // asking for a preset the manifest lacks
        assert!(SimBackend::from_manifest(&manifest, &["ham".into()]).is_err());
    }
}
