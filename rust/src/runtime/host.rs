//! Host-side tensors: the `Send`-able currency between coordinator threads
//! and the executor actor.

use crate::tensor::Tensor;

/// A dense host tensor (f32 or i32), row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// 32-bit float tensor.
    F32 {
        /// Dimensions.
        dims: Vec<usize>,
        /// Row-major data.
        data: Vec<f32>,
    },
    /// 32-bit signed integer tensor (labels, counts).
    I32 {
        /// Dimensions.
        dims: Vec<usize>,
        /// Row-major data.
        data: Vec<i32>,
    },
}

impl HostTensor {
    /// f32 tensor from parts.
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::F32 {
            dims: dims.to_vec(),
            data,
        }
    }

    /// i32 tensor from parts.
    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::I32 {
            dims: dims.to_vec(),
            data,
        }
    }

    /// Scalar f32.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 {
            dims: vec![],
            data: vec![v],
        }
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    /// Borrow f32 data (panics on i32 tensors).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            HostTensor::I32 { .. } => panic!("expected f32 tensor, got i32"),
        }
    }

    /// Borrow i32 data (panics on f32 tensors).
    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data,
            HostTensor::F32 { .. } => panic!("expected i32 tensor, got f32"),
        }
    }

    /// First element as f64 (for scalar outputs like loss/correct).
    pub fn first(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => data[0] as f64,
            HostTensor::I32 { data, .. } => data[0] as f64,
        }
    }

    /// Convert into the codec [`Tensor`] type (f32 only).
    pub fn into_tensor(self) -> Tensor {
        match self {
            HostTensor::F32 { dims, data } => Tensor::new(&dims, data),
            HostTensor::I32 { .. } => panic!("cannot convert i32 tensor to codec Tensor"),
        }
    }

    /// Build from a codec [`Tensor`].
    pub fn from_tensor(t: &Tensor) -> Self {
        HostTensor::f32(t.shape(), t.data().to_vec())
    }

    /// Approximate wire size if transmitted raw (for accounting baselines).
    pub fn raw_bytes(&self) -> usize {
        self.numel() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_check_lengths() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dims(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn bad_length_panics() {
        HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_and_first() {
        assert_eq!(HostTensor::scalar_f32(2.5).first(), 2.5);
        assert_eq!(HostTensor::i32(&[], vec![7]).first(), 7.0);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(&[1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let h = HostTensor::from_tensor(&t);
        assert_eq!(h.into_tensor(), t);
    }
}
