//! Host-side tensors: the `Send`-able currency between coordinator threads
//! and the executor actor.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// A dense host tensor (f32 or i32), row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// 32-bit float tensor.
    F32 {
        /// Dimensions.
        dims: Vec<usize>,
        /// Row-major data.
        data: Vec<f32>,
    },
    /// 32-bit signed integer tensor (labels, counts).
    I32 {
        /// Dimensions.
        dims: Vec<usize>,
        /// Row-major data.
        data: Vec<i32>,
    },
}

impl HostTensor {
    /// f32 tensor from parts.
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::F32 {
            dims: dims.to_vec(),
            data,
        }
    }

    /// i32 tensor from parts.
    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::I32 {
            dims: dims.to_vec(),
            data,
        }
    }

    /// Scalar f32.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 {
            dims: vec![],
            data: vec![v],
        }
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.dims().iter().product()
    }

    /// Borrow f32 data; a typed error on i32 tensors so a malformed
    /// executor request surfaces as a failed round, not a panicked worker.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { dims, .. } => {
                bail!("expected f32 tensor, got i32 (dims {dims:?})")
            }
        }
    }

    /// Borrow i32 data; a typed error on f32 tensors.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { dims, .. } => {
                bail!("expected i32 tensor, got f32 (dims {dims:?})")
            }
        }
    }

    /// First element as f64 (for scalar outputs like loss/correct).
    pub fn first(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => data[0] as f64,
            HostTensor::I32 { data, .. } => data[0] as f64,
        }
    }

    /// Convert into the codec [`Tensor`] type (f32 only); a typed error
    /// on i32 tensors.
    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            HostTensor::F32 { dims, data } => Ok(Tensor::new(&dims, data)),
            HostTensor::I32 { dims, .. } => {
                bail!("cannot convert i32 tensor (dims {dims:?}) to codec Tensor")
            }
        }
    }

    /// Build from a codec [`Tensor`].
    pub fn from_tensor(t: &Tensor) -> Self {
        HostTensor::f32(t.shape(), t.data().to_vec())
    }

    /// Approximate wire size if transmitted raw (for accounting baselines).
    pub fn raw_bytes(&self) -> usize {
        self.numel() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_check_lengths() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dims(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn bad_length_panics() {
        HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_and_first() {
        assert_eq!(HostTensor::scalar_f32(2.5).first(), 2.5);
        assert_eq!(HostTensor::i32(&[], vec![7]).first(), 7.0);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(&[1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let h = HostTensor::from_tensor(&t);
        assert_eq!(h.into_tensor().unwrap(), t);
    }

    #[test]
    fn as_f32_on_i32_is_a_typed_error() {
        let t = HostTensor::i32(&[2], vec![1, 2]);
        let err = t.as_f32().unwrap_err().to_string();
        assert!(err.contains("expected f32"), "got: {err}");
        assert!(err.contains("[2]"), "error should name the dims: {err}");
    }

    #[test]
    fn as_i32_on_f32_is_a_typed_error() {
        let t = HostTensor::f32(&[3], vec![0.0; 3]);
        let err = t.as_i32().unwrap_err().to_string();
        assert!(err.contains("expected i32"), "got: {err}");
        assert!(err.contains("[3]"), "error should name the dims: {err}");
    }

    #[test]
    fn into_tensor_on_i32_is_a_typed_error() {
        let t = HostTensor::i32(&[1], vec![9]);
        let err = t.into_tensor().unwrap_err().to_string();
        assert!(err.contains("i32 tensor"), "got: {err}");
        assert!(err.contains("codec Tensor"), "got: {err}");
    }

    #[test]
    fn happy_paths_still_borrow() {
        assert_eq!(
            HostTensor::f32(&[2], vec![1.0, 2.0]).as_f32().unwrap(),
            &[1.0, 2.0]
        );
        assert_eq!(HostTensor::i32(&[1], vec![5]).as_i32().unwrap(), &[5]);
    }
}
