//! The executor actor: one thread owning the PJRT client and every compiled
//! executable, serving execution requests over a channel.
//!
//! Why an actor: the `xla` crate's handles wrap raw pointers without `Send`,
//! so they cannot migrate across the coordinator's device-worker threads.
//! Confining them to one thread is both sound and representative — the
//! paper's edge server is a single accelerator endpoint that serializes
//! model execution while codec work happens on device CPUs (our worker
//! threads).
//!
//! Requests and replies carry [`HostTensor`]s. Executables are compiled
//! once at startup from `artifacts/<preset>/*.hlo.txt`.

use super::host::HostTensor;
use super::manifest::ArtifactManifest;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Cumulative execution statistics (per artifact).
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    /// (executions, total time) per artifact key (`preset/name`).
    pub per_artifact: BTreeMap<String, (u64, Duration)>,
    /// Time spent compiling at startup.
    pub compile_time: Duration,
}

impl ExecutorStats {
    /// Total executions across artifacts.
    pub fn total_execs(&self) -> u64 {
        self.per_artifact.values().map(|(n, _)| n).sum()
    }

    /// Total execution time across artifacts.
    pub fn total_time(&self) -> Duration {
        self.per_artifact.values().map(|(_, t)| *t).sum()
    }
}

enum Request {
    Execute {
        key: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Stats {
        reply: mpsc::Sender<ExecutorStats>,
    },
    Shutdown,
}

/// Cloneable handle to the executor actor. Dropping all handles shuts the
/// actor down (via `Shutdown` or channel disconnect).
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Request>,
}

impl ExecutorHandle {
    /// Spawn the actor: loads the manifest at `artifacts_root`, compiles all
    /// artifacts of the named presets, and returns once ready (or with the
    /// startup error).
    pub fn spawn(artifacts_root: &str, presets: &[String]) -> Result<ExecutorHandle> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let root = artifacts_root.to_string();
        let presets = presets.to_vec();
        std::thread::Builder::new()
            .name("xla-executor".into())
            .spawn(move || actor_main(root, presets, rx, init_tx))
            .context("spawning executor thread")?;
        init_rx
            .recv()
            .context("executor thread died during startup")??;
        Ok(ExecutorHandle { tx })
    }

    /// Execute artifact `preset/name` with the given inputs; blocks for the
    /// flattened output tuple.
    pub fn execute(
        &self,
        preset: &str,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                key: format!("{preset}/{artifact}"),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("executor is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Snapshot execution statistics.
    pub fn stats(&self) -> Result<ExecutorStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow!("executor is gone"))?;
        rx.recv().context("executor dropped stats reply")
    }

    /// Ask the actor to exit (idempotent; happens anyway when handles drop).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

fn actor_main(
    root: String,
    presets: Vec<String>,
    rx: mpsc::Receiver<Request>,
    init_tx: mpsc::Sender<Result<()>>,
) {
    // --- startup: client + compile everything ---
    let started = Instant::now();
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = ArtifactManifest::load(&root)?;
        let mut exes = BTreeMap::new();
        for preset in &presets {
            let p = manifest.preset(preset)?;
            for (name, sig) in &p.artifacts {
                let path = format!("{root}/{preset}/{}", sig.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {path}"))?;
                exes.insert(format!("{preset}/{name}"), exe);
            }
        }
        Ok((client, exes))
    })();

    let (client, exes) = match setup {
        Ok(v) => {
            let _ = init_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    let _client = client; // keep alive for the executables' lifetime
    let compile_time = started.elapsed();
    crate::info!(
        "executor ready: {} executables compiled in {:.2}s",
        exes.len(),
        compile_time.as_secs_f64()
    );

    let mut stats = ExecutorStats {
        compile_time,
        ..Default::default()
    };

    // --- serve ---
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Execute { key, inputs, reply } => {
                let t0 = Instant::now();
                let result = run_one(&exes, &key, inputs);
                let e = stats.per_artifact.entry(key).or_default();
                e.0 += 1;
                e.1 += t0.elapsed();
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    exes: &BTreeMap<String, xla::PjRtLoadedExecutable>,
    key: &str,
    inputs: Vec<HostTensor>,
) -> Result<Vec<HostTensor>> {
    let exe = exes
        .get(key)
        .with_context(|| format!("no compiled artifact '{key}'"))?;
    let literals: Vec<xla::Literal> = inputs
        .into_iter()
        .map(to_literal)
        .collect::<Result<Vec<_>>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .with_context(|| format!("executing '{key}'"))?;
    let out = result[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    // aot.py lowers with return_tuple=True: output is always a tuple.
    let parts = out.to_tuple().context("decomposing result tuple")?;
    parts.into_iter().map(from_literal).collect()
}

fn to_literal(t: HostTensor) -> Result<xla::Literal> {
    // §Perf iteration 3: build the literal in ONE copy via
    // create_from_shape_and_untyped_data instead of vec1().reshape()
    // (two copies) — the executor converts ~0.5 MB per exec on the round
    // hot path.
    fn as_bytes<T>(v: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    match t {
        HostTensor::F32 { dims, data } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                as_bytes(&data),
            )
            .map_err(|e| anyhow!("create f32 literal: {e}"))
        }
        HostTensor::I32 { dims, data } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &dims,
                as_bytes(&data),
            )
            .map_err(|e| anyhow!("create i32 literal: {e}"))
        }
    }
}

fn from_literal(l: xla::Literal) -> Result<HostTensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("result literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::f32(
            &dims,
            l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
        )),
        xla::ElementType::S32 => Ok(HostTensor::i32(
            &dims,
            l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
        )),
        other => bail!("unsupported result element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    // Executor tests that need real artifacts live in rust/tests/ (they are
    // skipped when artifacts/ is absent). Here: handle-level error paths.
    use super::*;

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let err = ExecutorHandle::spawn("/nonexistent-path", &["mnist".into()])
            .err()
            .expect("must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "msg: {msg}");
    }
}
