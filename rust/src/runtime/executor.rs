//! The model executor behind one cloneable, thread-safe handle.
//!
//! Two backends, two execution disciplines:
//!
//! * **xla** — the PJRT path: compiles `artifacts/<preset>/*.hlo.txt` once
//!   at startup and executes on the accelerator. The `xla` crate's handles
//!   wrap raw pointers without `Send`, so they are confined to one
//!   **actor thread** and requests serialize over a channel. That is also
//!   representative: the paper's edge server is a single accelerator
//!   endpoint that serializes model execution while codec work happens on
//!   device CPUs (our worker threads).
//! * **sim** — [`super::sim::SimBackend`], a pure-Rust deterministic split
//!   model that needs only `manifest.json`. It is `Send + Sync` and free
//!   of shared mutable state, so it executes **inline on the calling
//!   thread**: the parallel round engine's workers run client-side model
//!   compute truly concurrently. Per-artifact statistics are kept behind
//!   a mutex (thread-safe accounting; counts are schedule-independent,
//!   only the wall-time fields vary).
//!
//! Requests and replies carry [`HostTensor`]s either way, so the
//! coordinator is backend-agnostic.

use super::host::HostTensor;
use super::manifest::ArtifactManifest;
use super::sim::SimBackend;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Cumulative execution statistics (per artifact).
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    /// (executions, total time) per artifact key (`preset/name`).
    pub per_artifact: BTreeMap<String, (u64, Duration)>,
    /// Time spent compiling at startup.
    pub compile_time: Duration,
}

impl ExecutorStats {
    /// Total executions across artifacts.
    pub fn total_execs(&self) -> u64 {
        self.per_artifact.values().map(|(n, _)| n).sum()
    }

    /// Total execution time across artifacts.
    pub fn total_time(&self) -> Duration {
        self.per_artifact.values().map(|(_, t)| *t).sum()
    }

    fn record(&mut self, key: String, elapsed: Duration) {
        let e = self.per_artifact.entry(key).or_default();
        e.0 += 1;
        e.1 += elapsed;
    }

    /// Like `record` but borrowed: only the first observation of a key
    /// allocates (the resident fast path records with pre-built keys, so
    /// its steady state stays allocation-free).
    pub(crate) fn record_ref(&mut self, key: &str, elapsed: Duration) {
        if let Some(e) = self.per_artifact.get_mut(key) {
            e.0 += 1;
            e.1 += elapsed;
        } else {
            self.per_artifact.insert(key.to_string(), (1, elapsed));
        }
    }
}

/// Which model backend an executor serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT/XLA over compiled HLO artifacts (actor thread).
    Xla,
    /// Pure-Rust deterministic sim model (inline, parallel-safe).
    Sim,
}

enum Request {
    Execute {
        key: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Stats {
        reply: mpsc::Sender<ExecutorStats>,
    },
    Shutdown,
}

/// Sim backend + its thread-safe statistics (shared with the
/// device-resident fast path, so both record into one stats table).
pub(crate) struct SimState {
    pub(crate) backend: SimBackend,
    pub(crate) stats: Mutex<ExecutorStats>,
}

#[derive(Clone)]
enum HandleInner {
    /// Channel to the XLA actor thread.
    Actor(mpsc::Sender<Request>),
    /// Shared inline sim backend.
    Sim(Arc<SimState>),
}

/// Cloneable handle to the executor. Cloning is cheap; every round-engine
/// worker uses the same handle concurrently. For the XLA backend,
/// dropping all handles shuts the actor down (via `Shutdown` or channel
/// disconnect).
#[derive(Clone)]
pub struct ExecutorHandle {
    inner: HandleInner,
}

impl ExecutorHandle {
    /// Spawn an XLA-backed actor: loads the manifest at `artifacts_root`,
    /// compiles all artifacts of the named presets, and returns once ready
    /// (or with the startup error).
    pub fn spawn(artifacts_root: &str, presets: &[String]) -> Result<ExecutorHandle> {
        Self::spawn_backend(artifacts_root, presets, BackendKind::Xla)
    }

    /// Build a sim-backed executor: needs only `manifest.json` under
    /// `artifacts_root` (see [`super::sim::write_sim_manifest`]).
    pub fn spawn_sim(artifacts_root: &str, presets: &[String]) -> Result<ExecutorHandle> {
        Self::spawn_backend(artifacts_root, presets, BackendKind::Sim)
    }

    /// Build an executor with an explicit backend choice.
    pub fn spawn_backend(
        artifacts_root: &str,
        presets: &[String],
        kind: BackendKind,
    ) -> Result<ExecutorHandle> {
        match kind {
            BackendKind::Xla => Self::spawn_actor(artifacts_root, presets),
            BackendKind::Sim => {
                let started = Instant::now();
                let manifest = ArtifactManifest::load(artifacts_root)?;
                let backend = SimBackend::from_manifest(&manifest, presets)?;
                let stats = Mutex::new(ExecutorStats {
                    compile_time: started.elapsed(),
                    ..Default::default()
                });
                Ok(ExecutorHandle {
                    inner: HandleInner::Sim(Arc::new(SimState { backend, stats })),
                })
            }
        }
    }

    fn spawn_actor(artifacts_root: &str, presets: &[String]) -> Result<ExecutorHandle> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let root = artifacts_root.to_string();
        let presets = presets.to_vec();
        std::thread::Builder::new()
            .name("xla-executor".into())
            .spawn(move || actor_main(root, presets, rx, init_tx))
            .context("spawning executor thread")?;
        init_rx
            .recv()
            .context("executor thread died during startup")??;
        Ok(ExecutorHandle {
            inner: HandleInner::Actor(tx),
        })
    }

    /// Execute artifact `preset/name` with the given inputs; blocks for the
    /// flattened output tuple.
    pub fn execute(
        &self,
        preset: &str,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let key = format!("{preset}/{artifact}");
        match &self.inner {
            HandleInner::Actor(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::Execute { key, inputs, reply })
                    .map_err(|_| anyhow!("executor is gone"))?;
                rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
            }
            HandleInner::Sim(sim) => {
                let t0 = Instant::now();
                let result = sim.backend.execute(&key, inputs);
                sim.stats.lock().unwrap().record(key, t0.elapsed());
                result
            }
        }
    }

    /// Snapshot execution statistics.
    pub fn stats(&self) -> Result<ExecutorStats> {
        match &self.inner {
            HandleInner::Actor(tx) => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request::Stats { reply })
                    .map_err(|_| anyhow!("executor is gone"))?;
                rx.recv().context("executor dropped stats reply")
            }
            HandleInner::Sim(sim) => Ok(sim.stats.lock().unwrap().clone()),
        }
    }

    /// Ask an actor-backed executor to exit (idempotent; happens anyway
    /// when handles drop). No-op for the inline sim backend.
    pub fn shutdown(&self) {
        if let HandleInner::Actor(tx) = &self.inner {
            let _ = tx.send(Request::Shutdown);
        }
    }

    /// Open a device-resident compute session over this executor's model
    /// (`compute_fast_path`; see [`super::compute`]). Returns `None` for
    /// backends without resident support (the XLA actor executes opaque
    /// HLO artifacts, so its state round-trips by design) — callers fall
    /// back to the artifact `execute` path, which is bit-identical.
    pub fn open_resident(
        &self,
        preset: &str,
        devices: usize,
    ) -> Result<Option<super::compute::ResidentSession>> {
        match &self.inner {
            HandleInner::Actor(_) => Ok(None),
            HandleInner::Sim(sim) => Ok(Some(super::compute::ResidentSession::new(
                sim.clone(),
                preset,
                devices,
            )?)),
        }
    }
}

fn actor_main(
    root: String,
    presets: Vec<String>,
    rx: mpsc::Receiver<Request>,
    init_tx: mpsc::Sender<Result<()>>,
) {
    // --- startup: manifest first (its error message carries the `make
    // artifacts` hint), then client + compile everything ---
    let started = Instant::now();
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<String, xla::PjRtLoadedExecutable>)> {
        let manifest = ArtifactManifest::load(&root)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for preset in &presets {
            let p = manifest.preset(preset)?;
            for (name, sig) in &p.artifacts {
                let path = format!("{root}/{preset}/{}", sig.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {path}"))?;
                exes.insert(format!("{preset}/{name}"), exe);
            }
        }
        Ok((client, exes))
    })();

    let (client, exes) = match setup {
        Ok(v) => {
            let _ = init_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    let _client = client; // keep alive for the executables' lifetime
    let compile_time = started.elapsed();
    crate::info!(
        "executor ready: {} executables compiled in {:.2}s",
        exes.len(),
        compile_time.as_secs_f64()
    );

    let mut stats = ExecutorStats {
        compile_time,
        ..Default::default()
    };

    // --- serve ---
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Execute { key, inputs, reply } => {
                let t0 = Instant::now();
                let result = run_one(&exes, &key, inputs);
                stats.record(key, t0.elapsed());
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    exes: &BTreeMap<String, xla::PjRtLoadedExecutable>,
    key: &str,
    inputs: Vec<HostTensor>,
) -> Result<Vec<HostTensor>> {
    let exe = exes
        .get(key)
        .with_context(|| format!("no compiled artifact '{key}'"))?;
    let literals: Vec<xla::Literal> = inputs
        .into_iter()
        .map(to_literal)
        .collect::<Result<Vec<_>>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .with_context(|| format!("executing '{key}'"))?;
    let out = result[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    // aot.py lowers with return_tuple=True: output is always a tuple.
    let parts = out.to_tuple().context("decomposing result tuple")?;
    parts.into_iter().map(from_literal).collect()
}

fn to_literal(t: HostTensor) -> Result<xla::Literal> {
    // §Perf iteration 3: build the literal in ONE copy via
    // create_from_shape_and_untyped_data instead of vec1().reshape()
    // (two copies) — the executor converts ~0.5 MB per exec on the round
    // hot path.
    fn as_bytes<T>(v: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    match t {
        HostTensor::F32 { dims, data } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                as_bytes(&data),
            )
            .map_err(|e| anyhow!("create f32 literal: {e}"))
        }
        HostTensor::I32 { dims, data } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &dims,
                as_bytes(&data),
            )
            .map_err(|e| anyhow!("create i32 literal: {e}"))
        }
    }
}

fn from_literal(l: xla::Literal) -> Result<HostTensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("result literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::f32(
            &dims,
            l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
        )),
        xla::ElementType::S32 => Ok(HostTensor::i32(
            &dims,
            l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
        )),
        other => bail!("unsupported result element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    // Executor tests that need real artifacts live in rust/tests/ (they are
    // skipped when artifacts/ is absent). Here: handle-level error paths
    // and the inline sim backend, including concurrent accounting.
    use super::*;
    use crate::runtime::sim::{write_sim_manifest, SimManifestSpec};

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let err = ExecutorHandle::spawn("/nonexistent-path", &["mnist".into()])
            .err()
            .expect("must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "msg: {msg}");
        // same contract for the sim backend
        let err = ExecutorHandle::spawn_sim("/nonexistent-path", &["mnist".into()])
            .err()
            .expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    fn sim_exec() -> (ExecutorHandle, String) {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let dir = format!(
            "{}/slfac_exec_sim_{}_{}",
            std::env::temp_dir().display(),
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        );
        write_sim_manifest(
            &dir,
            &[SimManifestSpec {
                preset: "mnist".into(),
                batch_size: 2,
                act_channels: 2,
                act_hw: 4,
            }],
        )
        .unwrap();
        let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".into()]).unwrap();
        (exec, dir)
    }

    #[test]
    fn sim_backend_serves_and_accounts() {
        let (exec, dir) = sim_exec();
        let params = exec.execute("mnist", "init", vec![]).unwrap();
        assert_eq!(params.len(), 2);
        let stats = exec.stats().unwrap();
        assert_eq!(stats.total_execs(), 1);
        assert!(stats.per_artifact.contains_key("mnist/init"));
        // unknown artifact errors but the handle stays usable
        assert!(exec.execute("mnist", "nope", vec![]).is_err());
        assert_eq!(exec.stats().unwrap().total_execs(), 2);
        exec.shutdown(); // no-op for sim
        assert!(exec.execute("mnist", "init", vec![]).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sim_stats_are_thread_safe_under_concurrent_execution() {
        let (exec, dir) = sim_exec();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let exec = exec.clone();
                s.spawn(move || {
                    for _ in 0..25 {
                        exec.execute("mnist", "init", vec![]).unwrap();
                    }
                });
            }
        });
        assert_eq!(exec.stats().unwrap().total_execs(), 100);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
