//! The planned, zero-steady-state-allocation compute backend behind the
//! sim executor — blocked GEMM kernels, device-resident model state, and a
//! fused backward path (see ARCHITECTURE.md "Compute hot path").
//!
//! # Two kernel families, one numeric contract
//!
//! Every kernel exists in two forms:
//!
//! * **Reference** (`*_ref`) — the historical naive loops the sim backend
//!   has always run through the artifact `execute` path. They allocate
//!   their outputs and are the bit-frozen definition of the model math.
//! * **Fast** — column-blocked / unrolled i-k-j loops writing into
//!   caller-owned buffers. Each output element still folds **exactly the
//!   same addends in exactly the same order** as its reference twin
//!   (ascending `k`, f32 accumulation, identical zero-skip tests), so the
//!   fast kernels are **bit-identical** — blocking only re-orders work
//!   across *independent* output elements, never within one element's
//!   accumulation chain. `tests/compute_differential.rs` pins this over
//!   randomized shapes and seeds; the end-to-end guarantee (same train
//!   curves, same wire bytes) rides on it.
//!
//! # Device-resident state ([`ResidentSession`])
//!
//! The artifact `execute` protocol is stateless: every `server_step` /
//! `client_step` ships full weight + momentum tensors in and fresh ones
//! out as `HostTensor`s. At fleet scale that is megabytes of clone + free
//! per device per batch — the dominant cost once the codec path is
//! allocation-free (PR 4). A `ResidentSession` instead keeps
//!
//! * one **client slot per device** — `W_c`, `M_c`, the stashed `tanh`
//!   activations of the last forward, and the backward scratch
//!   (`dz`, `gW_c`) plus a per-device [`Dct2d`] transformer;
//! * one **server slot** — `W_s`, `M_s`, the maintained transpose `W_sᵀ`
//!   (refreshed in the same pass as the SGD update, so the `gact`
//!   backward kernel reads unit-stride rows), and the step scratch
//!   (`logits`, the exp row, `dlogits`, `gW_s`, `gact`);
//! * an **aggregate slot** (FedAvg result + its f64 fold buffer) and an
//!   **eval slot** (batch staging + forward scratch).
//!
//! Weights update **in place**; the activation stash lets `client_step`
//! compute `dz = gact · (1 − act²)` without re-running the forward GEMM
//! (the stashed `tanh(z)` is the bit-same value the reference recomputes).
//! After one warm-up step per shape the whole training round performs zero
//! heap allocations (`tests/compute_zero_alloc.rs`).
//!
//! # Concurrency & determinism
//!
//! Every slot sits behind its own `Mutex`. The round engine's shard
//! ownership (one worker per device per phase) keeps the per-device locks
//! uncontended; the server slot is only touched from the serial
//! `server_step` phase. Slot *contents* never influence results — every
//! scratch buffer is fully overwritten before it is read — so carrying
//! state across rounds or worker counts is bit-transparent
//! (`parallel_determinism.rs` pins `compute_fast_path` × workers).

use super::executor::SimState;
use super::host::HostTensor;
use super::sim::{SimPreset, SIM_MOMENTUM};
use crate::data::Dataset;
use crate::dct::Dct2d;
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Column block width for the blocked GEMM kernels: 64 f32 = 256 B of
/// output tile, small enough to stay register/L1-resident while the
/// weight rows stream, large enough to amortize the loop overhead.
pub const COL_BLOCK: usize = 64;

// ---------------------------------------------------------------------------
// Kernels — reference (bit-frozen) and fast (blocked, bit-identical)
// ---------------------------------------------------------------------------

/// `acc[j] += a · x[j]` with an 8-wide unrolled body. Element order is
/// untouched (each `acc[j]` receives exactly one addend), so this is a
/// pure codegen aid (bounds-check elision + vectorization).
#[inline]
fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let n8 = acc.len() - acc.len() % 8;
    let (ah, at) = acc.split_at_mut(n8);
    let (xh, xt) = x.split_at(n8);
    for (o, v) in ah.chunks_exact_mut(8).zip(xh.chunks_exact(8)) {
        o[0] += a * v[0];
        o[1] += a * v[1];
        o[2] += a * v[2];
        o[3] += a * v[3];
        o[4] += a * v[4];
        o[5] += a * v[5];
        o[6] += a * v[6];
        o[7] += a * v[7];
    }
    for (o, &v) in at.iter_mut().zip(xt) {
        *o += a * v;
    }
}

/// Reference forward GEMM `out[r, j] = Σ_k x[r, k] · w[k, j]` — fixed
/// i-k-j loop order, f32 accumulation, zero-skip on `x` (the historical
/// sim-backend `matmul`, verbatim; the artifact execute path still runs
/// this).
pub fn fwd_gemm_ref(x: &[f32], w: &[f32], b: usize, i_dim: usize, j_dim: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * i_dim);
    assert_eq!(w.len(), i_dim * j_dim);
    let mut out = vec![0.0f32; b * j_dim];
    for bi in 0..b {
        let row = &x[bi * i_dim..(bi + 1) * i_dim];
        let orow = &mut out[bi * j_dim..(bi + 1) * j_dim];
        for (i, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * j_dim..(i + 1) * j_dim];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Blocked forward GEMM into a caller-owned buffer. Column blocks of
/// [`COL_BLOCK`] keep the output tile hot while the weight rows stream;
/// each `out[r, j]` folds the same addends in the same ascending-`k`
/// order (with the same `x == 0` skip) as [`fwd_gemm_ref`], so the result
/// is **bit-identical**.
pub fn fwd_gemm(x: &[f32], w: &[f32], b: usize, i_dim: usize, j_dim: usize, out: &mut [f32]) {
    assert_eq!(x.len(), b * i_dim);
    assert_eq!(w.len(), i_dim * j_dim);
    assert_eq!(out.len(), b * j_dim);
    let mut jb = 0;
    while jb < j_dim {
        let jw = COL_BLOCK.min(j_dim - jb);
        for bi in 0..b {
            let orow = &mut out[bi * j_dim + jb..bi * j_dim + jb + jw];
            orow.fill(0.0);
            let xrow = &x[bi * i_dim..(bi + 1) * i_dim];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                axpy(orow, xv, &w[i * j_dim + jb..i * j_dim + jb + jw]);
            }
        }
        jb += COL_BLOCK;
    }
}

/// Reference weight-gradient kernel `out[i, j] = Σ_r a[r, i] · d[r, j]`
/// (`Aᵀ·D` folded over the batch) — the historical `gW_s` / `gW_c` loops:
/// ascending batch index, zero-skip on `a`.
pub fn grad_outer_ref(
    a: &[f32],
    d: &[f32],
    rows: usize,
    i_dim: usize,
    j_dim: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), rows * i_dim);
    assert_eq!(d.len(), rows * j_dim);
    let mut out = vec![0.0f32; i_dim * j_dim];
    for r in 0..rows {
        let arow = &a[r * i_dim..(r + 1) * i_dim];
        let drow = &d[r * j_dim..(r + 1) * j_dim];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let grow = &mut out[i * j_dim..(i + 1) * j_dim];
            for (g, &dv) in grow.iter_mut().zip(drow) {
                *g += av * dv;
            }
        }
    }
    out
}

/// Blocked weight-gradient kernel into a caller-owned buffer. Column
/// blocks keep a `i_dim × COL_BLOCK` output tile L2-hot across the batch
/// fold; each element still folds batch rows in ascending order with the
/// reference zero-skip — bit-identical to [`grad_outer_ref`].
pub fn grad_outer(
    a: &[f32],
    d: &[f32],
    rows: usize,
    i_dim: usize,
    j_dim: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), rows * i_dim);
    assert_eq!(d.len(), rows * j_dim);
    assert_eq!(out.len(), i_dim * j_dim);
    out.fill(0.0);
    let mut jb = 0;
    while jb < j_dim {
        let jw = COL_BLOCK.min(j_dim - jb);
        for r in 0..rows {
            let arow = &a[r * i_dim..(r + 1) * i_dim];
            let dseg = &d[r * j_dim + jb..r * j_dim + jb + jw];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                axpy(&mut out[i * j_dim + jb..i * j_dim + jb + jw], av, dseg);
            }
        }
        jb += COL_BLOCK;
    }
}

/// Reference activation-gradient kernel
/// `out[r, j] = Σ_k d[r, k] · w_s[j, k]` — the historical per-element dot
/// products over `W_s` rows (no zero-skip).
pub fn gact_ref(d: &[f32], w_s: &[f32], b: usize, feat: usize, classes: usize) -> Vec<f32> {
    assert_eq!(d.len(), b * classes);
    assert_eq!(w_s.len(), feat * classes);
    let mut out = vec![0.0f32; b * feat];
    for bi in 0..b {
        let drow = &d[bi * classes..(bi + 1) * classes];
        let grow = &mut out[bi * feat..(bi + 1) * feat];
        for (j, g) in grow.iter_mut().enumerate() {
            let wrow = &w_s[j * classes..(j + 1) * classes];
            let mut acc = 0.0f32;
            for (&dv, &wv) in drow.iter().zip(wrow) {
                acc += dv * wv;
            }
            *g = acc;
        }
    }
    out
}

/// Fast activation-gradient kernel over the **pre-transposed** server
/// weights (`w_s_t` is `classes × feat`, maintained by
/// [`sgd_momentum_tracked`]): an i-k-j sweep whose inner loop walks a
/// contiguous `W_sᵀ` row instead of striding `W_s` columns. Each
/// `out[r, j]` folds `d[r, k] · W_sᵀ[k, j]` in ascending-`k` order from a
/// `+0.0` start — the identical addend sequence of [`gact_ref`]'s scalar
/// accumulator (which also starts at `+0.0` and has no zero-skip), so the
/// result is bit-identical.
pub fn gact_fast(
    d: &[f32],
    w_s_t: &[f32],
    b: usize,
    feat: usize,
    classes: usize,
    out: &mut [f32],
) {
    assert_eq!(d.len(), b * classes);
    assert_eq!(w_s_t.len(), classes * feat);
    assert_eq!(out.len(), b * feat);
    let mut jb = 0;
    while jb < feat {
        let jw = COL_BLOCK.min(feat - jb);
        for bi in 0..b {
            let orow = &mut out[bi * feat + jb..bi * feat + jb + jw];
            orow.fill(0.0);
            let drow = &d[bi * classes..(bi + 1) * classes];
            for (k, &dv) in drow.iter().enumerate() {
                axpy(orow, dv, &w_s_t[k * feat + jb..k * feat + jb + jw]);
            }
        }
        jb += COL_BLOCK;
    }
}

/// Reference momentum-SGD update `m' = µ·m + g`, `w' = w − lr·m'`,
/// returning fresh vectors (the historical sim-backend helper).
pub fn sgd_momentum_ref(w: &[f32], m: &[f32], g: &[f32], lr: f32) -> (Vec<f32>, Vec<f32>) {
    let mut new_m = Vec::with_capacity(m.len());
    let mut new_w = Vec::with_capacity(w.len());
    for ((&wv, &mv), &gv) in w.iter().zip(m).zip(g) {
        let nm = SIM_MOMENTUM * mv + gv;
        new_m.push(nm);
        new_w.push(wv - lr * nm);
    }
    (new_w, new_m)
}

/// In-place momentum-SGD update — the same per-element operations as
/// [`sgd_momentum_ref`] without the two output allocations.
pub fn sgd_momentum(w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) {
    assert_eq!(w.len(), m.len());
    assert_eq!(w.len(), g.len());
    for ((wv, mv), &gv) in w.iter_mut().zip(m.iter_mut()).zip(g) {
        let nm = SIM_MOMENTUM * *mv + gv;
        *mv = nm;
        *wv -= lr * nm;
    }
}

/// In-place momentum-SGD update that also refreshes the maintained
/// transpose `wt[c, r] = w[r, c]` in the same pass, keeping the `gact`
/// fast kernel's operand exact at zero extra numeric cost (the transpose
/// entry is a copy of the freshly computed weight, not a recomputation).
pub fn sgd_momentum_tracked(
    w: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    lr: f32,
    wt: &mut [f32],
    rows: usize,
    cols: usize,
) {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(wt.len(), rows * cols);
    assert_eq!(w.len(), m.len());
    assert_eq!(w.len(), g.len());
    for r in 0..rows {
        for c in 0..cols {
            let idx = r * cols + c;
            let nm = SIM_MOMENTUM * m[idx] + g[idx];
            m[idx] = nm;
            let nw = w[idx] - lr * nm;
            w[idx] = nw;
            wt[c * rows + r] = nw;
        }
    }
}

/// Reference softmax cross-entropy forward: `(mean loss, correct count,
/// per-element `(p − onehot)/B` logit gradients)` — the historical
/// two-exp-pass sim-backend kernel, verbatim.
pub fn softmax_xent_ref(
    logits: &[f32],
    labels: &[i32],
    b: usize,
    classes: usize,
) -> (f64, u64, Vec<f32>) {
    let mut loss = 0.0f64;
    let mut correct = 0u64;
    let mut dlogits = vec![0.0f32; b * classes];
    for bi in 0..b {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let y = labels[bi] as usize;
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = k;
            }
        }
        if argmax == y {
            correct += 1;
        }
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let log_denom = denom.ln();
        loss += (log_denom - (row[y] - max)) as f64;
        let drow = &mut dlogits[bi * classes..(bi + 1) * classes];
        for (k, &v) in row.iter().enumerate() {
            let p = (v - max).exp() / denom;
            drow[k] = (p - if k == y { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    (loss / b as f64, correct, dlogits)
}

/// Fused single-exp-pass softmax cross-entropy into caller-owned buffers:
/// the denominator pass **stores** each `exp(v − max)` in `exp_row`
/// instead of recomputing it for the gradient pass. The stored value is
/// the identical f32 the reference recomputes (`p = exp_row[k] / denom`
/// divides the same operands), so loss, correct count, and `dlogits` are
/// bit-identical to [`softmax_xent_ref`].
pub fn softmax_xent_fused(
    logits: &[f32],
    labels: &[i32],
    b: usize,
    classes: usize,
    exp_row: &mut [f32],
    dlogits: &mut [f32],
) -> (f64, u64) {
    assert_eq!(logits.len(), b * classes);
    assert_eq!(labels.len(), b);
    assert_eq!(exp_row.len(), b * classes);
    assert_eq!(dlogits.len(), b * classes);
    let mut loss = 0.0f64;
    let mut correct = 0u64;
    for bi in 0..b {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let erow = &mut exp_row[bi * classes..(bi + 1) * classes];
        let y = labels[bi] as usize;
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > max {
                max = v;
                argmax = k;
            }
        }
        if argmax == y {
            correct += 1;
        }
        let mut denom = 0.0f32;
        for (e, &v) in erow.iter_mut().zip(row) {
            let ev = (v - max).exp();
            *e = ev;
            denom += ev;
        }
        let log_denom = denom.ln();
        loss += (log_denom - (row[y] - max)) as f64;
        let drow = &mut dlogits[bi * classes..(bi + 1) * classes];
        for (k, (dv, &ev)) in drow.iter_mut().zip(erow.iter()).enumerate() {
            let p = ev / denom;
            *dv = (p - if k == y { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    (loss / b as f64, correct)
}

// ---------------------------------------------------------------------------
// Device-resident model state
// ---------------------------------------------------------------------------

/// Immutable per-preset compute plan: the resolved model dimensions every
/// slot of a [`ResidentSession`] shares, fixed at session build time. The
/// layout decisions the plan encodes — maintained `W_sᵀ` for the `gact`
/// kernel, per-slot activation stash, [`COL_BLOCK`]-wide GEMM tiles — are
/// applied by the session methods below.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    /// Flattened image size (`C·H·W`).
    pub in_dim: usize,
    /// Per-sample cut-layer activation size (`C·M·N`).
    pub act_feat: usize,
    /// Output classes.
    pub classes: usize,
    /// Cut-layer activation shape `[batch, C, M, N]` from the manifest.
    pub act_shape: [usize; 4],
}

/// One device's resident client-side state + step scratch.
struct ClientSlot {
    /// Client weights `[in_dim, act_feat]`, updated in place.
    w_c: Vec<f32>,
    /// Client momenta.
    m_c: Vec<f32>,
    /// Stashed `tanh(z)` of the last forward (`[b, act_feat]`) — reused by
    /// `client_step` so the backward never re-runs the forward GEMM.
    act: Vec<f32>,
    /// `dz = gact · (1 − act²)` work buffer.
    dz: Vec<f32>,
    /// `gW_c` work buffer (`[in_dim, act_feat]`).
    g_wc: Vec<f32>,
    /// Per-device DCT transformer (plan shared, scratch private).
    dct: Dct2d,
}

/// The server's resident state + step scratch.
struct ServerSlot {
    /// Server weights `[act_feat, classes]`, updated in place.
    w_s: Vec<f32>,
    /// Server momenta.
    m_s: Vec<f32>,
    /// Maintained transpose `[classes, act_feat]` — refreshed by
    /// [`sgd_momentum_tracked`] in the same pass as the update.
    w_s_t: Vec<f32>,
    logits: Vec<f32>,
    exp: Vec<f32>,
    dlogits: Vec<f32>,
    g_ws: Vec<f32>,
    gact: Vec<f32>,
    dct: Dct2d,
}

/// FedAvg aggregate of the client side + the f64 fold buffer.
struct AggSlot {
    w: Vec<f32>,
    m: Vec<f32>,
    /// f64 accumulator (`in_dim · act_feat`) shared by both fold passes.
    acc: Vec<f64>,
}

/// Evaluation staging: batch gather buffers + forward scratch.
struct EvalSlot {
    x: Vec<f32>,
    y: Vec<i32>,
    z: Vec<f32>,
    logits: Vec<f32>,
    exp: Vec<f32>,
    dlogits: Vec<f32>,
}

/// Pre-built statistics keys (`preset/artifact`), so steady-state stat
/// recording never formats a string.
struct StatKeys {
    client_fwd: String,
    idct: String,
    server_step: String,
    client_step: String,
    eval_step: String,
}

/// A device-resident compute session over the sim backend: the fast
/// counterpart of the artifact `execute` path (see module docs). Built by
/// [`crate::runtime::ExecutorHandle::open_resident`]; `Send + Sync`, so
/// the round engine's workers drive their devices' slots concurrently.
pub struct ResidentSession {
    sim: Arc<SimState>,
    preset: SimPreset,
    plan: ModelPlan,
    keys: StatKeys,
    server: Mutex<ServerSlot>,
    agg: Mutex<AggSlot>,
    eval: Mutex<EvalSlot>,
    devices: Vec<Mutex<ClientSlot>>,
}

fn ensure_len_f32(v: &mut Vec<f32>, n: usize) {
    if v.len() != n {
        v.resize(n, 0.0);
    }
}

impl ResidentSession {
    /// Build a session: resolve the preset, run the deterministic init
    /// (the same RNG streams as the `init` artifact, so resident and
    /// artifact paths start from bit-identical parameters), and size the
    /// per-device slots.
    pub(crate) fn new(sim: Arc<SimState>, preset_name: &str, devices: usize) -> Result<Self> {
        ensure!(devices > 0, "resident session needs at least one device");
        let preset = sim.backend.preset(preset_name)?.clone();
        let plan = ModelPlan {
            in_dim: preset.in_dim,
            act_feat: preset.act_feat,
            classes: preset.classes,
            act_shape: preset.act_shape,
        };
        let (m, n) = (plan.act_shape[2], plan.act_shape[3]);
        let (w_c, w_s) = preset.init_weights();
        let client_slots = (0..devices)
            .map(|_| {
                Mutex::new(ClientSlot {
                    w_c: w_c.clone(),
                    m_c: vec![0.0; w_c.len()],
                    act: Vec::new(),
                    dz: Vec::new(),
                    g_wc: vec![0.0; w_c.len()],
                    dct: Dct2d::new(m, n),
                })
            })
            .collect();
        let mut w_s_t = vec![0.0f32; w_s.len()];
        for r in 0..plan.act_feat {
            for c in 0..plan.classes {
                w_s_t[c * plan.act_feat + r] = w_s[r * plan.classes + c];
            }
        }
        let server = ServerSlot {
            m_s: vec![0.0; w_s.len()],
            w_s_t,
            logits: Vec::new(),
            exp: Vec::new(),
            dlogits: Vec::new(),
            g_ws: vec![0.0; w_s.len()],
            gact: Vec::new(),
            dct: Dct2d::new(m, n),
            w_s,
        };
        let agg = AggSlot {
            m: vec![0.0; w_c.len()],
            acc: vec![0.0; w_c.len()],
            w: w_c,
        };
        let eval = EvalSlot {
            x: Vec::new(),
            y: Vec::new(),
            z: Vec::new(),
            logits: Vec::new(),
            exp: Vec::new(),
            dlogits: Vec::new(),
        };
        let key = |name: &str| format!("{preset_name}/{name}");
        Ok(ResidentSession {
            sim,
            preset,
            plan,
            keys: StatKeys {
                client_fwd: key("client_fwd"),
                idct: key("idct"),
                server_step: key("server_step"),
                client_step: key("client_step"),
                eval_step: key("eval_step"),
            },
            server: Mutex::new(server),
            agg: Mutex::new(agg),
            eval: Mutex::new(eval),
            devices: client_slots,
        })
    }

    /// The session's compute plan (dims/layout).
    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    /// Device slot count.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    fn record(&self, key: &str, elapsed: std::time::Duration) {
        self.sim.stats.lock().unwrap().record_ref(key, elapsed);
    }

    fn slot(&self, dev: usize) -> Result<&Mutex<ClientSlot>> {
        self.devices
            .get(dev)
            .with_context(|| format!("resident session has no device slot {dev}"))
    }

    /// Client forward: `act = tanh(x_flat · W_c)` stashed in the device
    /// slot, with the wire-domain tensor (DCT coefficients when `freq`,
    /// the spatial activations otherwise) written into `wire` in place.
    pub fn client_fwd(&self, dev: usize, x: &[f32], freq: bool, wire: &mut Tensor) -> Result<()> {
        let t0 = Instant::now();
        let p = &self.plan;
        ensure!(
            !x.is_empty() && x.len() % p.in_dim == 0,
            "client_fwd: batch numel {} is not a multiple of in_dim {}",
            x.len(),
            p.in_dim
        );
        let b = x.len() / p.in_dim;
        let shape = [b, p.act_shape[1], p.act_shape[2], p.act_shape[3]];
        let mut s = self.slot(dev)?.lock().unwrap();
        let s = &mut *s;
        ensure_len_f32(&mut s.act, b * p.act_feat);
        fwd_gemm(x, &s.w_c, b, p.in_dim, p.act_feat, &mut s.act);
        for v in &mut s.act {
            *v = v.tanh();
        }
        wire.reset_dense(&shape);
        if freq {
            let ch = p.act_shape[2] * p.act_shape[3];
            let out = wire.data_mut();
            for c in 0..b * p.act_shape[1] {
                s.dct.forward(&s.act[c * ch..(c + 1) * ch], &mut out[c * ch..(c + 1) * ch]);
            }
        } else {
            wire.data_mut().copy_from_slice(&s.act);
        }
        self.record(&self.keys.client_fwd, t0.elapsed());
        Ok(())
    }

    /// Per-channel inverse DCT of an activation-shaped coefficient tensor
    /// into `out` (reset in place), using the device slot's transformer —
    /// the resident twin of the `idct` artifact.
    pub fn idct(&self, dev: usize, coeffs: &Tensor, out: &mut Tensor) -> Result<()> {
        let t0 = Instant::now();
        let p = &self.plan;
        let (b, c, m, n) = coeffs.as_bchw();
        ensure!(
            m == p.act_shape[2] && n == p.act_shape[3],
            "idct: plane {m}x{n} does not match the activation plane {}x{}",
            p.act_shape[2],
            p.act_shape[3]
        );
        out.reset_dense(coeffs.shape());
        let mut s = self.slot(dev)?.lock().unwrap();
        let ch = m * n;
        let dst = out.data_mut();
        let src = coeffs.data();
        for ci in 0..b * c {
            s.dct.inverse(&src[ci * ch..(ci + 1) * ch], &mut dst[ci * ch..(ci + 1) * ch]);
        }
        self.record(&self.keys.idct, t0.elapsed());
        Ok(())
    }

    /// Server training step on the resident server slot: logits → fused
    /// softmax/xent → `gW_s` → `gact` (via the maintained `W_sᵀ`) → in-place
    /// SGD (+ transpose refresh). The downlink gradient lands in `grad_out`
    /// — DCT coefficients when `freq_grad`, spatial otherwise. Returns
    /// `(batch loss as f32, correct)`; the f32 cast matches the artifact
    /// path's scalar output exactly.
    pub fn server_step(
        &self,
        act: &Tensor,
        labels: &[i32],
        lr: f32,
        freq_grad: bool,
        grad_out: &mut Tensor,
    ) -> Result<(f32, u64)> {
        let t0 = Instant::now();
        let p = &self.plan;
        let dims = act.shape();
        ensure!(!dims.is_empty(), "server_step: rank-0 activations");
        let b = dims[0];
        ensure!(
            act.numel() == b * p.act_feat,
            "server_step: act numel {} != {} × act_feat {}",
            act.numel(),
            b,
            p.act_feat
        );
        ensure!(labels.len() == b, "server_step: labels/batch mismatch");
        let mut s = self.server.lock().unwrap();
        let s = &mut *s;
        ensure_len_f32(&mut s.logits, b * p.classes);
        ensure_len_f32(&mut s.exp, b * p.classes);
        ensure_len_f32(&mut s.dlogits, b * p.classes);
        ensure_len_f32(&mut s.gact, b * p.act_feat);
        let a = act.data();
        fwd_gemm(a, &s.w_s, b, p.act_feat, p.classes, &mut s.logits);
        let (loss, correct) =
            softmax_xent_fused(&s.logits, labels, b, p.classes, &mut s.exp, &mut s.dlogits);
        grad_outer(a, &s.dlogits, b, p.act_feat, p.classes, &mut s.g_ws);
        gact_fast(&s.dlogits, &s.w_s_t, b, p.act_feat, p.classes, &mut s.gact);
        sgd_momentum_tracked(
            &mut s.w_s,
            &mut s.m_s,
            &s.g_ws,
            lr,
            &mut s.w_s_t,
            p.act_feat,
            p.classes,
        );
        let shape = [b, p.act_shape[1], p.act_shape[2], p.act_shape[3]];
        grad_out.reset_dense(&shape);
        if freq_grad {
            let ch = p.act_shape[2] * p.act_shape[3];
            let out = grad_out.data_mut();
            for c in 0..b * p.act_shape[1] {
                s.dct.forward(&s.gact[c * ch..(c + 1) * ch], &mut out[c * ch..(c + 1) * ch]);
            }
        } else {
            grad_out.data_mut().copy_from_slice(&s.gact);
        }
        self.record(&self.keys.server_step, t0.elapsed());
        Ok((loss as f32, correct))
    }

    /// Client backward on the resident device slot: `dz` from the stashed
    /// forward activations (no forward recompute — the stash holds the
    /// bit-same `tanh(z)` the reference would recompute), `gW_c`, in-place
    /// SGD.
    pub fn client_step(&self, dev: usize, x: &[f32], gact: &Tensor, lr: f32) -> Result<()> {
        let t0 = Instant::now();
        let p = &self.plan;
        ensure!(
            !x.is_empty() && x.len() % p.in_dim == 0,
            "client_step: batch numel {} is not a multiple of in_dim {}",
            x.len(),
            p.in_dim
        );
        let b = x.len() / p.in_dim;
        ensure!(
            gact.numel() == b * p.act_feat,
            "client_step: gact numel {} != {} × act_feat {}",
            gact.numel(),
            b,
            p.act_feat
        );
        let mut s = self.slot(dev)?.lock().unwrap();
        let s = &mut *s;
        ensure!(
            s.act.len() == b * p.act_feat,
            "client_step without a matching stashed forward (stash {} vs {})",
            s.act.len(),
            b * p.act_feat
        );
        ensure_len_f32(&mut s.dz, b * p.act_feat);
        for ((dzv, &av), &gv) in s.dz.iter_mut().zip(&s.act).zip(gact.data()) {
            *dzv = gv * (1.0 - av * av);
        }
        grad_outer(x, &s.dz, b, p.in_dim, p.act_feat, &mut s.g_wc);
        sgd_momentum(&mut s.w_c, &mut s.m_c, &s.g_wc, lr);
        self.record(&self.keys.client_step, t0.elapsed());
        Ok(())
    }

    /// Evaluate one test batch (`[start, start + b)`) against the
    /// aggregate client weights + resident server weights, gathering into
    /// the eval slot's reusable buffers. Returns `(batch mean loss, correct)`
    /// with the same f64→f32→f64 loss cast chain as the artifact path.
    pub fn eval_batch(&self, test: &Dataset, start: usize, b: usize) -> Result<(f64, u64)> {
        let t0 = Instant::now();
        let p = &self.plan;
        ensure!(start + b <= test.len(), "eval batch out of range");
        let mut e = self.eval.lock().unwrap();
        let e = &mut *e;
        e.x.clear();
        e.y.clear();
        for j in start..start + b {
            e.x.extend_from_slice(test.image(j));
            e.y.push(test.labels[j] as i32);
        }
        ensure!(
            e.x.len() == b * p.in_dim,
            "eval batch sample size {} != in_dim {}",
            e.x.len() / b.max(1),
            p.in_dim
        );
        ensure_len_f32(&mut e.z, b * p.act_feat);
        ensure_len_f32(&mut e.logits, b * p.classes);
        ensure_len_f32(&mut e.exp, b * p.classes);
        ensure_len_f32(&mut e.dlogits, b * p.classes);
        {
            let agg = self.agg.lock().unwrap();
            fwd_gemm(&e.x, &agg.w, b, p.in_dim, p.act_feat, &mut e.z);
        }
        for v in &mut e.z {
            *v = v.tanh();
        }
        {
            let srv = self.server.lock().unwrap();
            fwd_gemm(&e.z, &srv.w_s, b, p.act_feat, p.classes, &mut e.logits);
        }
        let (loss, correct) =
            softmax_xent_fused(&e.logits, &e.y, b, p.classes, &mut e.exp, &mut e.dlogits);
        self.record(&self.keys.eval_step, t0.elapsed());
        Ok((((loss as f32) as f64), correct))
    }

    /// Copy the aggregate client weights/momenta into a device slot
    /// (SplitFed round start; the in-place twin of `cp = aggregate.clone()`).
    pub fn load_client_from_agg(&self, dev: usize) -> Result<()> {
        let agg = self.agg.lock().unwrap();
        let mut s = self.slot(dev)?.lock().unwrap();
        s.w_c.copy_from_slice(&agg.w);
        s.m_c.copy_from_slice(&agg.m);
        Ok(())
    }

    /// Copy one device slot's client weights/momenta into another
    /// (sequential SL's device→device hand-off).
    pub fn copy_client(&self, from: usize, to: usize) -> Result<()> {
        ensure!(from != to, "copy_client: from == to ({from})");
        let a = self.slot(from.min(to))?;
        let b = self.slot(from.max(to))?;
        // ascending-index lock order — deadlock-free even if a future
        // caller overlaps hand-offs
        let first = a.lock().unwrap();
        let second = b.lock().unwrap();
        let (src, mut dst) = if from < to { (first, second) } else { (second, first) };
        dst.w_c.copy_from_slice(&src.w_c);
        dst.m_c.copy_from_slice(&src.m_c);
        Ok(())
    }

    /// Store a device slot's client weights/momenta as the new aggregate
    /// (sequential SL round end).
    pub fn store_client_to_agg(&self, dev: usize) -> Result<()> {
        let s = self.slot(dev)?.lock().unwrap();
        let mut agg = self.agg.lock().unwrap();
        agg.w.copy_from_slice(&s.w_c);
        agg.m.copy_from_slice(&s.m_c);
        Ok(())
    }

    /// Shard-weighted FedAvg over the device slots into the aggregate
    /// slot, in place. The fold is the exact
    /// [`crate::coordinator::fedavg_sharded`] arithmetic — per element, an
    /// f64 accumulator folds `frac · v` over devices in ascending id
    /// order (zero-weight devices included, exactly like the reference) —
    /// so the aggregate is bit-identical to the artifact path's.
    pub fn fedavg(&self, weights: &[f64]) -> Result<()> {
        ensure!(
            weights.len() == self.devices.len(),
            "fedavg weights/devices mismatch: {} vs {}",
            weights.len(),
            self.devices.len()
        );
        let total: f64 = weights.iter().sum();
        ensure!(total > 0.0, "fedavg with zero total weight");
        let mut agg = self.agg.lock().unwrap();
        let agg = &mut *agg;
        for pass in 0..2 {
            agg.acc.fill(0.0);
            for (dev, &wt) in self.devices.iter().zip(weights) {
                let frac = wt / total;
                let s = dev.lock().unwrap();
                let src = if pass == 0 { &s.w_c } else { &s.m_c };
                for (a, &v) in agg.acc.iter_mut().zip(src.iter()) {
                    *a += frac * v as f64;
                }
            }
            let dst = if pass == 0 { &mut agg.w } else { &mut agg.m };
            for (d, &a) in dst.iter_mut().zip(agg.acc.iter()) {
                *d = a as f32;
            }
        }
        Ok(())
    }

    /// Allocating snapshot of the aggregate client parameters (reporting /
    /// differential tests; not a hot path).
    pub fn client_params(&self) -> Vec<HostTensor> {
        let agg = self.agg.lock().unwrap();
        vec![HostTensor::f32(
            &[self.plan.in_dim, self.plan.act_feat],
            agg.w.clone(),
        )]
    }

    /// Allocating snapshot of the resident server parameters.
    pub fn server_params(&self) -> Vec<HostTensor> {
        let s = self.server.lock().unwrap();
        vec![HostTensor::f32(
            &[self.plan.act_feat, self.plan.classes],
            s.w_s.clone(),
        )]
    }

    /// The preset this session serves (diagnostics).
    pub fn preset_name(&self) -> &str {
        &self.preset.name
    }

    /// Full aggregate client state `(weights, momenta)` — the
    /// checkpoint export path. Taken at a round boundary the aggregate is
    /// the only client state that matters: every device slot is reloaded
    /// from it at the next round start.
    pub fn export_client_agg(&self) -> (Vec<f32>, Vec<f32>) {
        let agg = self.agg.lock().unwrap();
        (agg.w.clone(), agg.m.clone())
    }

    /// Full server state `(weights, momenta)` — the checkpoint export
    /// path (`w_s_t` is derived, rebuilt on import).
    pub fn export_server(&self) -> (Vec<f32>, Vec<f32>) {
        let s = self.server.lock().unwrap();
        (s.w_s.clone(), s.m_s.clone())
    }

    /// Restore the aggregate client state from a checkpoint without
    /// leaving device-resident mode. Length-checked against the model
    /// plan — fails closed on mismatched checkpoints.
    pub fn import_client_agg(&self, w: &[f32], m: &[f32]) -> Result<()> {
        let mut agg = self.agg.lock().unwrap();
        ensure!(
            w.len() == agg.w.len() && m.len() == agg.m.len(),
            "client checkpoint shape mismatch: got {}/{} values, slot holds {}/{}",
            w.len(),
            m.len(),
            agg.w.len(),
            agg.m.len()
        );
        agg.w.copy_from_slice(w);
        agg.m.copy_from_slice(m);
        Ok(())
    }

    /// Restore the server state from a checkpoint, rebuilding the
    /// maintained `W_sᵀ` so the fast activation-gradient kernel sees the
    /// restored weights.
    pub fn import_server(&self, w: &[f32], m: &[f32]) -> Result<()> {
        let mut s = self.server.lock().unwrap();
        ensure!(
            w.len() == s.w_s.len() && m.len() == s.m_s.len(),
            "server checkpoint shape mismatch: got {}/{} values, slot holds {}/{}",
            w.len(),
            m.len(),
            s.w_s.len(),
            s.m_s.len()
        );
        s.w_s.copy_from_slice(w);
        s.m_s.copy_from_slice(m);
        let plan = &self.plan;
        for r in 0..plan.act_feat {
            for c in 0..plan.classes {
                s.w_s_t[c * plan.act_feat + r] = s.w_s[r * plan.classes + c];
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn randn(n: usize, seed: u64, zero_every: usize) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|i| {
                if zero_every != 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn fwd_gemm_matches_reference_bitwise() {
        for &(b, i, j) in &[(1usize, 3usize, 5usize), (4, 17, 64), (8, 64, 65), (3, 100, 130)] {
            let x = randn(b * i, 1, 7); // zeros exercise the skip path
            let w = randn(i * j, 2, 0);
            let want = fwd_gemm_ref(&x, &w, b, i, j);
            let mut got = vec![1.0f32; b * j]; // dirty buffer: must be fully overwritten
            fwd_gemm(&x, &w, b, i, j, &mut got);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "{b}x{i}x{j}");
        }
    }

    #[test]
    fn grad_outer_matches_reference_bitwise() {
        for &(r, i, j) in &[(2usize, 5usize, 3usize), (8, 30, 64), (4, 64, 100), (6, 7, 129)] {
            let a = randn(r * i, 3, 5);
            let d = randn(r * j, 4, 0);
            let want = grad_outer_ref(&a, &d, r, i, j);
            let mut got = vec![-2.0f32; i * j];
            grad_outer(&a, &d, r, i, j, &mut got);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "{r}x{i}x{j}");
        }
    }

    #[test]
    fn gact_fast_matches_reference_bitwise() {
        for &(b, feat, classes) in &[(2usize, 9usize, 4usize), (8, 64, 10), (4, 130, 7)] {
            let d = randn(b * classes, 5, 0);
            let w_s = randn(feat * classes, 6, 0);
            let mut w_s_t = vec![0.0f32; feat * classes];
            for r in 0..feat {
                for c in 0..classes {
                    w_s_t[c * feat + r] = w_s[r * classes + c];
                }
            }
            let want = gact_ref(&d, &w_s, b, feat, classes);
            let mut got = vec![9.0f32; b * feat];
            gact_fast(&d, &w_s_t, b, feat, classes, &mut got);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "{b}x{feat}x{classes}");
        }
    }

    #[test]
    fn sgd_variants_match_reference_bitwise() {
        let (rows, cols) = (13, 5);
        let n = rows * cols;
        let w0 = randn(n, 7, 0);
        let m0 = randn(n, 8, 0);
        let g = randn(n, 9, 0);
        let (want_w, want_m) = sgd_momentum_ref(&w0, &m0, &g, 0.05);

        let (mut w1, mut m1) = (w0.clone(), m0.clone());
        sgd_momentum(&mut w1, &mut m1, &g, 0.05);
        assert_eq!(w1, want_w);
        assert_eq!(m1, want_m);

        let (mut w2, mut m2) = (w0, m0);
        let mut wt = vec![0.0f32; n];
        sgd_momentum_tracked(&mut w2, &mut m2, &g, 0.05, &mut wt, rows, cols);
        assert_eq!(w2, want_w);
        assert_eq!(m2, want_m);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(wt[c * rows + r].to_bits(), w2[r * cols + c].to_bits());
            }
        }
    }

    #[test]
    fn fused_softmax_matches_reference_bitwise() {
        let (b, classes) = (6, 10);
        let logits = randn(b * classes, 11, 0);
        let labels: Vec<i32> = (0..b).map(|i| (i % classes) as i32).collect();
        let (want_loss, want_correct, want_d) = softmax_xent_ref(&logits, &labels, b, classes);
        let mut exp = vec![0.0f32; b * classes];
        let mut d = vec![0.5f32; b * classes];
        let (loss, correct) = softmax_xent_fused(&logits, &labels, b, classes, &mut exp, &mut d);
        assert_eq!(loss.to_bits(), want_loss.to_bits());
        assert_eq!(correct, want_correct);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&d), bits(&want_d));
        // the stored exp row really is exp(v - max)
        for bi in 0..b {
            let row = &logits[bi * classes..(bi + 1) * classes];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            for (k, &v) in row.iter().enumerate() {
                assert_eq!(exp[bi * classes + k].to_bits(), (v - max).exp().to_bits());
            }
        }
    }
}
