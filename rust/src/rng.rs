//! Deterministic PRNG + sampling distributions.
//!
//! The offline environment has no `rand` crate, so the coordinator carries
//! its own generator: PCG32 (O'Neill 2014, `pcg32_oneseq`), plus the
//! distributions the experiments need — uniform, standard normal
//! (Box–Muller), gamma (Marsaglia–Tsang), Dirichlet (normalized gammas,
//! used for the paper's non-IID β=0.5 partition), categorical, and
//! Fisher–Yates shuffling.
//!
//! Everything is seeded; every experiment records its seed in the config so
//! runs reproduce bit-for-bit.

/// PCG32: 64-bit state, 32-bit output, period 2^64.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 finalizer — a strong 64-bit bit mixer used for seed derivation.
pub fn mix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive an independent sub-seed from a root seed, a purpose tag, and an
/// index. Every consumer of randomness in the coordinator (per-device batch
/// loaders, link jitter, codec sampling, …) gets its own stream via this
/// function, so results are a function of `(root seed, purpose, device)`
/// alone — never of thread scheduling or the number of parallel workers.
pub fn derive_seed(root: u64, tag: u64, index: u64) -> u64 {
    mix64(root ^ mix64(tag ^ mix64(index)))
}

/// Purpose tags for [`derive_seed`] (stable across releases — changing one
/// changes every derived stream).
pub mod stream {
    /// Per-device batch loader shuffling.
    pub const LOADER: u64 = 0x4C4F_4144;
    /// Per-device link jitter.
    pub const LINK: u64 = 0x4C49_4E4B;
    /// Per-device codec sampling (randomized codecs, e.g. TK-SL).
    pub const CODEC: u64 = 0x434F_4443;
    /// Per-round client sampling (which devices participate in a round);
    /// indexed by round number, not device id.
    pub const SAMPLE: u64 = 0x5341_4D50;
    /// Deterministic projection bases (NSC-SL subspace codec); indexed by
    /// the plane/rank geometry, not device id — every device shares the
    /// same basis for a given `(seed, shape, rank)`.
    pub const BASIS: u64 = 0x4241_5349;
    /// Fault injection (crash windows, message loss, payload corruption,
    /// retry jitter, server outages); indexed by the round number. Every
    /// individual draw folds `(device, step, attempt, kind)` into the
    /// derive index, so a fault decision is a pure function of the message
    /// identity — never of scheduler control flow or worker count. See
    /// [`crate::transport::fault`].
    pub const FAULT: u64 = 0x4641_554C;
}

impl Pcg32 {
    /// Seed with a state seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut r = Pcg32 { state: 0, inc };
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        r.state = r.state.wrapping_add(seed);
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        r
    }

    /// Convenience single-seed constructor (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Independent per-entity generator: state and stream id both derived
    /// from `(root, tag, index)` via [`derive_seed`]/[`mix64`].
    pub fn derived(root: u64, tag: u64, index: u64) -> Self {
        Self::new(derive_seed(root, tag, index), mix64(tag).wrapping_add(index))
    }

    /// The full generator state `(state, inc)` — everything a checkpoint
    /// needs to reconstruct this generator mid-stream.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg32::state_parts`]. The restored
    /// generator's draw sequence continues bit-identically from where the
    /// snapshotted one left off.
    pub fn from_state_parts(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    /// Next raw 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)` with 24 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform double in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n.wrapping_neg() % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (2000); boosts k<1.
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0, "gamma shape must be positive");
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            let u = loop {
                let u = self.uniform_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(α·1) of dimension `dim` — the paper's non-IID partitioner
    /// uses β=0.5 per class.
    pub fn dirichlet(&mut self, alpha: f64, dim: usize) -> Vec<f64> {
        assert!(dim > 0);
        let mut g: Vec<f64> = (0..dim).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut t = self.uniform_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// `n` distinct indices from `[0, pool)` (reservoir-free, pool shuffled).
    pub fn sample_indices(&mut self, pool: usize, n: usize) -> Vec<usize> {
        assert!(n <= pool);
        let mut idx: Vec<usize> = (0..pool).collect();
        self.shuffle(&mut idx);
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_streams_are_deterministic_and_distinct() {
        let mut a = Pcg32::derived(42, stream::LOADER, 3);
        let mut b = Pcg32::derived(42, stream::LOADER, 3);
        for _ in 0..50 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        // different index, tag, or root ⇒ decorrelated streams
        for (root, tag, idx) in [
            (42u64, stream::LOADER, 4u64),
            (42, stream::LINK, 3),
            (43, stream::LOADER, 3),
        ] {
            let mut a = Pcg32::derived(42, stream::LOADER, 3);
            let mut c = Pcg32::derived(root, tag, idx);
            let same = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
            assert!(same < 4, "stream ({root},{tag:#x},{idx}) correlates");
        }
    }

    #[test]
    fn state_parts_roundtrip_continues_the_stream() {
        let mut a = Pcg32::derived(42, stream::CODEC, 5);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        for _ in 0..50 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn derive_seed_avalanches() {
        // flipping one input bit flips ~half the output bits on average
        let base = derive_seed(0xDEAD_BEEF, 1, 2);
        let mut total = 0u32;
        for bit in 0..64 {
            total += (base ^ derive_seed(0xDEAD_BEEF ^ (1 << bit), 1, 2)).count_ones();
        }
        let avg = total as f64 / 64.0;
        assert!((avg - 32.0).abs() < 8.0, "avg flipped bits {avg}");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn differs_across_seeds() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg32::seeded(13);
        for &k in &[0.5f64, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!(
                (mean - k).abs() < 0.1 * k.max(1.0),
                "k={k} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::seeded(17);
        let p = r.dirichlet(0.5, 10);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(19);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
        // rough proportions
        let w = [1.0, 3.0];
        let mut c1 = 0;
        let n = 20_000;
        for _ in 0..n {
            if r.categorical(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(29);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
