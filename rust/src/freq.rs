//! Frequency-domain utilities: zig-zag scan and AFD (adaptive frequency
//! decomposition — paper §II-B, Eq. 3–4).
//!
//! The zig-zag order walks the `M×N` coefficient plane along anti-diagonals
//! (JPEG-style), so the scanned sequence goes from low to high spatial
//! frequency. AFD computes per-coefficient spectral energy `E = X²` (Eq. 3),
//! the cumulative energy ratio `R_(k)` (Eq. 4) over the scanned sequence,
//! and splits at the smallest `k*` with `R_(k*) ≥ θ`: prefix = low-frequency
//! set `F_l`, suffix = high-frequency set `F_h`.

use crate::codec::plan::SnapshotCache;
use std::sync::{Arc, OnceLock};

/// Precomputed zig-zag index table for an `M×N` plane.
///
/// `scan[i]` is the row-major index of the `i`-th element in zig-zag order;
/// `inverse[j]` is the position in the scan of row-major index `j`.
#[derive(Debug, Clone)]
pub struct ZigZag {
    /// Plane height.
    pub m: usize,
    /// Plane width.
    pub n: usize,
    /// zig-zag position → row-major index.
    pub scan: Vec<u32>,
    /// row-major index → zig-zag position.
    pub inverse: Vec<u32>,
}

impl ZigZag {
    /// Build the table for an `M×N` plane.
    ///
    /// Anti-diagonal `d = r + c` runs from 0 to `M+N-2`; even diagonals are
    /// walked bottom-left → top-right, odd ones top-right → bottom-left
    /// (JPEG convention, generalized to rectangles).
    pub fn build(m: usize, n: usize) -> Self {
        assert!(m > 0 && n > 0);
        let mut scan = Vec::with_capacity(m * n);
        for d in 0..(m + n - 1) {
            // cells on diagonal d: r in [max(0, d-n+1), min(d, m-1)]
            let r_lo = d.saturating_sub(n - 1);
            let r_hi = d.min(m - 1);
            if d % 2 == 0 {
                // up-right: start at highest row
                for r in (r_lo..=r_hi).rev() {
                    let c = d - r;
                    scan.push((r * n + c) as u32);
                }
            } else {
                // down-left: start at lowest row
                for r in r_lo..=r_hi {
                    let c = d - r;
                    scan.push((r * n + c) as u32);
                }
            }
        }
        let mut inverse = vec![0u32; m * n];
        for (pos, &rm) in scan.iter().enumerate() {
            inverse[rm as usize] = pos as u32;
        }
        ZigZag {
            m,
            n,
            scan,
            inverse,
        }
    }

    /// Scatter `plane` (row-major, `M*N`) into zig-zag order.
    pub fn apply(&self, plane: &[f32], out: &mut [f32]) {
        assert_eq!(plane.len(), self.m * self.n);
        assert_eq!(out.len(), plane.len());
        for (pos, &rm) in self.scan.iter().enumerate() {
            out[pos] = plane[rm as usize];
        }
    }

    /// Gather a zig-zag-ordered sequence back into the row-major plane.
    pub fn invert(&self, seq: &[f32], out: &mut [f32]) {
        assert_eq!(seq.len(), self.m * self.n);
        assert_eq!(out.len(), seq.len());
        for (pos, &rm) in self.scan.iter().enumerate() {
            out[rm as usize] = seq[pos];
        }
    }
}

fn zigzag_cache() -> &'static SnapshotCache<(usize, usize), ZigZag> {
    static CACHE: OnceLock<SnapshotCache<(usize, usize), ZigZag>> = OnceLock::new();
    CACHE.get_or_init(SnapshotCache::new)
}

/// Fetch (building on first use) the cached zig-zag table for `M×N`.
/// Lock-free on the hot (cached) path — see
/// [`crate::codec::plan::SnapshotCache`].
pub fn zigzag(m: usize, n: usize) -> Arc<ZigZag> {
    zigzag_cache().get_or_build((m, n), || ZigZag::build(m, n))
}

/// Result of AFD on one channel: zig-zag-ordered coefficients and split point.
#[derive(Debug, Clone)]
pub struct AfdSplit {
    /// Coefficients in zig-zag (low→high frequency) order.
    /// (With [`afd_channel_into`], this mirrors the caller's scratch buffer.)
    pub coeffs: Vec<f32>,
    /// Number of low-frequency coefficients `k*` (Algorithm 1 line 11);
    /// `coeffs[..k]` is `F_l`, `coeffs[k..]` is `F_h`.
    pub k: usize,
    /// Mean spectral energy of `F_l` (Eq. 5).
    pub mean_energy_low: f64,
    /// Mean spectral energy of `F_h` (Eq. 5); 0 when `F_h` is empty.
    pub mean_energy_high: f64,
}

/// Borrowed-output variant of [`AfdSplit`] for the allocation-free path.
#[derive(Debug, Clone, Copy)]
pub struct AfdSplitRef {
    /// Split index `k*`.
    pub k: usize,
    /// Mean spectral energy of `F_l` (Eq. 5).
    pub mean_energy_low: f64,
    /// Mean spectral energy of `F_h` (Eq. 5); 0 when `F_h` is empty.
    pub mean_energy_high: f64,
}

/// Run AFD (Eq. 3–4) on one channel plane already in the frequency domain.
///
/// `coeffs_plane` is the row-major `M×N` DCT coefficient plane. `theta` is
/// the energy threshold θ ∈ (0, 1]. Returns the zig-zag-ordered sequence,
/// the split index `k*`, and the per-group mean energies FQC needs.
///
/// Edge cases, matching Algorithm 1: if the channel is all-zero the split is
/// `k* = 1` (the DC term alone, with zero energy everywhere); θ ≥ 1 puts all
/// coefficients in `F_l`.
pub fn afd_channel(zz: &ZigZag, coeffs_plane: &[f32], theta: f64) -> AfdSplit {
    let mut coeffs = vec![0.0f32; coeffs_plane.len()];
    let r = afd_channel_into(zz, coeffs_plane, theta, &mut coeffs);
    AfdSplit {
        coeffs,
        k: r.k,
        mean_energy_low: r.mean_energy_low,
        mean_energy_high: r.mean_energy_high,
    }
}

/// Allocation-free variant of [`afd_channel`]: the zig-zag sequence is
/// written into the caller-provided `coeffs` buffer (resized to the plane)
/// — the codec hot loop reuses one scratch buffer per tensor (§Perf L3
/// iteration 1).
pub fn afd_channel_into(
    zz: &ZigZag,
    coeffs_plane: &[f32],
    theta: f64,
    coeffs: &mut Vec<f32>,
) -> AfdSplitRef {
    let len = coeffs_plane.len();
    assert_eq!(len, zz.m * zz.n);
    coeffs.resize(len, 0.0);
    zz.apply(coeffs_plane, coeffs);

    // Eq. 3 energies + total.
    let mut total = 0.0f64;
    for &c in coeffs.iter() {
        total += (c as f64) * (c as f64);
    }

    // Eq. 4: find smallest k with cumulative ratio >= theta.
    let k = if total <= 0.0 {
        1
    } else {
        let target = theta * total;
        let mut acc = 0.0f64;
        let mut k = len; // theta > 1 ⇒ everything low-frequency
        for (i, &c) in coeffs.iter().enumerate() {
            acc += (c as f64) * (c as f64);
            if acc >= target {
                k = i + 1;
                break;
            }
        }
        k
    };

    // Eq. 5: group mean energies.
    let e_low: f64 = coeffs[..k].iter().map(|&c| (c as f64).powi(2)).sum();
    let n_high = len - k;
    let e_high: f64 = coeffs[k..].iter().map(|&c| (c as f64).powi(2)).sum();
    AfdSplitRef {
        k,
        mean_energy_low: e_low / k as f64,
        mean_energy_high: if n_high == 0 {
            0.0
        } else {
            e_high / n_high as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_8x8_matches_jpeg_prefix() {
        // First entries of the canonical JPEG 8x8 zig-zag order.
        let zz = ZigZag::build(8, 8);
        let expect = [0u32, 1, 8, 16, 9, 2, 3, 10, 17, 24, 32, 25, 18, 11, 4];
        assert_eq!(&zz.scan[..expect.len()], &expect);
        assert_eq!(zz.scan.len(), 64);
    }

    #[test]
    fn zigzag_is_permutation_for_rectangles() {
        for &(m, n) in &[(1usize, 1usize), (1, 7), (7, 1), (3, 5), (14, 14), (16, 9)] {
            let zz = ZigZag::build(m, n);
            let mut seen = vec![false; m * n];
            for &i in &zz.scan {
                assert!(!seen[i as usize], "dup in {m}x{n}");
                seen[i as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
            // inverse consistency
            for (pos, &rm) in zz.scan.iter().enumerate() {
                assert_eq!(zz.inverse[rm as usize] as usize, pos);
            }
        }
    }

    #[test]
    fn apply_invert_roundtrip() {
        let zz = ZigZag::build(5, 7);
        let plane: Vec<f32> = (0..35).map(|i| i as f32).collect();
        let mut seq = vec![0.0; 35];
        let mut back = vec![0.0; 35];
        zz.apply(&plane, &mut seq);
        zz.invert(&seq, &mut back);
        assert_eq!(plane, back);
    }

    #[test]
    fn zigzag_orders_by_diagonal() {
        // positions of row-major indices along increasing diagonal number
        // must be non-decreasing in scan position.
        let zz = ZigZag::build(6, 4);
        let diag = |rm: usize| (rm / 4) + (rm % 4);
        let mut last_diag = 0;
        for &rm in &zz.scan {
            let d = diag(rm as usize);
            assert!(d >= last_diag || d + 1 == last_diag + 1);
            last_diag = last_diag.max(d);
        }
    }

    #[test]
    fn afd_split_respects_theta() {
        // Plane with energy concentrated at DC.
        let zz = ZigZag::build(4, 4);
        let mut plane = vec![0.1f32; 16];
        plane[0] = 10.0; // DC in row-major = first in zig-zag
        let split = afd_channel(&zz, &plane, 0.9);
        assert_eq!(split.k, 1, "DC alone carries >90% of energy");
        assert!(split.mean_energy_low > split.mean_energy_high);
    }

    #[test]
    fn afd_theta_one_takes_everything() {
        let zz = ZigZag::build(3, 3);
        let plane = vec![1.0f32; 9];
        let split = afd_channel(&zz, &plane, 1.0);
        assert_eq!(split.k, 9);
        assert_eq!(split.mean_energy_high, 0.0);
    }

    #[test]
    fn afd_zero_plane_defaults_to_dc() {
        let zz = ZigZag::build(4, 4);
        let plane = vec![0.0f32; 16];
        let split = afd_channel(&zz, &plane, 0.9);
        assert_eq!(split.k, 1);
        assert_eq!(split.mean_energy_low, 0.0);
    }

    #[test]
    fn afd_monotone_in_theta() {
        let zz = ZigZag::build(8, 8);
        let mut rng = crate::rng::Pcg32::seeded(9);
        // decaying spectrum
        let plane: Vec<f32> = (0..64)
            .map(|i| rng.normal() / (1.0 + i as f32 * 0.5))
            .collect();
        let mut last_k = 0;
        for &theta in &[0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
            let s = afd_channel(&zz, &plane, theta);
            assert!(s.k >= last_k, "k must grow with theta");
            last_k = s.k;
        }
    }

    #[test]
    fn cumulative_ratio_at_k_meets_threshold() {
        let zz = ZigZag::build(6, 6);
        let mut rng = crate::rng::Pcg32::seeded(10);
        let plane: Vec<f32> = (0..36).map(|_| rng.normal()).collect();
        let theta = 0.8;
        let s = afd_channel(&zz, &plane, theta);
        let total: f64 = s.coeffs.iter().map(|&c| (c as f64).powi(2)).sum();
        let low: f64 = s.coeffs[..s.k].iter().map(|&c| (c as f64).powi(2)).sum();
        assert!(low / total >= theta - 1e-9);
        if s.k > 1 {
            let low_m1: f64 = s.coeffs[..s.k - 1]
                .iter()
                .map(|&c| (c as f64).powi(2))
                .sum();
            assert!(low_m1 / total < theta, "k* must be minimal");
        }
    }
}
