//! Experiment-suite runner shared by the `examples/fig*` drivers.
//!
//! Runs a list of [`ExperimentConfig`] variants against a shared executor
//! (compiling each preset once), collects [`TrainOutcome`]s, prints
//! paper-style tables, and writes per-run CSVs under `results/`.

use crate::config::ExperimentConfig;
use crate::coordinator::{TrainOutcome, Trainer};
use crate::runtime::ExecutorHandle;
use anyhow::Result;
use std::collections::BTreeSet;

/// One completed run.
pub struct SuiteRun {
    /// The configuration that produced it.
    pub cfg: ExperimentConfig,
    /// The outcome.
    pub outcome: TrainOutcome,
}

/// Run every variant sequentially on a shared executor; writes
/// `results/<name>_<codec>.csv` per run.
pub fn run_suite(variants: Vec<ExperimentConfig>) -> Result<Vec<SuiteRun>> {
    anyhow::ensure!(!variants.is_empty(), "empty suite");
    let presets: BTreeSet<String> = variants
        .iter()
        .map(|v| v.dataset.name().to_string())
        .collect();
    let presets: Vec<String> = presets.into_iter().collect();
    let exec = ExecutorHandle::spawn(&variants[0].artifacts_dir, &presets)?;

    let mut runs = Vec::with_capacity(variants.len());
    for cfg in variants {
        crate::info!("=== run {} / codec {} ===", cfg.name, cfg.codec);
        let mut trainer = Trainer::new(cfg.clone(), exec.clone())?;
        let outcome = trainer.run()?;
        let path = format!("results/{}_{}.csv", cfg.name, cfg.codec);
        outcome.history.write_csv(&path)?;
        println!("{}   -> {path}", outcome.history.summary());
        runs.push(SuiteRun { cfg, outcome });
    }
    Ok(runs)
}

/// Print an accuracy-vs-round grid (rows = rounds, columns = runs), the
/// shape of the paper's Fig. 2/3/4 panels, plus a headline table.
pub fn print_convergence_table(title: &str, runs: &[SuiteRun]) {
    println!("\n### {title}");
    print!("{:>5} ", "round");
    for r in runs {
        print!(" {:>14}", label(r));
    }
    println!();
    let max_rounds = runs
        .iter()
        .map(|r| r.outcome.history.rounds.len())
        .max()
        .unwrap_or(0);
    for i in 0..max_rounds {
        print!("{:>5} ", i + 1);
        for r in runs {
            match r.outcome.history.rounds.get(i) {
                Some(m) => print!(" {:>13.2}%", m.test_acc * 100.0),
                None => print!(" {:>14}", "-"),
            }
        }
        println!();
    }
    println!("\n{:<16} {:>10} {:>10} {:>12} {:>14}", "run", "final acc", "best acc", "MB total", "MB->90% best");
    for r in runs {
        let h = &r.outcome.history;
        let target = 0.9 * runs.iter().map(|x| x.outcome.history.best_test_acc()).fold(0.0, f64::max);
        let mb_to_target = h
            .rounds_to_accuracy(target)
            .map(|round| h.cumulative_bytes(round - 1) as f64 / 1e6);
        println!(
            "{:<16} {:>9.2}% {:>9.2}% {:>12.2} {:>14}",
            label(r),
            h.final_test_acc() * 100.0,
            h.best_test_acc() * 100.0,
            h.total_bytes() as f64 / 1e6,
            mb_to_target
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "n/a".into()),
        );
    }
}

fn label(r: &SuiteRun) -> String {
    r.cfg.codec.clone()
}

/// Convenience: clone a base config with a new codec, applying the
/// byte-parity calibration used throughout the evaluation: every baseline's
/// aggressiveness is set so its wire volume lands near SL-FAC's (~8–10×
/// compression on cut-layer tensors), making "accuracy at equal
/// communication" the thing Fig. 2/4 actually compare (paper §III-A.3 pits
/// methods at their operating points; with a simulated link we can do the
/// fairer equal-bytes comparison and note it in EXPERIMENTS.md).
pub fn with_codec(base: &ExperimentConfig, codec: &str) -> ExperimentConfig {
    let mut c = base.clone();
    c.codec = codec.into();
    match codec {
        // top-k keeps 6 B/element (u32 idx + f16): ~10% kept ⇒ ~6.7×
        "tk-sl" => {
            c.codec_params.keep_fraction = 0.08;
            c.codec_params.random_fraction = 0.02;
        }
        // SplitFC at 4 bits: half the channels kept ⇒ ~14×
        "fc-sl" => {
            c.codec_params.keep_fraction = 0.5;
        }
        // spatial-selection ablations: ~15% kept at 6 bits ⇒ ~9×
        "magnitude" | "std" => {
            c.codec_params.keep_fraction = 0.15;
            c.codec_params.uniform_bits = 6;
        }
        // uniform-bit quantizers at 4 bits ⇒ 8×
        _ => {}
    }
    c
}

/// Convenience: clone a base config with a new θ (name updated).
pub fn with_theta(base: &ExperimentConfig, theta: f64) -> ExperimentConfig {
    let mut c = base.clone();
    c.codec_params.theta = theta;
    c.name = format!("{}_theta{}", c.name, theta);
    c
}
