//! Paper-style result tables for sweep outcomes.
//!
//! The figure grids themselves live in `configs/sweeps/*.json` and run
//! through [`crate::sweep::run_sweep`] (the `examples/fig*` drivers are
//! thin wrappers); this module only renders the executed runs as the
//! accuracy-vs-round panels and headline tables the paper's Figs. 2–4
//! use.

use crate::sweep::SweepRunResult;

/// Print one panel: an accuracy-vs-round grid (rows = rounds, columns =
/// runs) plus a headline table with accuracy-per-byte numbers. Column
/// labels are each run's last axis label (the innermost, fastest-varying
/// axis — codec for Fig. 2/4, θ for Fig. 3), falling back to the codec
/// name for axis-less runs.
pub fn print_convergence_table(title: &str, runs: &[&SweepRunResult]) {
    println!("\n### {title}");
    print!("{:>5} ", "round");
    for r in runs {
        print!(" {:>14}", label(r));
    }
    println!();
    let max_rounds = runs
        .iter()
        .map(|r| r.outcome.history.rounds.len())
        .max()
        .unwrap_or(0);
    for i in 0..max_rounds {
        print!("{:>5} ", i + 1);
        for r in runs {
            match r.outcome.history.rounds.get(i) {
                Some(m) => print!(" {:>13.2}%", m.test_acc * 100.0),
                None => print!(" {:>14}", "-"),
            }
        }
        println!();
    }
    println!(
        "\n{:<16} {:>10} {:>10} {:>12} {:>14}",
        "run", "final acc", "best acc", "MB total", "MB->90% best"
    );
    let target = 0.9
        * runs
            .iter()
            .map(|x| x.outcome.history.best_test_acc())
            .fold(0.0, f64::max);
    for r in runs {
        let h = &r.outcome.history;
        let mb_to_target = h
            .rounds_to_accuracy(target)
            .map(|round| h.cumulative_bytes(round - 1) as f64 / 1e6);
        println!(
            "{:<16} {:>9.2}% {:>9.2}% {:>12.2} {:>14}",
            label(r),
            h.final_test_acc() * 100.0,
            h.best_test_acc() * 100.0,
            h.total_bytes() as f64 / 1e6,
            mb_to_target
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "n/a".into()),
        );
    }
}

/// Print every panel of a sweep: consecutive runs sharing all axis labels
/// but the last form one panel (the grid expands with the last axis
/// fastest, so a panel is exactly one pass of the innermost axis). Runs
/// executed this invocation only — a resumed sweep prints the runs it
/// actually ran.
pub fn print_sweep_tables(title: &str, results: &[SweepRunResult]) {
    if results.is_empty() {
        println!("(no runs executed this invocation — nothing to tabulate)");
        return;
    }
    let mut start = 0;
    while start < results.len() {
        let key = panel_key(&results[start]);
        let mut end = start + 1;
        while end < results.len() && panel_key(&results[end]) == key {
            end += 1;
        }
        let panel: Vec<&SweepRunResult> = results[start..end].iter().collect();
        let panel_title = if key.is_empty() {
            title.to_string()
        } else {
            format!("{title}: {key}")
        };
        print_convergence_table(&panel_title, &panel);
        start = end;
    }
}

/// All axis labels but the innermost: the panel a run belongs to.
fn panel_key(r: &SweepRunResult) -> String {
    match r.run.labels.split_last() {
        Some((_, outer)) => outer.join(" / "),
        None => String::new(),
    }
}

fn label(r: &SweepRunResult) -> String {
    match r.run.labels.last() {
        Some(l) => l.clone(),
        None => r.run.cfg.codec.clone(),
    }
}
