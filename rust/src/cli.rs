//! Hand-rolled command-line parser (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options,
//! positional arguments, defaults, and generated `--help` text. Declarative
//! enough for the `slfac` binary and the experiment drivers:
//!
//! ```
//! use slfac::cli::Command;
//! let cmd = Command::new("demo", "demo tool")
//!     .opt("config", "PATH", "config file", Some("configs/mnist_iid.json"))
//!     .flag("verbose", "chatty output");
//! let m = cmd.parse_from(&["--verbose".into()]).unwrap();
//! assert!(m.flag("verbose"));
//! assert_eq!(m.get("config").unwrap(), "configs/mnist_iid.json");
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    value_name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative command/subcommand definition.
#[derive(Debug, Clone)]
pub struct Command {
    /// Command name (binary or subcommand).
    pub name: String,
    /// One-line description for help.
    pub about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>,
    subcommands: Vec<Command>,
}

/// Parse result: option values, set flags, positionals, chosen subcommand.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments, in order.
    pub positionals: Vec<String>,
    /// `Some((name, matches))` when a subcommand was invoked.
    pub subcommand: Option<(String, Box<Matches>)>,
}

impl Matches {
    /// Option value (or its default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required option value, with a readable error.
    pub fn req(&self, name: &str) -> Result<&str, String> {
        self.get(name)
            .ok_or_else(|| format!("missing required option --{name}"))
    }

    /// Parse an option as any `FromStr` type.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("option --{name}: cannot parse '{s}'")),
        }
    }

    /// Whether a flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse error (already formatted; includes usage on bad input).
#[derive(Debug)]
pub enum CliError {
    /// `--help` was requested; payload is the help text.
    Help(String),
    /// Malformed invocation.
    Bad(String),
}

impl Command {
    /// New command with no options.
    pub fn new(name: &str, about: &str) -> Self {
        Command {
            name: name.into(),
            about: about.into(),
            opts: Vec::new(),
            positionals: Vec::new(),
            subcommands: Vec::new(),
        }
    }

    /// Add a value option (optionally with a default).
    pub fn opt(
        mut self,
        name: &str,
        value_name: &str,
        help: &str,
        default: Option<&str>,
    ) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            value_name: value_name.into(),
            help: help.into(),
            default: default.map(|s| s.into()),
            is_flag: false,
        });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            value_name: String::new(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument (for help text only; extra positionals
    /// are always collected).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    /// Attach a subcommand.
    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subcommands.push(sub);
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str(" <SUBCOMMAND>");
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push('\n');
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let head = if o.is_flag {
                    format!("  --{}", o.name)
                } else {
                    format!("  --{} <{}>", o.name, o.value_name)
                };
                s.push_str(&format!("{head:<34} {}", o.help));
                if let Some(d) = &o.default {
                    s.push_str(&format!(" [default: {d}]"));
                }
                s.push('\n');
            }
        }
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<12}> {h}\n"));
            }
        }
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for sub in &self.subcommands {
                s.push_str(&format!("  {:<14} {}\n", sub.name, sub.about));
            }
        }
        s
    }

    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse(&self) -> Result<Matches, CliError> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&args)
    }

    /// Parse an explicit argument vector.
    pub fn parse_from(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut m = Matches::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                m.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help(self.help()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Bad(format!("unknown option --{name}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError::Bad(format!("flag --{name} takes no value")));
                    }
                    m.flags.push(name.to_string());
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    CliError::Bad(format!("option --{name} needs a value"))
                                })?
                        }
                    };
                    m.values.insert(name.to_string(), v);
                }
            } else if m.positionals.is_empty() && m.subcommand.is_none() {
                // First bare word: subcommand if one matches, else positional.
                if let Some(sub) = self.subcommands.iter().find(|s| s.name == *a) {
                    let rest = args[i + 1..].to_vec();
                    let sub_m = sub.parse_from(&rest)?;
                    m.subcommand = Some((sub.name.clone(), Box::new(sub_m)));
                    return Ok(m);
                }
                m.positionals.push(a.clone());
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let c = Command::new("t", "test").opt("theta", "F", "threshold", Some("0.9"));
        let m = c.parse_from(&args(&[])).unwrap();
        assert_eq!(m.get("theta"), Some("0.9"));
        let m = c.parse_from(&args(&["--theta", "0.7"])).unwrap();
        assert_eq!(m.get("theta"), Some("0.7"));
        let m = c.parse_from(&args(&["--theta=0.8"])).unwrap();
        assert_eq!(m.get("theta"), Some("0.8"));
    }

    #[test]
    fn flags() {
        let c = Command::new("t", "test").flag("fast", "go fast");
        assert!(!c.parse_from(&args(&[])).unwrap().flag("fast"));
        assert!(c.parse_from(&args(&["--fast"])).unwrap().flag("fast"));
    }

    #[test]
    fn unknown_option_rejected() {
        let c = Command::new("t", "test");
        assert!(matches!(
            c.parse_from(&args(&["--nope"])),
            Err(CliError::Bad(_))
        ));
    }

    #[test]
    fn subcommands_route() {
        let c = Command::new("slfac", "x")
            .subcommand(Command::new("train", "train").opt("rounds", "N", "rounds", Some("10")));
        let m = c.parse_from(&args(&["train", "--rounds", "5"])).unwrap();
        let (name, sub) = m.subcommand.unwrap();
        assert_eq!(name, "train");
        assert_eq!(sub.get("rounds"), Some("5"));
    }

    #[test]
    fn positionals_collected() {
        let c = Command::new("t", "test").positional("file", "input");
        let m = c.parse_from(&args(&["a.txt", "b.txt"])).unwrap();
        assert_eq!(m.positionals, vec!["a.txt", "b.txt"]);
    }

    #[test]
    fn help_requested() {
        let c = Command::new("t", "test").flag("x", "y");
        match c.parse_from(&args(&["--help"])) {
            Err(CliError::Help(h)) => assert!(h.contains("USAGE")),
            other => panic!("expected help, got {other:?}"),
        }
    }

    #[test]
    fn parsed_typed_values() {
        let c = Command::new("t", "test").opt("n", "N", "count", Some("3"));
        let m = c.parse_from(&args(&[])).unwrap();
        assert_eq!(m.get_parsed::<usize>("n").unwrap(), Some(3));
        let m = c.parse_from(&args(&["--n", "xyz"])).unwrap();
        assert!(m.get_parsed::<usize>("n").is_err());
    }
}
