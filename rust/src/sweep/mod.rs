//! Experiment sweep orchestrator: declarative grids, resumable
//! checkpointed execution, and a paginated results control plane.
//!
//! The paper's figures are grids — codec × scheduler × straggler ×
//! sampling × contention × seed — and this module is the single harness
//! that runs them reproducibly:
//!
//! - [`spec`]: [`SweepSpec`] parses a JSON grid description and
//!   cross-products its axes into concrete, fully validated [`RunSpec`]s
//!   (see `configs/sweeps/` for the shipped figure grids).
//! - [`orchestrator`]: [`run_sweep`] plans, executes across a
//!   scoped-thread worker pool, and checkpoints each completed run to an
//!   append-only [`Journal`]; restarting skips journaled runs, and an
//!   interrupted+resumed sweep is **byte-identical** to an uninterrupted
//!   one at any worker count (pinned by `tests/sweep_determinism.rs`).
//! - [`report`]: stable `slfac-sweep/1` pages with `run:<id>` keyset
//!   cursors, queryable while the sweep is still executing.
//!
//! The `slfac sweep run | status | report` CLI subcommands front all
//! three.

pub mod journal;
pub mod orchestrator;
pub mod report;
pub mod spec;

pub use journal::{Journal, JournalHeader, RunMetrics, RunRecord};
pub use orchestrator::{
    journal_path, planned_header, run_sweep, sweep_status, verify_journal, SweepOptions,
    SweepOutcome, SweepRunResult,
};
pub use report::{cursor_for, page, pages, parse_cursor};
pub use spec::{Axis, RunSpec, SweepSpec};
